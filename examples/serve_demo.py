"""End-to-end serving driver: continuous batching over a small model.

Eight requests with different prompt lengths share 3 decode slots; the
engine admits queued requests as slots free (iteration-level scheduling).

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_smoke_config("h2o-danube-1.8b")   # SWA decode path
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    engine = ServeEngine(bundle, params, slots=3, max_len=64)

    rng = np.random.default_rng(0)
    n_requests, total_new = 8, 0
    for rid in range(n_requests):
        plen = int(rng.integers(3, 9))
        new = int(rng.integers(4, 10))
        total_new += new
        engine.submit(Request(rid, rng.integers(0, cfg.vocab, size=plen),
                              new))
    t0 = time.time()
    done = engine.run(max_steps=500)
    dt = time.time() - t0

    assert len(done) == n_requests
    print(f"served {len(done)} requests / {total_new} new tokens in "
          f"{dt:.1f}s with 3 slots (continuous batching)")
    for c in sorted(done, key=lambda c: c.rid):
        print(f"  request {c.rid}: {len(c.tokens)} tokens -> "
              f"{c.tokens[:8]}{'...' if len(c.tokens) > 8 else ''}")


if __name__ == "__main__":
    main()
