"""Collaborative applicability demo: data-availability cases A-D
(paper §IV-D, fig. 5) on the emulated scout-like dataset.

Run:  PYTHONPATH=src python examples/collaborative_search.py
"""
import numpy as np

from repro.core import (BOConfig, Constraint, Objective, run_search,
                        scout_search_space)
from repro.simdata import make_emulator
import sys
sys.path.insert(0, ".")
from benchmarks.common import case_repo, build_same_workload_pool  # noqa: E402


def main():
    emu = make_emulator()
    space = scout_search_space()
    target = "spark2.1/pagerank/web-large"
    rt = emu.runtime_target(target, 50)
    opt = emu.optimal_cost(target, rt)
    print(f"target {target}; runtime target {rt:.0f}s; optimal ${opt:.4f}\n")

    pool = build_same_workload_pool(target, 4, iters=10)
    rng = np.random.default_rng(0)

    def one(method, repo=None, tag=""):
        prof_rng = np.random.default_rng(1)
        res = run_search(space, lambda c: emu.run(target, c, rng=prof_rng),
                         Objective("cost"), [Constraint("runtime", rt)],
                         method=method, repository=repo,
                         bo_config=BOConfig(max_iters=10, n_support=3,
                                            n_init=1 if repo else 3),
                         seed=1)
        best = res.best_index_per_iter[-1]
        cost = emu.run(target, res.observations[best].config)[0]["cost"] \
            if best >= 0 else float("nan")
        print(f"  {tag:28s} final cost ${cost:.4f} "
              f"({cost / opt - 1:+.1%} vs optimal)")

    one("naive", tag="NaiveBO (no sharing)")
    for case, desc in [("A", "diff fw+algo+data"),
                       ("B", "same fw"),
                       ("C", "same fw+algo"),
                       ("D", "same workload")]:
        repo = case_repo(target, case, pool=pool, seed=3 + ord(case))
        one("karasu", repo, f"Karasu case {case} ({desc})")


if __name__ == "__main__":
    main()
