"""Quickstart: Karasu-accelerated cluster-configuration search.

A target workload searches the 69-config AWS space for the cheapest
configuration meeting its runtime target, bootstrapped from one
collaborator's shared profiling runs of a similar workload.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (BOConfig, Constraint, Objective, Repository,
                        run_search, scout_search_space)
from repro.simdata import make_emulator


def main():
    emu = make_emulator()
    space = scout_search_space()
    target = "spark2.1/kmeans/points-100m"
    runtime_target = emu.runtime_target(target, 50)
    optimal = emu.optimal_cost(target, runtime_target)
    print(f"target workload : {target}")
    print(f"runtime target  : {runtime_target:.0f}s  "
          f"(optimal feasible cost ${optimal:.4f})")

    # a collaborator shared profiling runs of a related workload — only
    # (opaque id, config, compact metrics, measures) cross the boundary
    repo = Repository()
    rng = np.random.default_rng(7)
    donor = "spark1.5/kmeans/points-100m"
    for ci in rng.choice(len(space), 12, replace=False):
        repo.add_run(emu.make_record("anon-collab", donor,
                                     space.configs[int(ci)], rng))

    rng_t = np.random.default_rng(0)
    profile = lambda c: emu.run(target, c, rng=rng_t)
    for method, kwargs in [("naive", {}),
                           ("karasu", {"repository": repo})]:
        res = run_search(space, profile, Objective("cost"),
                         [Constraint("runtime", runtime_target)],
                         method=method,
                         bo_config=BOConfig(max_iters=10,
                                            n_init=1 if method == "karasu"
                                            else 3),
                         seed=0, **kwargs)
        traj = [res.observations[i].measures["cost"] if i >= 0 else None
                for i in res.best_index_per_iter]
        print(f"\n{method:7s} incumbent cost per profiling run:")
        print("  " + " ".join("   -  " if t is None else f"{t:6.3f}"
                              for t in traj))
        best = res.best_index_per_iter[-1]
        print(f"  best config: {dict(res.observations[best].config)}")


if __name__ == "__main__":
    main()
