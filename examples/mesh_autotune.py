"""TPU-mesh auto-tuning with Karasu (the hardware adaptation).

The "workload" is an (architecture x input shape) cell; the "resource
configuration" is the mesh layout + launch knobs. Support models come
from other architectures' searches shared through the repository — the
paper's collaborative transfer, applied to parallelism planning.

Uses the analytic roofline black box (fast); swap mode="compile" for the
real lower+compile loop (needs the 512-device XLA flag).

Run:  PYTHONPATH=src python examples/mesh_autotune.py
"""
import numpy as np

from repro.core import Repository, RunRecord, tpu_search_space
from repro.launch.karasu_search import (analytic_profile,
                                        result_to_records,
                                        search_mesh_config)


def main():
    space = tpu_search_space(pods=(1, 2), model_par=(4, 8, 16, 32),
                             microbatches=(2, 4, 8, 16),
                             seq_parallel=(False, True))
    # collaborators already tuned two other dense models
    repo = Repository()
    rng = np.random.default_rng(0)
    for j, donor in enumerate(["gemma2-27b", "h2o-danube-1.8b"]):
        for ci in rng.choice(len(space), 16, replace=False):
            cfg = space.configs[int(ci)]
            m, metr = analytic_profile(donor, "train_4k", cfg)
            repo.add_run(RunRecord(f"anon-{j}", cfg, metr, m))

    print("tuning minitron-8b train_4k over", len(space), "mesh configs")
    for method, r in [("naive", None), ("karasu", repo)]:
        res = search_mesh_config("minitron-8b", "train_4k",
                                 mode="analytic", repository=r,
                                 max_iters=8, seed=0, space=space)
        best = res.best_index_per_iter[-1]
        o = res.observations[best]
        cfgs = {k: v for k, v in o.config.items()
                if k not in ("machine_type", "node_count")}
        print(f"  {method:7s}: best step={o.measures['runtime']*1e3:.0f}ms"
              f"  mfu={o.measures['mfu']:.2f}  {cfgs}")


if __name__ == "__main__":
    main()
