"""End-to-end training driver: a ~100M-parameter dense LM trained for a
few hundred steps on synthetic data, with checkpointing, fault-injected
restart, and straggler watchdog — the single-host miniature of the
production loop in launch/train.py.

Run:  PYTHONPATH=src python examples/train_100m.py            # full (~100M)
      PYTHONPATH=src python examples/train_100m.py --preset ci  # small/fast
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, build_model
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.fault import FailureInjector, run_resilient
from repro.train.optim import adamw, cosine_schedule
from repro.train.step import make_train_step

PRESETS = {
    # ~103M params: 12L x 512d x 8H, d_ff 2048, vocab 32k (tied)
    "full": dict(n_layers=12, d_model=512, n_heads=8, n_kv_heads=8,
                 d_ff=2048, vocab=32768, seq=256, batch=8, steps=300),
    # ~7M params, a minute on CPU
    "ci": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
               d_ff=512, vocab=8192, seq=128, batch=4, steps=60),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="full", choices=list(PRESETS))
    ap.add_argument("--ckpt-dir", default="artifacts/train_100m")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[17])
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"dense-{args.preset}", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"], vocab=p["vocab"],
        block_pattern=("attn",), tie_embeddings=True, remat=False,
        param_dtype=jnp.float32)
    bundle = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"steps={p['steps']}  batch={p['batch']}x{p['seq']}")

    opt = adamw(weight_decay=0.01)
    step_fn = jax.jit(make_train_step(
        bundle, opt, cosine_schedule(3e-4, 20, p["steps"]),
        microbatches=1), donate_argnums=(0, 1))

    data = SyntheticLM(cfg.vocab, p["seq"], p["batch"], seed=0)
    pf = Prefetcher(data, start_step=0, depth=2)

    def init_state():
        params = bundle.init(jax.random.PRNGKey(0))
        return params, opt.init(params)

    t0 = time.time()
    losses = []

    def batch_at(step):
        return {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}

    def step_logged(params, opt_state, batch, step):
        out = step_fn(params, opt_state, batch,
                      jnp.asarray(step, jnp.int32))
        loss = float(out[2]["loss"])
        if step % 10 == 0:
            tok_s = (step + 1) * p["batch"] * p["seq"] / \
                max(time.time() - t0, 1e-9)
            print(f"  step {step:4d}  loss {loss:.4f}  "
                  f"~{tok_s:,.0f} tok/s", flush=True)
        return out

    report = run_resilient(
        init_state=init_state, step_fn=step_logged, batch_at=batch_at,
        total_steps=p["steps"], ckpt_dir=args.ckpt_dir, ckpt_every=20,
        injector=FailureInjector(fail_at=args.fail_at))
    pf.close()

    print(f"\ndone: {report.steps_done} steps, {report.restarts} restart(s) "
          f"(injected node failure), {len(report.stragglers)} stragglers, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"in {time.time()-t0:.0f}s")
    assert report.losses[-1] < report.losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
