"""Roofline table benchmark: per (arch x shape x mesh) cell, read the
dry-run artifact and emit the three terms + projected MFU (the §Roofline
deliverable). `us_per_call` is the dominant roofline term (the projected
step time bound) in microseconds."""
from __future__ import annotations

import os

from repro.launch.roofline import load_artifacts, roofline_from_artifact

from . import common as C

ART_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def main():
    arts = load_artifacts(ART_DIR)
    if not arts:
        C.emit("roofline_missing_artifacts", 0.0,
               f"run: python -m repro.launch.dryrun --all --out {ART_DIR}")
        return
    n_ok = 0
    for a in arts:
        r = roofline_from_artifact(a)
        name = f"roofline_{a.get('arch')}_{a.get('shape')}_{a.get('mesh')}"
        if r is None:
            C.emit(name, 0.0, f"status={a.get('status')}")
            continue
        n_ok += 1
        bound_us = max(r.compute_s, r.memory_s, r.collective_s) * 1e6
        C.emit(name, bound_us,
               f"MFU={r.projected_mfu:.3f};dom={r.dominant};"
               f"useful={r.useful_ratio:.2f};hbm={r.hbm_gib:.1f}GiB;"
               f"fits={'y' if r.fits_hbm else 'N'}")
    C.emit("roofline_cells_ok", 0.0, n_ok)


if __name__ == "__main__":
    main()
