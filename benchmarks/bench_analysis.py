"""Static-analyzer wall time: per-rule and total lint cost. The lint
CLI gates CI, so its latency is a budget like any other — `us_per_call`
is the rule's wall time, `derived` its finding count."""
from __future__ import annotations

import time

from repro.analysis.lint import RULES, run_rule
from repro.analysis.findings import apply_suppressions

from . import common as C


def main():
    total = 0.0
    n_findings = 0
    for rule in RULES:
        t0 = time.perf_counter()
        findings = apply_suppressions(run_rule(rule))
        wall = time.perf_counter() - t0
        total += wall
        n_findings += len(findings)
        errors = sum(1 for f in findings if f.severity == "error")
        C.emit(f"lint_{rule.replace('-', '_')}", wall * 1e6,
               f"findings={len(findings)};errors={errors}")
    C.emit("lint_total", total * 1e6, f"findings={n_findings}")


if __name__ == "__main__":
    main()
