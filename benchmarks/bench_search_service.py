"""Multi-tenant SearchService vs looped single-tenant run_search.

The ROADMAP's serving scenario: N users each run a Karasu search against
one shared repository. The baseline loops ``run_search`` per tenant
(each search refits every target and support GP in Python loops); the
service batches all tenants' target fits into one vmapped Cholesky per
step and shares one incremental support-model store.

Emits (CSV, benchmarks/run.py format):
  search_service_loop     — looped baseline, us per tenant-iteration
  search_service_batched  — SearchService,   us per tenant-iteration
  search_service_speedup  — derived = loop_wall / service_wall (~1.8x
                            since run_search adopted the jit-stable
                            batched fit; the >= 2.0 acceptance now
                            lives on search_service_async_speedup)

With ``--slow-profilers`` (or REPRO_BENCH_SLOW_PROFILERS=1) it instead
measures the async-profiling path: 8 tenants whose profile_fns carry
heterogeneous artificial latencies (100..800 ms), synchronous executor
vs thread pool. The synchronous service pays the SUM of the latencies every
round; the async service pays ~the MAX, because WAITING_PROFILE sessions
overlap their cluster runs while landed sessions keep fitting:
  search_service_sync_profilers   — us per tenant-iteration
  search_service_async_profilers  — us per tenant-iteration
  search_service_async_speedup    — derived (acceptance: >= 2.0)

With ``--moo`` it measures the fused posterior + sample query plans on
a mixed single-objective + multi-objective karasu cohort: the fused
service (one padded batched_posterior launch per step, fused RGPE
support-sample draws via batched_sample_multi, vmapped multi-session
MC-EHVI) vs the loop path (``fuse_posteriors=False, fuse_samples=False``
— per-ensemble posteriors, per-job sample draws, per-candidate EHVI
reference):
  search_service_moo_loop     — loop path,             us/tenant-iter
  search_service_moo_fused    — fused query plans,     us/tenant-iter
  search_service_moo_speedup  — derived (acceptance: >= 2.0 at 8 tenants)
  search_service_moo_sample_speedup — fused-samples-vs-sample-loop
                                contribution (posteriors fused in both)

With ``--smoke`` it runs a tiny mixed cohort (4 tenants: naive SO,
karasu SO, karasu 2-objective, karasu 3-objective; 4 iterations) end to
end — twice: the first pass compiles every launch shape, the repeat
must hit the compile-once steady state (``plan_compile_misses == 0``,
with the executor dispatching the fused EHVI bucket launch)
— and asserts completion AND that the query-plan layer actually
engaged (``plan_batches <= plan_queries`` with fusion on every leg:
posterior/sample/EHVI) — the CPU CI hook that fails fast when the
serving path regresses, instead of waiting for the weekly slow job.
``REPRO_BENCH_STATS_JSON=path`` (or ``--stats-json path``) additionally
dumps the service stats as JSON, which CI uploads as an artifact so
fusion regressions are diagnosable from the run page.

With ``--steady-state`` it measures the compile-once serving claim
directly: per-step latency of a churning mixed cohort served cold vs
after ``SearchService.precompile`` (asserting zero tracked recompiles
post-precompile), the fused posterior+EI and fused
draw+EHVI bucket kernels vs the vmapped XLA chains, and the fused
launches' static roofline numbers:
  search_service_steady_cold_step / _warm_step  — us per service step
  search_service_precompile                     — one-time warmup cost
  search_service_steady_misses                  — must be 0
  fused_posterior_launch / _vs_vmapped_speedup / _roofline_intensity
  fused_ehvi_launch / _vs_vmapped_speedup / _roofline_intensity

With ``--mesh N`` (or REPRO_BENCH_MESH=N) it forces an N-device host
platform (``--xla_force_host_platform_device_count``, staged before jax
imports) and measures data-parallel serving: a 64-tenant karasu cohort
served warm on the single-device executor vs with every bucket's lane
axis sharded over the N-device ``("data",)`` mesh, asserting the warm
sharded pass holds ``plan_compile_misses == 0``:
  search_service_mesh1_step / _mesh<N>_step — us per service step
  search_service_mesh_scaling               — measured step-time ratio
  search_service_mesh_misses                — must be 0
  search_service_mesh*_fit_wall             — fit leg dispatch wall
``--mesh`` composes with ``--smoke``: the CI mesh leg runs the smoke
cohort through the sharded executor under REPRO_BENCH_MESH=4.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _parse_mesh_argv() -> int:
    n = int(os.environ.get("REPRO_BENCH_MESH", "0") or 0)
    if "--mesh" in sys.argv[1:]:
        at = sys.argv.index("--mesh")
        if at + 1 >= len(sys.argv):
            raise SystemExit("--mesh needs a device-count argument")
        n = int(sys.argv[at + 1])
    return n


# --mesh N (or REPRO_BENCH_MESH=N) serves the cohort through the
# data-parallel plan executor on an N-device host platform. XLA reads
# --xla_force_host_platform_device_count once at backend init, so the
# flag must be staged into the environment HERE, before the repro
# imports below pull in jax (external XLA_FLAGS already forcing a
# device count are respected as-is).
MESH_N = _parse_mesh_argv()
if MESH_N > 1 and "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count={MESH_N}"
        ).strip()

import numpy as np

from repro.core import (BOConfig, Constraint, Objective, Repository,
                        run_search)
from repro.serve.profile_executor import (SyncProfileExecutor,
                                          ThreadPoolProfileExecutor)
from repro.serve.search_service import SearchRequest, SearchService

from . import common as C

N_TENANTS = {"ci": 8, "mid": 8, "full": 16}
MAX_ITERS = {"ci": 10, "mid": 12, "full": 20}

_MESH_CACHE: dict = {}


def _mesh():
    """The benchmark's data mesh: ``Mesh((MESH_N,), ("data",))`` when
    mesh mode is on, else None (single-device executor). Cached so every
    service of the run shares ONE mesh object — the sharded launch twins
    are cached per mesh, and repeat cohorts must re-enter the same jit
    caches for the compile-once assertions to hold."""
    if MESH_N <= 1:
        return None
    if "mesh" not in _MESH_CACHE:
        import jax
        if len(jax.devices()) < MESH_N:
            raise SystemExit(
                f"--mesh {MESH_N} needs {MESH_N} devices but the backend "
                f"has {len(jax.devices())} (is XLA_FLAGS= "
                f"--xla_force_host_platform_device_count set before jax "
                f"init?)")
        _MESH_CACHE["mesh"] = jax.make_mesh((MESH_N,), ("data",))
    return _MESH_CACHE["mesh"]


def _setup(n_tenants: int):
    emu = C.emulator()
    sp = C.space()
    wids = emu.workload_ids()
    tenants = [wids[i % len(wids)] for i in range(n_tenants)]
    # shared repository: uniformly profiled collaborator runs of the
    # tenants' workloads (case-D-like, 12 runs each)
    repo = C.random_profiled_repo(sorted(set(tenants)), 12, seed=7)
    targets = {w: emu.runtime_target(w, 50) for w in set(tenants)}
    return sp, tenants, repo, targets


def _fresh_repo(repo: Repository) -> Repository:
    # both paths mutate nothing, but rebuild anyway so neither inherits
    # the other's version counters
    out = Repository()
    for z, rs in repo.all_runs().items():
        out.add_runs(rs)
    return out


def _loop(sp, tenants, repo, targets, max_iters: int) -> float:
    t0 = time.time()
    for t, wid in enumerate(tenants):
        run_search(sp, C.profile_fn(wid, t), Objective("cost"),
                   [Constraint("runtime", targets[wid])], method="karasu",
                   repository=repo,
                   bo_config=BOConfig(max_iters=max_iters), seed=t)
    return time.time() - t0


def _service(sp, tenants, repo, targets, max_iters: int) -> float:
    t0 = time.time()
    svc = SearchService(repo, slots=len(tenants))
    for t, wid in enumerate(tenants):
        svc.submit(SearchRequest(sp, C.profile_fn(wid, t),
                                 Objective("cost"),
                                 [Constraint("runtime", targets[wid])],
                                 method="karasu",
                                 bo_config=BOConfig(max_iters=max_iters),
                                 seed=t))
    done = svc.run()
    assert len(done) == len(tenants)
    return time.time() - t0


def _slow_profile_fn(wid: str, seed: int, latency_s: float):
    # a fresh Generator per call, seeded from (workload, tenant, config):
    # the thread pool may run one tenant's init jobs concurrently, and
    # numpy Generators are not thread-safe — per-call seeding keeps the
    # draws deterministic no matter how the pool schedules them
    import zlib
    base = (zlib.crc32(wid.encode()) & 0xFFFF, seed)

    def fn(config):
        time.sleep(latency_s)      # stand-in for the cluster run
        rng = np.random.default_rng(
            base + (int(config["node_count"]),
                    zlib.crc32(str(config["machine_type"]).encode())))
        return C.emulator().run(wid, config, rng=rng)

    return fn


def _service_with_executor(sp, tenants, repo, targets, max_iters,
                           latencies, executor, wait_mode) -> float:
    svc = SearchService(repo, slots=len(tenants), executor=executor,
                        wait_mode=wait_mode)
    for t, wid in enumerate(tenants):
        svc.submit(SearchRequest(
            sp, _slow_profile_fn(wid, t, latencies[t]), Objective("cost"),
            [Constraint("runtime", targets[wid])], method="naive",
            bo_config=BOConfig(max_iters=max_iters), seed=t))
    t0 = time.time()
    done = svc.run()
    assert len(done) == len(tenants)
    svc.close()
    return time.time() - t0


def slow_profilers() -> None:
    """Async vs synchronous profiling at 8 tenants with heterogeneous
    profile latencies (the ISSUE-2 acceptance scenario).

    Real cluster bring-up takes minutes, so the profiling-bound regime
    is the honest one; we emulate it with 100..800 ms sleeps (an 8x
    spread, as between a smoke-test config and a many-node cluster
    bring-up). NaiveBO
    keeps the model math identical across tenants so the measurement
    isolates profiling overlap; karasu's extra fit work is the same in
    both paths and only dilutes the contrast."""
    n_tenants = 8
    max_iters = MAX_ITERS.get(C.SCALE, 10)
    sp, tenants, repo, targets = _setup(n_tenants)
    iters_total = n_tenants * max_iters
    latencies = [0.1 * (t + 1) for t in range(n_tenants)]

    # untimed jit warmup at the TIMED shapes (8 tenants -> 16-model pow2
    # bucket; 9 obs -> 16-obs round_to bucket) with zero latency, so
    # neither timed run is charged for one-time XLA compiles
    _service_with_executor(sp, tenants, _fresh_repo(repo), targets,
                           min(9, max_iters), [0.0] * n_tenants,
                           SyncProfileExecutor(), "any")

    sync_s = _service_with_executor(
        sp, tenants, _fresh_repo(repo), targets, max_iters, latencies,
        SyncProfileExecutor(), "any")
    async_s = _service_with_executor(
        sp, tenants, _fresh_repo(repo), targets, max_iters, latencies,
        ThreadPoolProfileExecutor(max_workers=n_tenants), "any")

    C.emit("search_service_sync_profilers", sync_s * 1e6 / iters_total,
           f"{n_tenants}tenants")
    C.emit("search_service_async_profilers", async_s * 1e6 / iters_total,
           f"{n_tenants}tenants")
    C.emit("search_service_async_speedup", 0.0,
           f"{sync_s / async_s:.2f}")


def _moo_mixed_requests(sp, tenants, targets, max_iters, *, n_mc=64):
    """Every other tenant is multi-objective (cost x energy under the
    runtime constraint); the rest single-objective. All karasu, so the
    fused plan carries targets AND support stacks for both kinds."""
    reqs = []
    for t, wid in enumerate(tenants):
        cons = [Constraint("runtime", targets[wid])]
        if t % 2 == 1:
            reqs.append(SearchRequest(
                sp, C.profile_fn(wid, t), None, cons, method="karasu",
                bo_config=BOConfig(max_iters=max_iters), seed=t,
                objectives=[Objective("cost"), Objective("energy")],
                n_mc=n_mc))
        else:
            reqs.append(SearchRequest(
                sp, C.profile_fn(wid, t), Objective("cost"), cons,
                method="karasu", bo_config=BOConfig(max_iters=max_iters),
                seed=t))
    return reqs


def _service_moo(sp, tenants, repo, targets, max_iters, *,
                 fuse: bool, fuse_samples=None) -> float:
    svc = SearchService(repo, slots=len(tenants), fuse_posteriors=fuse,
                        fuse_samples=(fuse if fuse_samples is None
                                      else fuse_samples))
    for req in _moo_mixed_requests(sp, tenants, targets, max_iters):
        svc.submit(req)
    t0 = time.time()
    done = svc.run()
    assert len(done) == len(tenants)
    return time.time() - t0


def moo_mixed() -> None:
    """Fused posterior + sample query plans vs the per-session loop on
    a mixed SO+MOO karasu cohort (the ISSUE-3/ISSUE-4 acceptance
    scenario)."""
    n_tenants = 8
    max_iters = MAX_ITERS.get(C.SCALE, 10)
    sp, tenants, repo, targets = _setup(n_tenants)
    iters_total = n_tenants * max_iters

    # untimed jit warmup at the timed shapes for every measured path —
    # FULL length: the sample plan's grid buckets track the growing
    # observation count, so a shorter warmup would charge the fused
    # path for late-step bucket compiles the loop never pays
    warm = max_iters
    _service_moo(sp, tenants, _fresh_repo(repo), targets, warm, fuse=True)
    _service_moo(sp, tenants, _fresh_repo(repo), targets, warm, fuse=False)
    _service_moo(sp, tenants, _fresh_repo(repo), targets, warm,
                 fuse=True, fuse_samples=False)

    loop_s = _service_moo(sp, tenants, _fresh_repo(repo), targets,
                          max_iters, fuse=False)
    fused_s = _service_moo(sp, tenants, _fresh_repo(repo), targets,
                           max_iters, fuse=True)
    # posterior plan fused in both; isolates the sample-draw fusion
    sloop_s = _service_moo(sp, tenants, _fresh_repo(repo), targets,
                           max_iters, fuse=True, fuse_samples=False)

    C.emit("search_service_moo_loop", loop_s * 1e6 / iters_total,
           f"{n_tenants}tenants")
    C.emit("search_service_moo_fused", fused_s * 1e6 / iters_total,
           f"{n_tenants}tenants")
    C.emit("search_service_moo_speedup", 0.0, f"{loop_s / fused_s:.2f}")
    C.emit("search_service_moo_sample_speedup", 0.0,
           f"{sloop_s / fused_s:.2f}")


def _smoke_cohort(sp, tenants, repo, targets, max_iters):
    """The 4-tenant mixed cohort smoke() measures, as a reusable run:
    returns (service, completions, elapsed seconds). The executor runs
    with ``fused_ehvi=True`` so the zero-recompile assertion covers the
    fused draw+EHVI bucket launch, not just the vmapped chain."""
    from repro.core.plan import PlanExecutor
    mesh = _mesh()
    svc = SearchService(repo, slots=4, mesh=mesh,
                        plan_executor=PlanExecutor(fused_ehvi=True,
                                                   mesh=mesh))
    wid0, wid1, wid2 = tenants[:3]
    svc.submit(SearchRequest(
        sp, C.profile_fn(wid0, 0), Objective("cost"),
        [Constraint("runtime", targets[wid0])], method="naive",
        bo_config=BOConfig(max_iters=max_iters), seed=0))
    svc.submit(SearchRequest(
        sp, C.profile_fn(wid1, 1), Objective("cost"),
        [Constraint("runtime", targets[wid1])], method="karasu",
        bo_config=BOConfig(max_iters=max_iters), seed=1))
    svc.submit(SearchRequest(
        sp, C.profile_fn(wid2, 2), None,
        [Constraint("runtime", targets[wid2])], method="karasu",
        bo_config=BOConfig(max_iters=max_iters), seed=2,
        objectives=[Objective("cost"), Objective("energy")], n_mc=8))
    # n=3 objectives: the box-decomposition EHVI plan node
    svc.submit(SearchRequest(
        sp, C.profile_fn(wid0, 3), None, [], method="karasu",
        bo_config=BOConfig(max_iters=max_iters), seed=3,
        objectives=[Objective("cost"), Objective("energy"),
                    Objective("runtime")], n_mc=8))
    t0 = time.time()
    done = {c.rid: c.result for c in svc.run()}
    return svc, done, time.time() - t0


def smoke() -> None:
    """CI smoke: a 4-tenant mixed cohort (naive SO, karasu SO, karasu
    2-objective, karasu 3-objective) over 5 iterations must complete,
    route its model math through the query-plan layer, and produce
    (k, 2) and (k, 3) Pareto fronts — fast enough for the tier-1 CPU
    job. Five iterations leave TWO model-driven steps past ``n_init``,
    so every model refits once and the second fit must ride the
    warm-start cache (``fit_warm_lanes > 0``). The cohort then runs a
    SECOND time against warm jit caches: the repeat must hit the
    compile-once steady state (``plan_compile_misses == 0``), which is
    the invariant CI asserts from the dumped stats JSON artifact."""
    sp, tenants, repo, targets = _setup(3)
    max_iters = 5
    cold_svc, done, _ = _smoke_cohort(sp, tenants, _fresh_repo(repo),
                                      targets, max_iters)
    svc, done2, dt = _smoke_cohort(sp, tenants, _fresh_repo(repo),
                                   targets, max_iters)
    assert sorted(done) == [0, 1, 2, 3], done
    assert sorted(done2) == [0, 1, 2, 3], done2
    done = done2
    # every tracked launch shape compiled in the first run; the repeat
    # cohort re-enters only precompiled buckets
    assert svc.stats["plan_compile_misses"] == 0, \
        (svc.stats["plan_compile_misses"], cold_svc.stats)
    for res in done.values():
        assert len(res.observations) == max_iters
    assert done[2].meta["moo"] is True
    assert len(done[2].meta["pareto_front"]) >= 1
    front3 = done[3].meta["pareto_front"]
    assert front3.ndim == 2 and front3.shape[1] == 3 and len(front3) >= 1
    # the query-plan layer must have engaged on every leg: far fewer
    # fused launches (plan_batches) than the query nodes they carried
    # (plan_queries), with per-kind fusion for posteriors, the RGPE/MOO
    # sample draws, and the EHVI evaluations
    s = svc.stats
    assert s["plan_batches"] >= 1, s
    assert s["plan_batches"] <= s["plan_queries"], s
    assert s["plan_batches"] == (s["posterior_batches"]
                                 + s["sample_batches"]
                                 + s["ehvi_batches"]
                                 + s["fit_batches"]), s
    assert s["posterior_batches"] < s["posterior_queries"], s
    assert s["sample_batches"] >= 1, s
    assert s["sample_queries"] > s["sample_batches"], s
    assert s["ehvi_batches"] >= 1, s
    # the fit leg rode the plan and its warm cache engaged: after each
    # measure's first (cold) fit every refit takes the short warm rung
    assert s["fit_batches"] >= 1, s
    assert s["fit_warm_lanes"] > 0, s
    assert s["fit_cold_lanes"] > 0, s
    stats_path = os.environ.get("REPRO_BENCH_STATS_JSON")
    if "--stats-json" in sys.argv[1:]:
        at = sys.argv.index("--stats-json")
        if at + 1 >= len(sys.argv):
            raise SystemExit("--stats-json needs a path argument")
        stats_path = sys.argv[at + 1]
    if stats_path:
        with open(stats_path, "w") as f:
            json.dump({**s, "elapsed_s": dt, "tenants": 4,
                       "max_iters": max_iters,
                       "cold_plan_compile_misses":
                           cold_svc.stats["plan_compile_misses"]},
                      f, indent=2)
    C.emit("search_service_smoke", dt * 1e6 / (4 * max_iters), "ok")


def _fused_kernel_numbers() -> None:
    """The fused posterior+EI bucket kernel vs the vmapped-XLA chain it
    replaces (one launch vs posterior launch + eager EI), plus static
    roofline numbers from the fused launch's compiled HLO."""
    import jax.numpy as jnp

    from repro.core.acquisition import expected_improvement
    from repro.core.gp import _batched_posterior
    from repro.kernels.fused_posterior.ops import _fused_launch
    from repro.launch.hlo_stats import analyze
    from repro.launch.mesh import MESH_HARDWARE

    m, n, q, d = 16, 64, 512, 7
    rng = np.random.default_rng(0)
    ls = jnp.asarray(rng.normal(0.0, 0.1, (m, d)), jnp.float32)
    sf = jnp.asarray(rng.normal(0.0, 0.1, (m,)), jnp.float32)
    x = jnp.asarray(rng.random((m, n, d)), jnp.float32)
    mask = jnp.ones((m, n), jnp.float32)
    chol = jnp.asarray(np.broadcast_to(np.eye(n, dtype=np.float32) * 1.1,
                                       (m, n, n)))
    alpha = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    xq = jnp.asarray(rng.random((m, q, d)), jnp.float32)
    best = jnp.zeros((m,), jnp.float32)
    args = (ls, sf, x, mask, chol, alpha, xq, best)

    def vmapped():
        mu, var = _batched_posterior(ls, sf, x, mask, chol, alpha, xq)
        return expected_improvement(mu, var, 0.0)

    _fused_launch(*args, impl="xla")[2].block_until_ready()
    vmapped().block_until_ready()
    reps = 20
    t0 = time.time()
    for _ in range(reps):
        _fused_launch(*args, impl="xla")[2].block_until_ready()
    fused_s = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        vmapped().block_until_ready()
    vmap_s = (time.time() - t0) / reps
    C.emit("fused_posterior_launch", fused_s * 1e6, f"m{m}n{n}q{q}")
    C.emit("fused_posterior_vs_vmapped_speedup", 0.0,
           f"{vmap_s / fused_s:.2f}")

    h = analyze(_fused_launch.lower(*args, impl="xla").compile().as_text())
    compute_s = h["dot_flops"] / MESH_HARDWARE["peak_flops_bf16"]
    memory_s = h["dot_bytes"] / MESH_HARDWARE["hbm_bw"]
    intensity = h["dot_flops"] / max(h["dot_bytes"], 1.0)
    dominant = "compute" if compute_s >= memory_s else "memory"
    C.emit("fused_posterior_roofline_intensity", intensity,
           f"dominant={dominant}")


def _fused_ehvi_numbers() -> None:
    """The fused draw+EHVI bucket kernel vs the two-launch chain it
    replaces (eager draw combine -> vmapped box launch, with the raw-
    scale draw tensor round-tripping through HBM between them), plus
    static roofline numbers. ``hlo_stats.analyze`` counts dot flops
    only and the EHVI reduction is dot-free, so the analytic
    elementwise min/max/clip/product work is added on top — the honest
    number for a kernel whose arithmetic never touches the MXU."""
    import jax
    import jax.numpy as jnp

    from repro.core.acquisition import _ehvi_box_launch
    from repro.core.plan import _draw_launch
    from repro.kernels.fused_ehvi.ops import _fused_ehvi_launch
    from repro.launch.hlo_stats import analyze
    from repro.launch.mesh import MESH_HARDWARE

    l, d, s, q, k = 8, 2, 64, 512, 64
    rng = np.random.default_rng(0)
    corners = np.sort(rng.random((l, k, d)).astype(np.float32), axis=1)
    los = jnp.asarray(corners)
    his = jnp.asarray(np.concatenate(
        [corners[:, 1:], np.full((l, 1, d), np.inf, np.float32)], axis=1))
    refs = jnp.ones((l, d), jnp.float32) * 2.0
    mu = jnp.asarray(rng.normal(size=(l, d, q)), jnp.float32)
    var = jnp.asarray(rng.uniform(0.1, 1.0, (l, d, q)), jnp.float32)
    y_mean = jnp.zeros((l, d), jnp.float32)
    y_std = jnp.ones((l, d), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(0), l * d)
    draw = jax.vmap(lambda kk: jax.random.normal(kk, (s, q)))

    def fused():
        eps = draw(keys).reshape(l, d, s, q)
        return _fused_ehvi_launch(los, his, refs, mu, var, y_mean,
                                  y_std, eps, impl="xla")

    def vmapped():
        ps = _draw_launch(keys, mu.reshape(l * d, q), var.reshape(l * d, q),
                          jnp.ones((l * d,)), jnp.zeros((l * d,)),
                          n_mc=s).reshape(l, d, s, q)
        return _ehvi_box_launch(los, his, refs, ps)

    fused().block_until_ready()
    vmapped().block_until_ready()
    reps = 20
    t0 = time.time()
    for _ in range(reps):
        fused().block_until_ready()
    fused_s = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        vmapped().block_until_ready()
    vmap_s = (time.time() - t0) / reps
    C.emit("fused_ehvi_launch", fused_s * 1e6, f"l{l}s{s}q{q}k{k}")
    C.emit("fused_ehvi_vs_vmapped_speedup", 0.0,
           f"{vmap_s / fused_s:.2f}")

    eps = draw(keys).reshape(l, d, s, q)
    h = analyze(_fused_ehvi_launch.lower(
        los, his, refs, mu, var, y_mean, y_std, eps,
        impl="xla").compile().as_text())
    # ~6 elementwise ops per (dim, box, sample, candidate) cell: min,
    # max, sub, clip, product-accumulate, sum-accumulate
    ew_flops = 6.0 * l * d * s * q * k
    # HBM floor: eps in, boxes in, acquisition out (f32)
    ew_bytes = 4.0 * (l * d * s * q + 2 * l * k * d + l * q)
    flops = h["dot_flops"] + ew_flops
    bytes_ = max(h["dot_bytes"], ew_bytes)
    compute_s = flops / MESH_HARDWARE["peak_flops_bf16"]
    memory_s = bytes_ / MESH_HARDWARE["hbm_bw"]
    dominant = "compute" if compute_s >= memory_s else "memory"
    C.emit("fused_ehvi_roofline_intensity", flops / bytes_,
           f"dominant={dominant}")


def _fused_fit_numbers() -> None:
    """The fused fit kernel (masked Matern-5/2 NLML + analytic grad +
    Adam + factorisation in ONE launch) vs the two-launch vmapped chain
    it replaces (autodiff ``_fit_batched`` then ``_batched_chol_alpha``,
    with the hyperparameters round-tripping through HBM between them),
    plus the warm-vs-cold rung wall split — the kernel-level view of
    what the warm-start cache buys per fit round."""
    import jax.numpy as jnp

    from repro.core.gp import _batched_chol_alpha, _fit_batched
    from repro.kernels.fused_fit.ops import _fused_fit_launch

    m, n, d = 16, 32, 7
    cold_steps, warm_steps, noise = 120, 16, 0.1
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random((m, n, d)), jnp.float32)
    yr = np.sin(np.asarray(x).sum(axis=2)) \
        + 0.1 * rng.normal(size=(m, n)).astype(np.float32)
    y = jnp.asarray((yr - yr.mean(axis=1, keepdims=True))
                    / yr.std(axis=1, keepdims=True), jnp.float32)
    mask = jnp.ones((m, n), jnp.float32)
    zls = jnp.zeros((m, d), jnp.float32)
    zsf = jnp.zeros((m,), jnp.float32)

    def fused(steps):
        return _fused_fit_launch(x, y, mask, zls, zsf, steps=steps,
                                 noise=noise, impl="xla")

    def vmapped():
        fitted = _fit_batched(x, y, mask, steps=cold_steps, noise=noise)
        chol, alpha = _batched_chol_alpha(fitted["ls"], fitted["sf"],
                                          x, y, mask, noise)
        return fitted["ls"], fitted["sf"], chol, alpha

    ls_f, sf_f, _, _ = fused(cold_steps)
    ls_v, sf_v, _, _ = vmapped()
    # parity guard: the analytic gradient IS the autodiff gradient
    np.testing.assert_allclose(np.asarray(ls_f), np.asarray(ls_v),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(sf_f), np.asarray(sf_v),
                               atol=1e-3)
    fused(warm_steps)[3].block_until_ready()
    reps = 10
    t0 = time.time()
    for _ in range(reps):
        fused(cold_steps)[3].block_until_ready()
    cold_s = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        fused(warm_steps)[3].block_until_ready()
    warm_s = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        vmapped()[3].block_until_ready()
    vmap_s = (time.time() - t0) / reps
    C.emit("fused_fit_launch", cold_s * 1e6,
           f"m{m}n{n}steps{cold_steps}")
    C.emit("fused_fit_vs_vmapped_speedup", 0.0,
           f"{vmap_s / cold_s:.2f}")
    C.emit("fused_fit_warm_rung", warm_s * 1e6,
           f"steps{warm_steps}")
    C.emit("fused_fit_warm_vs_cold_speedup", 0.0,
           f"{cold_s / warm_s:.2f}")


def steady_state() -> None:
    """Compile-once serving (the ISSUE-6 acceptance scenario): per-step
    latency of a churning mixed SO + 2-objective + 3-objective cohort
    served COLD (every launch shape compiles inline as it first
    appears) vs after ``SearchService.precompile`` has warmed the
    enumerated bucket vocabulary — where ``plan_compile_misses`` must
    stay exactly 0 — plus the fused posterior kernel comparison and
    its roofline numbers."""
    import dataclasses as dc

    from repro.core.plan import CohortLimits

    emu = C.emulator()
    sp_full = C.space()
    # a trimmed candidate space keeps the EHVI bucket vocabulary (the
    # dominant share of the precompile) proportionate to a benchmark
    sp = dc.replace(sp_full, name="scout-mini",
                    configs=sp_full.configs[:8])
    wid = emu.workload_ids()[6]
    cons = [Constraint("runtime", emu.runtime_target(wid, 50))]
    cfg = BOConfig(n_init=2, max_iters=5, rgpe_samples=32)

    def fresh_repo() -> Repository:
        repo = Repository()
        rng = np.random.default_rng(7)
        for u in range(2):
            for ci in rng.choice(len(sp), 6, replace=False):
                repo.add_run(emu.make_record(f"anon-{u}", wid,
                                             sp.configs[ci], rng))
        return repo

    def submit(svc: SearchService, i: int) -> None:
        runner = C.profile_fn(wid, 100 + i)
        if i % 3 == 0:
            svc.submit(SearchRequest(
                sp, runner, Objective("cost"), cons, method="karasu",
                bo_config=cfg, seed=100 + i))
        elif i % 3 == 1:
            svc.submit(SearchRequest(
                sp, runner, None, cons, method="karasu", bo_config=cfg,
                seed=100 + i,
                objectives=[Objective("cost"), Objective("energy")],
                n_mc=8))
        else:
            svc.submit(SearchRequest(
                sp, runner, None, (), method="karasu", bo_config=cfg,
                seed=100 + i,
                objectives=[Objective("cost"), Objective("energy"),
                            Objective("runtime")], n_mc=8))

    def run_steps(svc: SearchService, n_steps: int):
        submitted = 0
        times = []
        for _ in range(n_steps):
            while len(svc.active) + len(svc.queue) < 3:
                submit(svc, submitted)
                submitted += 1
            t0 = time.time()
            svc.step()
            times.append(time.time() - t0)
        return times

    steps = {"ci": 40, "full": 200}.get(C.SCALE, 40)

    from repro.core.plan import PlanExecutor

    # both services dispatch the fused EHVI launch, so the cold/warm
    # contrast isolates precompile (and the zero-miss assertion covers
    # the fused vocabulary)
    cold = SearchService(fresh_repo(), slots=3,
                         plan_executor=PlanExecutor(fused_ehvi=True))
    cold_times = run_steps(cold, steps)

    warm = SearchService(fresh_repo(), slots=3,
                         plan_executor=PlanExecutor(fused_ehvi=True))
    # lane bound: 8 target lanes (the cohort's measures) + 8 RGPE jobs
    # x up to 3 support bases fused into the same posterior buckets
    limits = CohortLimits(d=sp.all_encoded().shape[1], q_grid=len(sp),
                          max_obs=8, max_lanes=32, n_samples=(32,),
                          n_mc=(8,), n_objectives=(2, 3),
                          max_ehvi_boxes=256)
    t0 = time.time()
    pre = warm.precompile(limits)
    pre_s = time.time() - t0
    warm_times = run_steps(warm, steps)
    assert warm.stats["plan_compile_misses"] == 0, warm.stats
    # the churning cohort's refits must actually ride the warm rung
    assert warm.stats["fit_warm_lanes"] > 0, warm.stats

    C.emit("search_service_steady_cold_step",
           float(np.mean(cold_times)) * 1e6, f"{steps}steps")
    C.emit("search_service_steady_warm_step",
           float(np.mean(warm_times)) * 1e6, f"{steps}steps")
    C.emit("search_service_precompile", pre_s * 1e6,
           f"{pre['buckets']}buckets_{pre['compiles']}compiles")
    C.emit("search_service_steady_misses", 0.0,
           str(warm.stats["plan_compile_misses"]))
    # the fit round's wall per service step, annotated with how the
    # cohort's fit lanes split between the warm refine and cold rungs
    C.emit("search_service_steady_fit_wall",
           warm.stats["fit_wall_s"] * 1e6 / steps,
           f"warm{warm.stats['fit_warm_lanes']}"
           f"_cold{warm.stats['fit_cold_lanes']}")
    _fused_kernel_numbers()
    _fused_ehvi_numbers()
    _fused_fit_numbers()


def mesh_scaling() -> None:
    """``--mesh N`` acceptance mode: one large karasu cohort served
    twice per executor — cold (compiling) then warm — on the
    single-device path and again with every bucket's lane axis sharded
    over the N-device data mesh. Emits warm per-step wall times for
    both plus the measured scaling ratio; the warm sharded pass must
    hold ``plan_compile_misses == 0`` (the sharded jit twins are part
    of the compile-once vocabulary). The ratio is MEASURED, never
    asserted: ``--xla_force_host_platform_device_count`` devices share
    the machine's physical cores, so near-linear scaling appears only
    on hosts that actually have N cores to back the mesh."""
    from repro.core.plan import PlanExecutor

    n_tenants = int(os.environ.get("REPRO_BENCH_MESH_TENANTS", "64"))
    # n_init < max_iters so every tenant runs real BO iterations (init
    # profiling alone must not satisfy max_iters and finish the session
    # before the plan layer ever executes)
    cfg = BOConfig(n_init=2, max_iters=6)
    sp, tenants, repo, targets = _setup(n_tenants)

    def run_cohort(mesh):
        svc = SearchService(
            _fresh_repo(repo), slots=n_tenants, mesh=mesh,
            plan_executor=PlanExecutor(fused_ehvi=True, mesh=mesh))
        for t, wid in enumerate(tenants):
            svc.submit(SearchRequest(
                sp, C.profile_fn(wid, t), Objective("cost"),
                [Constraint("runtime", targets[wid])], method="karasu",
                bo_config=cfg, seed=t))
        steps = 0
        t0 = time.time()
        while svc.active or svc.queue:
            svc.step()
            steps += 1
        return svc, (time.time() - t0) / max(1, steps), steps

    run_cohort(None)                                     # cold: compiles
    base_svc, base_step, base_steps = run_cohort(None)   # warm, timed
    assert base_svc.stats["plan_compile_misses"] == 0, base_svc.stats
    C.emit("search_service_mesh1_step", base_step * 1e6,
           f"{n_tenants}tenants_{base_steps}steps")

    mesh = _mesh()
    if mesh is None:          # --mesh 1: single-device numbers only
        return
    run_cohort(mesh)                                     # cold: compiles
    sh_svc, sh_step, sh_steps = run_cohort(mesh)         # warm, timed
    assert sh_svc.stats["plan_compile_misses"] == 0, sh_svc.stats
    C.emit(f"search_service_mesh{MESH_N}_step", sh_step * 1e6,
           f"{n_tenants}tenants_{sh_steps}steps")
    C.emit("search_service_mesh_scaling", 0.0,
           f"{base_step / sh_step:.2f}x_over_{MESH_N}dev")
    C.emit("search_service_mesh_misses", 0.0,
           str(sh_svc.stats["plan_compile_misses"]))
    # per-leg dispatch wall split (satellite of the wall counters): how
    # much of the warm step the fit leg still claims on each path
    for tag, svc in (("mesh1", base_svc), (f"mesh{MESH_N}", sh_svc)):
        s = svc.stats
        C.emit(f"search_service_{tag}_fit_wall", s["fit_wall_s"] * 1e6,
               f"plan_wall={s['plan_wall_s']:.3f}s")


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        smoke()
        return
    if "--steady-state" in sys.argv[1:] or \
            os.environ.get("REPRO_BENCH_STEADY_STATE") == "1":
        steady_state()
        return
    if "--mesh" in sys.argv[1:]:
        mesh_scaling()
        return
    if "--moo" in sys.argv[1:] or \
            os.environ.get("REPRO_BENCH_MOO") == "1":
        moo_mixed()
        return
    if "--slow-profilers" in sys.argv[1:] or \
            os.environ.get("REPRO_BENCH_SLOW_PROFILERS") == "1":
        slow_profilers()
        return
    scale = C.SCALE
    n_tenants = N_TENANTS.get(scale, 8)
    max_iters = MAX_ITERS.get(scale, 10)
    sp, tenants, repo, targets = _setup(n_tenants)
    iters_total = n_tenants * max_iters

    # untimed warmup (2 tenants, 5 iters) so both paths measure
    # steady-state execution rather than first-call jit compilation
    _loop(sp, tenants[:2], _fresh_repo(repo), targets, 5)
    _service(sp, tenants[:2], _fresh_repo(repo), targets, 5)

    loop_s = _loop(sp, tenants, _fresh_repo(repo), targets, max_iters)
    svc_s = _service(sp, tenants, _fresh_repo(repo), targets, max_iters)

    C.emit("search_service_loop", loop_s * 1e6 / iters_total,
           f"{n_tenants}tenants")
    C.emit("search_service_batched", svc_s * 1e6 / iters_total,
           f"{n_tenants}tenants")
    C.emit("search_service_speedup", 0.0, f"{loop_s / svc_s:.2f}")


if __name__ == "__main__":
    main()
