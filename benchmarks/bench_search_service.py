"""Multi-tenant SearchService vs looped single-tenant run_search.

The ROADMAP's serving scenario: N users each run a Karasu search against
one shared repository. The baseline loops ``run_search`` per tenant
(each search refits every target and support GP in Python loops); the
service batches all tenants' target fits into one vmapped Cholesky per
step and shares one incremental support-model store.

Emits (CSV, benchmarks/run.py format):
  search_service_loop     — looped baseline, us per tenant-iteration
  search_service_batched  — SearchService,   us per tenant-iteration
  search_service_speedup  — derived = loop_wall / service_wall
                            (acceptance: >= 2.0 at 8 tenants on CPU)

Scale: REPRO_BENCH_SCALE=ci (8 tenants x 10 iters) | full (16 x 20).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (BOConfig, Constraint, Objective, Repository,
                        run_search)
from repro.serve.search_service import SearchRequest, SearchService

from . import common as C

N_TENANTS = {"ci": 8, "mid": 8, "full": 16}
MAX_ITERS = {"ci": 10, "mid": 12, "full": 20}


def _setup(n_tenants: int):
    emu = C.emulator()
    sp = C.space()
    wids = emu.workload_ids()
    tenants = [wids[i % len(wids)] for i in range(n_tenants)]
    # shared repository: uniformly profiled collaborator runs of the
    # tenants' workloads (case-D-like, 12 runs each)
    repo = C.random_profiled_repo(sorted(set(tenants)), 12, seed=7)
    targets = {w: emu.runtime_target(w, 50) for w in set(tenants)}
    return sp, tenants, repo, targets


def _fresh_repo(repo: Repository) -> Repository:
    # both paths mutate nothing, but rebuild anyway so neither inherits
    # the other's version counters
    out = Repository()
    for z, rs in repo.all_runs().items():
        out.add_runs(rs)
    return out


def _loop(sp, tenants, repo, targets, max_iters: int) -> float:
    t0 = time.time()
    for t, wid in enumerate(tenants):
        run_search(sp, C.profile_fn(wid, t), Objective("cost"),
                   [Constraint("runtime", targets[wid])], method="karasu",
                   repository=repo,
                   bo_config=BOConfig(max_iters=max_iters), seed=t)
    return time.time() - t0


def _service(sp, tenants, repo, targets, max_iters: int) -> float:
    t0 = time.time()
    svc = SearchService(repo, slots=len(tenants))
    for t, wid in enumerate(tenants):
        svc.submit(SearchRequest(sp, C.profile_fn(wid, t),
                                 Objective("cost"),
                                 [Constraint("runtime", targets[wid])],
                                 method="karasu",
                                 bo_config=BOConfig(max_iters=max_iters),
                                 seed=t))
    done = svc.run()
    assert len(done) == len(tenants)
    return time.time() - t0


def main() -> None:
    scale = C.SCALE
    n_tenants = N_TENANTS.get(scale, 8)
    max_iters = MAX_ITERS.get(scale, 10)
    sp, tenants, repo, targets = _setup(n_tenants)
    iters_total = n_tenants * max_iters

    # untimed warmup (2 tenants, 5 iters) so both paths measure
    # steady-state execution rather than first-call jit compilation
    _loop(sp, tenants[:2], _fresh_repo(repo), targets, 5)
    _service(sp, tenants[:2], _fresh_repo(repo), targets, 5)

    loop_s = _loop(sp, tenants, _fresh_repo(repo), targets, max_iters)
    svc_s = _service(sp, tenants, _fresh_repo(repo), targets, max_iters)

    C.emit("search_service_loop", loop_s * 1e6 / iters_total,
           f"{n_tenants}tenants")
    C.emit("search_service_batched", svc_s * 1e6 / iters_total,
           f"{n_tenants}tenants")
    C.emit("search_service_speedup", 0.0, f"{loop_s / svc_s:.2f}")


if __name__ == "__main__":
    main()
