"""Fig 5 + Fig 6: collaborative applicability across data-availability
cases A-D, with Algorithm-1 selection and 3 support models; Fig 6 adds
the early-stopping variant and heterogeneous data amounts (hatched bars).
"""
from __future__ import annotations

import numpy as np

from repro.core import BOConfig, Constraint, Objective, run_search

from . import common as C

CASES = ["A", "B", "C", "D"]


def run(early_stop: bool, heterogeneous: bool):
    sc = C.scale()
    out = {c: {"final": [], "time": [], "cost": [], "timeout": []}
           for c in CASES + ["naive"]}
    timer = C.Timer()
    rng = np.random.default_rng(5)
    for wid in C.bench_workloads():
        pool = C.build_same_workload_pool(wid, 4, iters=sc.max_iters)
        for pct in sc.percentiles[:1] if early_stop else sc.percentiles:
            rt = C.emulator().runtime_target(wid, pct)
            opt = C.emulator().optimal_cost(wid, rt)
            for rep in range(max(1, sc.reps // 2)):
                seed = rep * 31 + pct

                def record(tag, res):
                    timer.calls += len(res.observations)
                    final = res.best_index_per_iter[-1]
                    o = out[tag]
                    o["final"].append(
                        C.noise_free_cost(
                            wid, res.observations[final].config) / opt
                        if final >= 0 else np.nan)
                    rts = res.measures_array("runtime")
                    o["time"].append(float(rts.sum()))
                    o["cost"].append(float(
                        res.measures_array("cost").sum()))
                    o["timeout"].append(float(np.mean(rts > rt)))

                res = run_search(
                    C.space(), C.profile_fn(wid, seed), Objective("cost"),
                    [Constraint("runtime", rt)], method="naive",
                    bo_config=BOConfig(max_iters=sc.max_iters,
                                       early_stop=early_stop), seed=seed)
                record("naive", res)
                for case in CASES:
                    repo = C.case_repo(wid, case, pool=pool,
                                       seed=seed + ord(case))
                    if heterogeneous:
                        counts = {z: int(rng.integers(3, 13))
                                  for z in repo.workloads()}
                        repo = repo.truncated(counts)
                    res = run_search(
                        C.space(), C.profile_fn(wid, seed),
                        Objective("cost"), [Constraint("runtime", rt)],
                        method="karasu", repository=repo,
                        bo_config=BOConfig(max_iters=sc.max_iters,
                                           early_stop=early_stop,
                                           n_init=1, n_support=3),
                        seed=seed)
                    record(case, res)
    return out, timer


def main():
    out, timer = run(early_stop=False, heterogeneous=False)
    for tag, st in out.items():
        C.emit(f"fig5_case{tag}_final_ratio", timer.us_per_call(),
               f"{np.nanmean(st['final']):.3f}")

    out_es, timer_es = run(early_stop=True, heterogeneous=False)
    for tag, st in out_es.items():
        C.emit(f"fig6_case{tag}_final_ratio", timer_es.us_per_call(),
               f"{np.nanmean(st['final']):.3f}")
        C.emit(f"fig6_case{tag}_search_time_s", timer_es.us_per_call(),
               f"{np.mean(st['time']):.1f}")
        C.emit(f"fig6_case{tag}_timeout_frac", timer_es.us_per_call(),
               f"{np.mean(st['timeout']):.3f}")

    out_h, timer_h = run(early_stop=True, heterogeneous=True)
    for tag, st in out_h.items():
        if tag == "naive":
            continue
        C.emit(f"fig6_hatched_case{tag}_final_ratio", timer_h.us_per_call(),
               f"{np.nanmean(st['final']):.3f}")


if __name__ == "__main__":
    main()
