"""Fig 3 (general performance boost) + Fig 4 (early stopping).

Scenario: support models from the SAME workload, different runtime
targets/initialisations (paper: near-optimal case). Compares NaiveBO,
AugmentedBO, NaiveBO+Karasu with 1 and 3 support models.

Paper claims checked (reported as `derived` values):
  - fig3: % of cases within 25% of optimal cost by profiling run 2
          (paper: 88.4-90.2% Karasu vs 33.0% NaiveBO)
  - fig3: % of cases at the optimum by run 5 (paper: 21.4-26.3% vs 5.8%)
  - fig4: with the CherryPick stopping rule — search time, search cost,
          final cost ratio, timeout fraction
"""
from __future__ import annotations

import numpy as np

from repro.core import BOConfig, Constraint, Objective, run_search

from . import common as C


def _experiments():
    sc = C.scale()
    for wid in C.bench_workloads():
        pool = C.build_same_workload_pool(wid, 4, iters=sc.max_iters)
        for pct in sc.percentiles:
            rt = C.emulator().runtime_target(wid, pct)
            opt = C.emulator().optimal_cost(wid, rt)
            for rep in range(sc.reps):
                yield wid, pool, pct, rt, opt, rep


def run(early_stop: bool = False):
    sc = C.scale()
    methods = ["naive", "augmented", "karasu1", "karasu3"]
    traj: dict = {m: [] for m in methods}
    stats: dict = {m: {"time": [], "cost": [], "final": [], "timeout": [],
                       "runs": []} for m in methods}
    timer = C.Timer()

    for wid, pool, pct, rt, opt, rep in _experiments():
        for m in methods:
            seed = rep * 17 + pct
            kwargs = {}
            if m.startswith("karasu"):
                nm = int(m[-1])
                which = list(np.random.default_rng(seed).choice(
                    len(pool), nm, replace=False))
                kwargs = {"repository": C.repo_from_pool(pool, which),
                          "method": "karasu"}
            else:
                kwargs = {"method": m}
            # Karasu needs only ONE initial run (support models carry the
            # prior; fig. 3 diverges from run 2), baselines use 3 (§IV-B)
            n_init = 1 if m.startswith("karasu") else 3
            res = run_search(
                C.space(), C.profile_fn(wid, seed), Objective("cost"),
                [Constraint("runtime", rt)],
                bo_config=BOConfig(max_iters=sc.max_iters,
                                   early_stop=early_stop, n_init=n_init,
                                   n_support=3), seed=seed, **kwargs)
            timer.calls += len(res.observations)
            traj[m].append(C.regret_trajectory(res, wid, opt))
            st = stats[m]
            rts = res.measures_array("runtime")
            st["time"].append(float(rts.sum()))
            st["cost"].append(float(res.measures_array("cost").sum()))
            st["timeout"].append(float(np.mean(rts > rt)))
            st["runs"].append(len(res.observations))
            final = res.best_index_per_iter[-1]
            st["final"].append(
                C.noise_free_cost(wid, res.observations[final].config) / opt
                if final >= 0 else np.nan)
    return traj, stats, timer


def main():
    traj, stats, timer = run(early_stop=False)
    for m, t in traj.items():
        arr = np.array([r + [r[-1]] * (C.scale().max_iters - len(r))
                        for r in t])
        within25_at2 = float(np.nanmean(arr[:, 1] <= 1.25))
        at_opt_5 = float(np.nanmean(arr[:, min(4, arr.shape[1] - 1)]
                                    <= 1.02))
        C.emit(f"fig3_{m}_within25_run2", timer.us_per_call(),
               f"{within25_at2:.3f}")
        C.emit(f"fig3_{m}_atopt_run5", timer.us_per_call(),
               f"{at_opt_5:.3f}")
        C.emit(f"fig3_{m}_final_ratio", timer.us_per_call(),
               f"{np.nanmean(arr[:, -1]):.3f}")

    traj_es, stats_es, timer_es = run(early_stop=True)
    for m, st in stats_es.items():
        C.emit(f"fig4_{m}_search_time_s", timer_es.us_per_call(),
               f"{np.mean(st['time']):.1f}")
        C.emit(f"fig4_{m}_search_cost", timer_es.us_per_call(),
               f"{np.mean(st['cost']):.4f}")
        C.emit(f"fig4_{m}_final_ratio", timer_es.us_per_call(),
               f"{np.nanmean(st['final']):.3f}")
        C.emit(f"fig4_{m}_timeout_frac", timer_es.us_per_call(),
               f"{np.mean(st['timeout']):.3f}")
        C.emit(f"fig4_{m}_n_runs", timer_es.us_per_call(),
               f"{np.mean(st['runs']):.1f}")


if __name__ == "__main__":
    main()
