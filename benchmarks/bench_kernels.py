"""Kernel microbenchmarks (XLA-path wall time on CPU; the Pallas kernels
target TPU and are correctness-validated in interpret mode by tests/)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention
from repro.kernels.matern import matern52
from repro.kernels.pairwise_pearson import pairwise_pearson
from repro.kernels.ranking_loss import ranking_loss
from repro.kernels.ssm_scan import ssm_scan

from . import common as C


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def main():
    key = jax.random.PRNGKey(0)

    # repository-scale similarity: 1k x 10k metric vectors
    a = jax.random.normal(key, (1000, 18))
    b = jax.random.normal(key, (10000, 18))
    f = jax.jit(lambda x, y: pairwise_pearson(x, y, impl="xla"))
    C.emit("kernel_pairwise_pearson_1kx10k", _time(f, a, b),
           "xla;pallas validated in tests")

    # RGPE weighting: 4096 samples x 20 observations
    p = jax.random.normal(key, (4096, 20))
    y = jax.random.normal(key, (20,))
    f = jax.jit(lambda x, z: ranking_loss(x, z, impl="xla"))
    C.emit("kernel_ranking_loss_4096x20", _time(f, p, y),
           "xla;pallas validated in tests")

    # GP kernel matrix: 2048 x 2048, d=7
    xa = jax.random.normal(key, (2048, 7))
    f = jax.jit(lambda x: matern52(x, x, impl="xla"))
    C.emit("kernel_matern52_2048sq", _time(f, xa),
           "xla;pallas validated in tests")

    # flash attention: 1x1024x8x64, GQA 8:2
    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.bfloat16)
    kv = jax.random.normal(key, (1, 1024, 2, 64), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                impl="xla"))
    C.emit("kernel_flash_attention_1k", _time(f, q, kv, kv),
           "xla;pallas validated in tests")

    # ssm scan: 1x2048x8x64, n=64
    x = jax.random.normal(key, (1, 2048, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 2048, 8)))
    decay = jnp.exp(-dt)
    B = jax.random.normal(key, (1, 2048, 64))
    Cc = jax.random.normal(key, (1, 2048, 64))
    f = jax.jit(lambda *a: ssm_scan(*a, impl="xla")[0])
    C.emit("kernel_ssm_scan_2k", _time(f, x, dt, decay, B, Cc),
           "xla;pallas validated in tests")

    # grouped GEMM (MoE experts): 8192 slots x 8 local experts, d=512
    from repro.kernels.grouped_gemm import grouped_gemm
    m, kk, nn, g = 8192, 512, 512, 8
    lhs = jax.random.normal(key, (m, kk), jnp.bfloat16)
    rhs = jax.random.normal(key, (g, kk, nn), jnp.bfloat16)
    sizes = jnp.full((g,), m // g, jnp.int32)
    f_bmm = jax.jit(lambda l, r, s: grouped_gemm(l, r, s, impl="xla"))
    f_rag = jax.jit(lambda l, r, s: grouped_gemm(l, r, s, impl="ragged"))
    t_bmm = _time(f_bmm, lhs, rhs, sizes)
    t_rag = _time(f_rag, lhs, rhs, sizes)
    C.emit("kernel_grouped_gemm_8kx8e_padded_bmm", t_bmm,
           f"vs ragged_dot {t_rag:.0f}us ({t_rag / t_bmm:.1f}x);"
           "pallas validated in tests")


if __name__ == "__main__":
    main()
