"""Fig 7 (dataset cost/energy structure), Fig 8 (SOO vs MOO example),
Fig 9 (MOO with/without Karasu)."""
from __future__ import annotations

import numpy as np

from repro.core import (BOConfig, Constraint, Objective, run_search,
                        run_search_moo)
from repro.core.acquisition import _hv_2d, pareto_front

from . import common as C


def fig7():
    """Cost/energy correlation across the emulated dataset (paper: the
    two objectives correlate, tighter near their minima)."""
    timer = C.Timer()
    cost, energy, mtypes = [], [], []
    for wid in C.emulator().workload_ids():
        for cfg, m in C.emulator().full_table(wid):
            timer.calls += 1
            cost.append(m["cost"])
            energy.append(m["energy"])
            mtypes.append(cfg["machine_type"])
    cost, energy = np.array(cost), np.array(energy)
    C.emit("fig7_n_runs", timer.us_per_call(), len(cost))
    C.emit("fig7_corr_cost_energy", timer.us_per_call(),
           f"{np.corrcoef(cost, energy)[0, 1]:.3f}")
    # correlation in the cheapest quartile (paper: correlated near minimum)
    q = cost <= np.quantile(cost, 0.25)
    C.emit("fig7_corr_cheapest_quartile", timer.us_per_call(),
           f"{np.corrcoef(cost[q], energy[q])[0, 1]:.3f}")


def fig8_fig9():
    sc = C.scale()
    wid = C.bench_workloads()[0]
    pct = sc.percentiles[-1]
    rt = C.emulator().runtime_target(wid, pct)
    objs = [Objective("cost"), Objective("energy")]
    cons = [Constraint("runtime", rt)]
    timer = C.Timer()

    pool = C.build_same_workload_pool(wid, 3, iters=sc.max_iters)
    repo = C.repo_from_pool(pool, [0, 1, 2])

    # fig8: SOO vs MOO (both with Karasu, as in the paper's example)
    soo = run_search(C.space(), C.profile_fn(wid, 0), objs[0], cons,
                     method="karasu", repository=repo,
                     bo_config=BOConfig(max_iters=sc.max_iters), seed=0)
    moo = run_search_moo(C.space(), C.profile_fn(wid, 0), objs, cons,
                         method="karasu", repository=repo,
                         bo_config=BOConfig(max_iters=sc.max_iters),
                         seed=0, n_mc=32)
    timer.calls += len(soo.observations) + len(moo.observations)

    def best_pair(res):
        feas = [o for o in res.observations
                if o.measures["runtime"] <= rt] or res.observations
        bc = min(o.measures["cost"] for o in feas)
        be = min(o.measures["energy"] for o in feas)
        return bc, be

    sc_, se_ = best_pair(soo)
    mc_, me_ = best_pair(moo)
    C.emit("fig8_soo_best_cost", timer.us_per_call(), f"{sc_:.4f}")
    C.emit("fig8_soo_best_energy", timer.us_per_call(), f"{se_:.5f}")
    C.emit("fig8_moo_best_cost", timer.us_per_call(), f"{mc_:.4f}")
    C.emit("fig8_moo_best_energy", timer.us_per_call(), f"{me_:.5f}")

    # fig9: MOO naive vs karasu — final dominated hypervolume (higher
    # is better) + cost of best feasible config
    hv = {}
    for method, kwargs in [("naive", {}),
                           ("karasu", {"repository": repo})]:
        hvs, costs = [], []
        for rep in range(max(1, sc.reps // 2)):
            res = run_search_moo(C.space(), C.profile_fn(wid, rep), objs,
                                 cons, method=method,
                                 bo_config=BOConfig(max_iters=sc.max_iters),
                                 seed=rep, n_mc=32, **kwargs)
            timer.calls += len(res.observations)
            pts = np.array([[o.measures["cost"], o.measures["energy"]]
                            for o in res.observations
                            if o.measures["runtime"] <= rt])
            if len(pts) == 0:
                continue
            ref = np.array([2.0, 0.3])  # fixed ref above all observations
            hvs.append(_hv_2d(pareto_front(pts), ref))
            costs.append(pts[:, 0].min())
        hv[method] = (np.mean(hvs) if hvs else np.nan,
                      np.mean(costs) if costs else np.nan)
        C.emit(f"fig9_moo_{method}_hypervolume", timer.us_per_call(),
               f"{hv[method][0]:.5f}")
        C.emit(f"fig9_moo_{method}_best_cost", timer.us_per_call(),
               f"{hv[method][1]:.4f}")


def main():
    fig7()
    fig8_fig9()


if __name__ == "__main__":
    main()
