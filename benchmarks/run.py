"""Benchmark entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Scale with REPRO_BENCH_SCALE
(ci | full; see common.py).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import bench_analysis, bench_kernels, bench_roofline
    from . import bench_search_service
    from . import bench_fig3_fig4, bench_fig5_fig6, bench_fig7_fig8_fig9

    print("name,us_per_call,derived")
    t0 = time.time()
    for mod in [bench_roofline, bench_analysis, bench_kernels,
                bench_search_service,
                bench_fig7_fig8_fig9, bench_fig3_fig4, bench_fig5_fig6]:
        try:
            mod.main()
        except Exception as e:  # keep the suite going; record the failure
            print(f"{mod.__name__},0.0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
            print(f"{mod.__name__.split('.')[-1]}_error,0.0,"
                  f"{type(e).__name__}")
    print(f"total_bench_wall_s,0.0,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
