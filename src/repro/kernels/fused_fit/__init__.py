from .ops import fused_fit, fused_fit_launch_fn
from .ref import fused_fit_ref
from .fused import fused_fit_pallas

__all__ = ["fused_fit", "fused_fit_ref", "fused_fit_pallas",
           "fused_fit_launch_fn"]
