"""Fused Pallas TPU kernel: the whole warm-startable GP fit for one
lane bucket in a single launch.

Grid is ``(m,)`` — one program per lane (one model). Each program runs
the entire optimizer block in-core: per Adam step it rebuilds the
masked Matern-5/2 kernel matrix on the MXU, factorises it with a
column-wise Cholesky (Crout) loop, inverts the factor by forward
substitution against the identity, forms the analytic NLML gradient
from ``G = K^{-1} - alpha alpha^T`` (the same closed forms as
``ref.py``), and applies the Adam update — then one final pass emits
``(chol, alpha)`` at the fitted hyperparameters. Nothing round-trips
to HBM between steps: hyperparameters, moments, and the (n, n)
work matrices all live in VMEM/VREGs as loop carries.

Column updates are expressed as full-array masked selects
(``where(col_ids == j, new_col, L)``) rather than dynamic lane-axis
slices — O(n^2) VPU work per column, but layout-trivial on TPU and
bitwise-identical under the interpreter, which is what the ref /
interpret parity tests pin.

Compiled mode zero-pads n and d up to multiples of 128 for clean
(8, 128) f32 tiling. Both pads are exact by the same contract the
caller's own padding relies on: padded observations carry zero mask
and a unit diagonal (parameter-independent constants), padded feature
dims carry zero coordinates — so gradients through either are exactly
zero. Interpret mode skips the padding and runs the identical program
on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

SQRT5 = 5.0 ** 0.5
JITTER = 1e-6
R2_SHIFT = 1e-12


def _kernel_parts(ls, sf, x, mask1, noise, row_ids, col_ids):
    """K, K_data, dK/dr2 and scaled inputs at params (ls, sf) — the
    in-core twin of ``ref._masked_kernel_parts``."""
    xt = x * jnp.exp(-ls)                                  # (n, d)
    sq = jnp.sum(xt * xt, axis=1)                          # (n,)
    dots = jax.lax.dot_general(
        xt, xt, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (n, n)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * dots, 0.0)
    r = jnp.sqrt(d2 + R2_SHIFT)
    e = jnp.exp(-SQRT5 * r)
    mval = (1.0 + SQRT5 * r + 5.0 / 3.0 * d2) * e
    mo = mask1[:, None] * mask1[None, :]
    sfe = jnp.exp(sf)
    kd = sfe * mval * mo
    diag = jnp.where(row_ids == col_ids,
                     (noise + JITTER + 1.0 - mask1)[:, None], 0.0)
    k = kd + diag
    # Diagonal excluded explicitly: see ref._masked_kernel_parts.
    p = jnp.where((d2 > 0.0) & (row_ids != col_ids),
                  -(5.0 / 6.0) * sfe * (1.0 + SQRT5 * d2 / r) * e * mo,
                  0.0)
    return k, kd, p, xt


def _chol_inv(k, n, row_ids, col_ids):
    """Cholesky factor L of ``k`` and V = L^{-1}, by column-wise Crout
    then forward substitution against the identity."""
    iota_col = row_ids[:, :1]                              # (n, 1) row index

    def chol_col(j, l):
        oh = (iota_col == j).astype(jnp.float32)           # (n, 1) one-hot j
        krow = jnp.sum(k * oh, axis=0)                     # row j == col j (sym)
        lrow = jnp.sum(l * oh, axis=0)                     # (n,) row j of L
        s = jax.lax.dot_general(
            l, lrow[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]      # L @ lrow
        c = krow - s
        cj = jnp.sum(c * oh[:, 0])
        col = c / jnp.sqrt(cj)
        col = jnp.where(iota_col[:, 0] >= j, col, 0.0)
        return jnp.where(col_ids == j, col[:, None], l)

    l = jax.lax.fori_loop(0, n, chol_col, jnp.zeros_like(k))

    def sub_row(j, v):
        oh = (iota_col == j).astype(jnp.float32)
        lrow = jnp.sum(l * oh, axis=0)                     # (n,)
        ljj = jnp.sum(lrow * oh[:, 0])
        acc = jax.lax.dot_general(
            lrow[None, :], v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]         # lrow @ V
        vrow = (oh[:, 0] - acc) / ljj
        return jnp.where(row_ids == j, vrow[None, :], v)

    v = jax.lax.fori_loop(0, n, sub_row, jnp.zeros_like(k))
    return l, v


def _fused_fit_kernel(x_ref, y_ref, mask_ref, ils_ref, isf_ref,
                      ls_out, sf_out, chol_out, alpha_out,
                      *, steps: int, noise: float, lr: float, n: int):
    x = x_ref[0]                                           # (n, d)
    y = y_ref[0]                                           # (n,)
    mask1 = mask_ref[0]                                    # (n,)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)

    def alpha_of(v):
        w = jax.lax.dot_general(
            v, y[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]      # V y
        return jax.lax.dot_general(
            w[None, :], v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]         # V^T (V y)

    def adam_step(i, carry):
        ls, sf, m_ls, m_sf, v_ls, v_sf = carry
        k, kd, p, xt = _kernel_parts(ls, sf, x, mask1, noise,
                                     row_ids, col_ids)
        _, v = _chol_inv(k, n, row_ids, col_ids)
        alpha = alpha_of(v)
        kinv = jax.lax.dot_general(
            v, v, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # V^T V
        g = kinv - alpha[:, None] * alpha[None, :]
        g_sf = 0.5 * jnp.sum(g * kd)
        a = g * p
        ra = jnp.sum(a, axis=1)
        term1 = jnp.sum(xt * xt * ra[:, None], axis=0)
        b = jax.lax.dot_general(
            a, xt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # A @ Xt
        term2 = jnp.sum(xt * b, axis=0)
        g_ls = 2.0 * term2 - 2.0 * term1
        m_ls = 0.9 * m_ls + 0.1 * g_ls
        m_sf = 0.9 * m_sf + 0.1 * g_sf
        v_ls = 0.999 * v_ls + 0.001 * g_ls * g_ls
        v_sf = 0.999 * v_sf + 0.001 * g_sf * g_sf
        t = jnp.float32(i) + 1.0
        c1 = 1.0 - 0.9 ** t
        c2 = 1.0 - 0.999 ** t
        ls = ls - lr * (m_ls / c1) / (jnp.sqrt(v_ls / c2) + 1e-8)
        sf = sf - lr * (m_sf / c1) / (jnp.sqrt(v_sf / c2) + 1e-8)
        ls = jnp.clip(ls, -3.0, 3.0)
        sf = jnp.clip(sf, -3.0, 3.0)
        return ls, sf, m_ls, m_sf, v_ls, v_sf

    d = x.shape[-1]
    init = (ils_ref[0], isf_ref[0, 0],
            jnp.zeros((d,), jnp.float32), jnp.float32(0.0),
            jnp.zeros((d,), jnp.float32), jnp.float32(0.0))
    ls, sf, _, _, _, _ = jax.lax.fori_loop(0, steps, adam_step, init)

    k, _, _, _ = _kernel_parts(ls, sf, x, mask1, noise, row_ids, col_ids)
    l, v = _chol_inv(k, n, row_ids, col_ids)
    ls_out[0] = ls
    sf_out[0, 0] = sf
    chol_out[0] = l
    alpha_out[0] = alpha_of(v)


def fused_fit_pallas(x, y, mask, init_ls, init_sf, *,
                     steps: int = 120, noise: float = 0.1,
                     lr: float = 0.05, interpret: bool = False):
    """x: (m, n, d), y/mask: (m, n), init_ls: (m, d), init_sf: (m,)
    -> (log_ls, log_sf, chol, alpha) — one Pallas launch per bucket."""
    m, n, d = x.shape
    pn = 0 if interpret else (-n) % 128
    pd = 0 if interpret else (-d) % 128
    if pn or pd:
        x = jnp.pad(x, ((0, 0), (0, pn), (0, pd)))
        y = jnp.pad(y, ((0, 0), (0, pn)))
        mask = jnp.pad(mask, ((0, 0), (0, pn)))
        init_ls = jnp.pad(init_ls, ((0, 0), (0, pd)))
    np_, dp = n + pn, d + pd
    kern = functools.partial(_fused_fit_kernel, steps=steps, noise=noise,
                             lr=lr, n=np_)
    ls, sf, chol, alpha = pl.pallas_call(
        kern,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, np_, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, np_, np_), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, dp), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, np_, np_), jnp.float32),
            jax.ShapeDtypeStruct((m, np_), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), y.astype(jnp.float32),
      mask.astype(jnp.float32), init_ls.astype(jnp.float32),
      jnp.asarray(init_sf, jnp.float32).reshape(m, 1))
    return (ls[:, :d], sf[:, 0], chol[:, :n, :n], alpha[:, :n])
