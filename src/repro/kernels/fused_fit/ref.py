"""Pure-jnp oracle: warm-startable fused GP fit.

One launch fits a whole lane bucket: masked Matern-5/2 kernel +
Cholesky + analytic NLML gradient + Adam update, iterated ``steps``
times from a caller-supplied initial point, then a final factorisation
emitting ``(chol, alpha)`` at the fitted hyperparameters. This is the
XLA twin of the Pallas kernel in ``fused.py`` — same formulas, same
update rule — and the numeric bridge to the legacy autodiff fit
(``core.gp._fit_batched`` + ``_batched_chol_alpha``), which remains
the parity baseline: with a zero initial point the two agree to
<= 1e-4 on every hyperparameter and factor.

The gradient is analytic rather than autodiff so the Pallas kernel can
compute the identical expressions in-core. With

  G = K^{-1} - alpha alpha^T,   K = sf * M(r) * mask_outer + diag

the NLML derivatives are

  d/dlog_sf   = 0.5 * sum(G * K_data)
  d/dlog_ls_k = 2 * diag(Xt^T A Xt)_k - 2 * (Xt^2)^T rowsum(A)
                with A = G * dK/dr2 and Xt = x / ls,

where ``dK/dr2 = -(5/6) sf (1 + sqrt5 * d2/r) exp(-sqrt5 r)`` is the
Matern-5/2 radial derivative (finite at r=0). Masked rows/cols carry
zero mask factors, so padded observations and fully-masked lanes have
exactly zero gradient — params stay at their initial point and the
factorisation degenerates to the unit-diagonal padding contract.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

SQRT5 = 5.0 ** 0.5
JITTER = 1e-6            # matches core.gp.JITTER
R2_SHIFT = 1e-12         # matches kernels.matern sqrt shift


def _masked_kernel_parts(log_ls, log_sf, x, mask, noise):
    """K (full, pad-stabilised), K_data (parameter-dependent block),
    and the radial-derivative matrix P = dK/dr2 — shared between the
    gradient and the final factorisation."""
    n_max = x.shape[0]
    ls = jnp.exp(log_ls)
    sf = jnp.exp(log_sf)
    xt = x / ls
    sq = jnp.sum(xt * xt, axis=1)
    d2 = jnp.maximum(sq[:, None] + sq[None, :]
                     - 2.0 * (xt @ xt.T), 0.0)
    r = jnp.sqrt(d2 + R2_SHIFT)
    e = jnp.exp(-SQRT5 * r)
    mval = (1.0 + SQRT5 * r + 5.0 / 3.0 * d2) * e
    mo = mask[:, None] * mask[None, :]
    kd = sf * mval * mo
    k = kd + (noise + JITTER) * jnp.eye(n_max) + jnp.diag(1.0 - mask)
    # dM/dd2. The (d2 > 0) factor mirrors autodiff through the clamp;
    # the diagonal is excluded EXPLICITLY rather than relying on
    # d2_ii == 0: its analytic contribution is zero (Delta_ii = 0) but
    # when d2_ii rounds to a tiny positive the term1/term2 cancellation
    # in the gradient leaves roundoff residue that Adam's sign
    # normalisation amplifies to O(lr) — an n_obs=1 lane would drift
    # off its warm-start instead of staying put.
    off = ~jnp.eye(n_max, dtype=bool)
    p = jnp.where((d2 > 0.0) & off,
                  -(5.0 / 6.0) * sf * (1.0 + SQRT5 * d2 / r) * e * mo,
                  0.0)
    return k, kd, p, xt


def _masked_nlml_grads(log_ls, log_sf, x, y, mask, noise):
    """Analytic d NLML / d (log_ls, log_sf) over the valid block."""
    n_max = x.shape[0]
    k, kd, p, xt = _masked_kernel_parts(log_ls, log_sf, x, mask, noise)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    kinv = jax.scipy.linalg.cho_solve((chol, True), jnp.eye(n_max))
    g = kinv - alpha[:, None] * alpha[None, :]
    g_sf = 0.5 * jnp.sum(g * kd)
    a = g * p
    ra = jnp.sum(a, axis=1)
    term1 = jnp.sum(xt * xt * ra[:, None], axis=0)       # (Xt^2)^T rA
    term2 = jnp.sum(xt * (a @ xt), axis=0)               # diag(Xt^T A Xt)
    g_ls = 2.0 * term2 - 2.0 * term1
    return g_ls, g_sf


def _fused_fit_one(x, y, mask, init_ls, init_sf, *, steps, noise, lr):
    """One lane: Adam on the analytic NLML gradient from ``init``,
    then the final masked factorisation. The update rule is kept in
    exact lockstep with ``core.gp._adam_nlml``."""
    def body(carry, i):
        ls, sf, m_ls, m_sf, v_ls, v_sf = carry
        g_ls, g_sf = _masked_nlml_grads(ls, sf, x, y, mask, noise)
        m_ls = 0.9 * m_ls + 0.1 * g_ls
        m_sf = 0.9 * m_sf + 0.1 * g_sf
        v_ls = 0.999 * v_ls + 0.001 * g_ls * g_ls
        v_sf = 0.999 * v_sf + 0.001 * g_sf * g_sf
        t = i.astype(jnp.float32) + 1.0
        c1 = 1.0 - 0.9 ** t
        c2 = 1.0 - 0.999 ** t
        ls = ls - lr * (m_ls / c1) / (jnp.sqrt(v_ls / c2) + 1e-8)
        sf = sf - lr * (m_sf / c1) / (jnp.sqrt(v_sf / c2) + 1e-8)
        ls = jnp.clip(ls, -3.0, 3.0)
        sf = jnp.clip(sf, -3.0, 3.0)
        return (ls, sf, m_ls, m_sf, v_ls, v_sf), None

    d = x.shape[-1]
    init = (init_ls, init_sf,
            jnp.zeros((d,)), jnp.zeros(()), jnp.zeros((d,)), jnp.zeros(()))
    (ls, sf, _, _, _, _), _ = jax.lax.scan(body, init, jnp.arange(steps))
    k, _, _, _ = _masked_kernel_parts(ls, sf, x, mask, noise)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return ls, sf, chol, alpha


def fused_fit_ref(x, y, mask, init_ls, init_sf, *,
                  steps: int = 120, noise: float = 0.1, lr: float = 0.05):
    """x: (m, n, d), y/mask: (m, n), init_ls: (m, d), init_sf: (m,)
    -> (log_ls (m, d), log_sf (m,), chol (m, n, n), alpha (m, n))."""
    one = partial(_fused_fit_one, steps=steps, noise=noise, lr=lr)
    return jax.vmap(one)(x, y, mask, init_ls, init_sf)
