"""Dispatcher for the fused warm-startable fit bucket kernel.

``fused_fit`` takes the padded lanes of one fit bucket (the exact
arrays ``core.plan.PlanExecutor._exec_fit`` packs) plus per-lane
warm-start hyperparameters and returns ``(log_ls, log_sf, chol,
alpha)`` — everything a ``BatchedGP`` needs beyond the inputs
themselves, in ONE launch per optimizer block instead of the legacy
fit + chol_alpha pair. ``impl`` follows the package convention:
``"xla"`` is the analytic vmapped reference, ``"pallas"`` /
``"pallas_interpret"`` the fused kernel, and ``"auto"`` routes through
``kernels.routing.resolve_impl`` on the per-step kernel-matrix cell
count (callers under a mesh pass their per-shard view via
``resolve_impl(..., shards=)`` before binding ``impl`` statically).

``steps`` is a STATIC schedule length — the warm (short refine) and
cold (full) rungs are distinct entries of the closed launch
vocabulary, enumerated and precompiled like every other bucket shape.

``_fused_fit_launch`` is the jitted entry the plan executor calls. On
TPU it uses ``_fused_fit_launch_donated`` instead: only the per-lane
warm-start rows (``init_ls``, ``init_sf``) are donated — they are
rebuilt from the host-side warm cache every step — while x/y/mask must
stay live because the executor hands them to the ``BatchedGP`` the
posterior legs query afterwards.
"""
from __future__ import annotations

from functools import partial

import jax

from ..routing import resolve_impl
from .fused import fused_fit_pallas
from .ref import fused_fit_ref


def fused_fit(x, y, mask, init_ls, init_sf, *, steps: int = 120,
              noise: float = 0.1, lr: float = 0.05, impl: str = "xla"):
    if impl == "auto":
        impl = resolve_impl(
            impl, cells=x.shape[0] * x.shape[1] * x.shape[1] * steps)
    if impl == "xla":
        return fused_fit_ref(x, y, mask, init_ls, init_sf,
                             steps=steps, noise=noise, lr=lr)
    if impl == "pallas":
        return fused_fit_pallas(x, y, mask, init_ls, init_sf,
                                steps=steps, noise=noise, lr=lr,
                                interpret=False)
    if impl == "pallas_interpret":
        return fused_fit_pallas(x, y, mask, init_ls, init_sf,
                                steps=steps, noise=noise, lr=lr,
                                interpret=True)
    raise ValueError(f"unknown fused_fit impl {impl!r}")


@partial(jax.jit, static_argnames=("steps", "noise", "lr", "impl"))
def _fused_fit_launch(x, y, mask, init_ls, init_sf, steps: int = 120,
                      noise: float = 0.1, lr: float = 0.05,
                      impl: str = "xla"):
    return fused_fit(x, y, mask, init_ls, init_sf, steps=steps,
                     noise=noise, lr=lr, impl=impl)


_fused_fit_launch_donated = jax.jit(
    lambda x, y, mask, init_ls, init_sf, steps=120, noise=0.1, lr=0.05, \
           impl="xla":
        fused_fit(x, y, mask, init_ls, init_sf, steps=steps, noise=noise,
                  lr=lr, impl=impl),
    static_argnames=("steps", "noise", "lr", "impl"),
    donate_argnums=(3, 4))


def fused_fit_launch_fn(donate=None):
    """The jitted launch entry: donating when ``donate`` (default: on a
    TPU backend), plain otherwise. The plan executor pins the choice at
    construction so precompile and serving warm one jit cache."""
    if donate is None:
        donate = jax.default_backend() == "tpu"
    return _fused_fit_launch_donated if donate else _fused_fit_launch


def ref_twin():
    """The pure-XLA reference body standing in for the Pallas kernel in
    jaxpr-level analysis (``repro.analysis``): same signature, same
    masked-dataflow contract, traceable without a Pallas lowering."""
    return fused_fit_ref
