"""Pallas TPU flash attention (forward) with GQA / SWA / logit softcap.

TPU-native design notes (vs. the CUDA flash-attention algorithm):
  - The kv axis is the innermost *sequential* grid dimension; VMEM scratch
    (acc, m, l) persists across kv steps of one (batch, head, q-block), so
    the online-softmax state lives in VMEM instead of registers/SMEM.
  - Block shapes are (block_q, head_dim) / (block_kv, head_dim); head_dim
    is MXU-lane aligned by the caller (multiple of 128 preferred);
    block_q/block_kv default to 128/512 so the working set
    (bq*hd + 2*bkv*hd + bq*bkv fp32 words) stays well under 16 MiB VMEM.
  - Fully-masked (causal/window) kv blocks are no-ops under @pl.when; a
    production index_map would skip them outright — the roofline model
    applies the causal 1/2 factor analytically instead.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    qpos_ref, kvpos_ref, kvmask_ref,  # position/validity inputs
    q_ref, k_ref, v_ref,              # blocked tensor inputs
    o_ref,                            # blocked output
    acc_ref, m_ref, l_ref,            # VMEM scratch
    *,
    causal: bool,
    window: int,
    softcap: float,
    scale: float,
    n_kv_blocks: int,
    block_q: int,
    block_kv: int,
):
    kv_idx = pl.program_id(3)

    @pl.when(kv_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (block_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_kv, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bkv)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qp = qpos_ref[0].astype(jnp.int32)   # (block_q,)
    kp = kvpos_ref[0].astype(jnp.int32)  # (block_kv,)
    mask = jnp.ones((block_q, block_kv), dtype=bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window:
        mask &= qp[:, None] - kp[None, :] < window
    mask &= kvmask_ref[0][None, :] != 0
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_idx == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (batch, q_len, n_q_heads, head_dim)
    k: jnp.ndarray,  # (batch, kv_len, n_kv_heads, head_dim)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_positions: Optional[jnp.ndarray] = None,
    kv_positions: Optional[jnp.ndarray] = None,
    kv_mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, qlen, nq, hd = q.shape
    _, kvlen, nkv, _ = k.shape
    assert nq % nkv == 0, (nq, nkv)
    group = nq // nkv
    scale = scale if scale is not None else hd ** -0.5

    block_q = min(block_q, qlen)
    block_kv = min(block_kv, kvlen)

    if q_positions is None:
        q_positions = jnp.broadcast_to(
            jnp.arange(kvlen - qlen, kvlen), (b, qlen))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(kvlen), (b, kvlen))
    if kv_mask is None:
        kv_mask = jnp.ones((b, kvlen), dtype=jnp.int32)
    else:
        kv_mask = kv_mask.astype(jnp.int32)

    # pad sequence axes to block multiples; padded kv is masked out and
    # padded q rows are dropped on return.
    q_pad = (-qlen) % block_q
    kv_pad = (-kvlen) % block_kv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, q_pad)),
                              constant_values=-(10 ** 9))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, kv_pad)))
        kv_mask = jnp.pad(kv_mask, ((0, 0), (0, kv_pad)))

    qlen_p, kvlen_p = qlen + q_pad, kvlen + kv_pad
    n_q_blocks = qlen_p // block_q
    n_kv_blocks = kvlen_p // block_kv

    # layout: (batch, heads, seq, hd) so the blocked dims are the minor two
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, nq, n_q_blocks, n_kv_blocks)
    kernel = functools.partial(
        _flash_kernel,
        causal=causal, window=window, softcap=softcap, scale=scale,
        n_kv_blocks=n_kv_blocks, block_q=block_q, block_kv=block_kv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda bi, hi, qi, ki: (bi, qi)),
            pl.BlockSpec((1, block_kv), lambda bi, hi, qi, ki: (bi, ki)),
            pl.BlockSpec((1, block_kv), lambda bi, hi, qi, ki: (bi, ki)),
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, qlen_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, kv_mask, qt, kt, vt)

    out = out.transpose(0, 2, 1, 3)  # (b, qlen_p, nq, hd)
    return out[:, :qlen]
