"""Dispatching wrapper for flash attention.

impl:
  - ``xla``              chunked online-softmax in pure jnp (lax.scan over
                         kv blocks). Never materialises the (q, kv) score
                         matrix for long sequences, so dry-run HLO byte
                         counts stay realistic. Default on CPU and for
                         dry-run lowering.
  - ``pallas``           the TPU Pallas kernel (compiled).
  - ``pallas_interpret`` the Pallas kernel in interpret mode (CPU tests).
  - ``naive``            the ref oracle (tests / tiny shapes only).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .ref import attention_ref
from .flash_attention import flash_attention_pallas

_CHUNK = 1024
_DECODE_Q = 8  # q_len at or below this uses the decode path


def _decode_attention(q, k, v, *, causal, window, softcap, q_positions,
                      kv_positions, kv_mask, scale):
    """Small-q attention that materialises (b, h, q, S) scores.

    GQA is handled by head grouping (no KV repeat), and every reduction
    over the KV axis is a plain max/sum — so when the KV cache is sharded
    over a mesh axis (flash-decoding style KV parallelism for long_500k),
    GSPMD lowers the softmax into partial reductions + small all-reduces
    instead of gathering the cache.
    """
    b, qlen, nq, hd = q.shape
    _, kvlen, nkv, _ = k.shape
    group = nq // nkv
    scale = scale if scale is not None else hd ** -0.5
    if q_positions is None:
        q_positions = jnp.broadcast_to(
            jnp.arange(kvlen - qlen, kvlen), (b, qlen))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(kvlen), (b, kvlen))
    if kv_mask is None:
        kv_mask = jnp.ones((b, kvlen), dtype=bool)

    qg = q.reshape(b, qlen, nkv, group, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = q_positions[:, None, None, :, None]
    kp = kv_positions[:, None, None, None, :]
    mask = kv_mask[:, None, None, None, :]
    if causal:
        mask = mask & (qp >= kp)
    if window:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    l = jnp.where(l == 0.0, 1.0, l)
    o = o / l.transpose(0, 3, 1, 2, 4)
    return o.reshape(b, qlen, nq, hd).astype(q.dtype)


def _xla_flash(q, k, v, *, causal, window, softcap, q_positions,
               kv_positions, kv_mask, scale):
    """Chunked online-softmax attention; one kv chunk per scan step."""
    b, qlen, nq, hd = q.shape
    _, kvlen, nkv, _ = k.shape
    group = nq // nkv
    scale = scale if scale is not None else hd ** -0.5

    if q_positions is None:
        q_positions = jnp.broadcast_to(
            jnp.arange(kvlen - qlen, kvlen), (b, qlen))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(kvlen), (b, kvlen))
    if kv_mask is None:
        kv_mask = jnp.ones((b, kvlen), dtype=bool)

    chunk = min(_CHUNK, kvlen)
    pad = (-kvlen) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)))
        kv_mask = jnp.pad(kv_mask, ((0, 0), (0, pad)))
    n_chunks = (kvlen + pad) // chunk

    # (chunks, b, chunk, ...) scan layout
    ks = k.reshape(b, n_chunks, chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_chunks, chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    kps = kv_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    kms = kv_mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    qf = q.astype(jnp.float32)

    def step(carry, chunk_in):
        acc, m, l = carry
        kc, vc, kpc, kmc = chunk_in
        kh = jnp.repeat(kc, group, axis=2).astype(jnp.float32)
        vh = jnp.repeat(vc, group, axis=2).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kh) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qp = q_positions[:, None, :, None]
        kp = kpc[:, None, None, :]
        mask = kmc[:, None, None, :]
        if causal:
            mask &= qp >= kp
        if window:
            mask &= qp - kp < window
        s = jnp.where(mask, s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1)                      # (b,h,q)
        m_new = jnp.maximum(m, m_cur)
        # guard fully-masked chunks (m_new may still be -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vh)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, nq, qlen, hd), jnp.float32)
    m0 = jnp.full((b, nq, qlen), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, nq, qlen), jnp.float32)
    # remat the chunk body: backward recomputes the (b,h,q,chunk) score
    # transients from the carried (acc, m, l) instead of saving them
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(step, prevent_cse=False),
        (acc0, m0, l0), (ks, vs, kps, kms))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).transpose(0, 2, 1, 3)  # (b,q,h,hd)
    return out.astype(q.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_positions: Optional[jnp.ndarray] = None,
    kv_positions: Optional[jnp.ndarray] = None,
    kv_mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
    impl: str = "xla",
) -> jnp.ndarray:
    kwargs = dict(causal=causal, window=window, softcap=softcap,
                  q_positions=q_positions, kv_positions=kv_positions,
                  kv_mask=kv_mask, scale=scale)
    if impl == "naive":
        return attention_ref(q, k, v, **kwargs)
    if impl == "decode":
        return _decode_attention(q, k, v, **kwargs)
    if impl == "xla":
        if q.shape[1] <= _DECODE_Q < k.shape[1]:
            return _decode_attention(q, k, v, **kwargs)
        return _xla_flash(q, k, v, **kwargs)
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, interpret=False, **kwargs)
    if impl == "pallas_interpret":
        return flash_attention_pallas(q, k, v, interpret=True, **kwargs)
    raise ValueError(f"unknown attention impl {impl!r}")
