"""Pure-jnp oracle for flash attention.

Deliberately the most naive correct implementation: materialises the full
(q_len, kv_len) score matrix in fp32. Used as the allclose reference for
both the Pallas kernel and the chunked XLA path in ``ops.py``.

Supports: GQA (n_q_heads a multiple of n_kv_heads), causal masking,
sliding-window masking, attention-logit softcapping, explicit positions
(for decode with a KV cache) and a KV validity mask.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def attention_ref(
    q: jnp.ndarray,  # (batch, q_len, n_q_heads, head_dim)
    k: jnp.ndarray,  # (batch, kv_len, n_kv_heads, head_dim)
    v: jnp.ndarray,  # (batch, kv_len, n_kv_heads, head_dim)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_positions: Optional[jnp.ndarray] = None,  # (batch, q_len)
    kv_positions: Optional[jnp.ndarray] = None,  # (batch, kv_len)
    kv_mask: Optional[jnp.ndarray] = None,  # (batch, kv_len) bool
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, qlen, nq, hd = q.shape
    _, kvlen, nkv, _ = k.shape
    assert nq % nkv == 0, (nq, nkv)
    group = nq // nkv
    scale = scale if scale is not None else hd ** -0.5

    if q_positions is None:
        # default: q occupies the last qlen positions of the kv axis
        q_positions = jnp.broadcast_to(
            jnp.arange(kvlen - qlen, kvlen), (b, qlen))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(kvlen), (b, kvlen))

    kh = jnp.repeat(k, group, axis=2)  # (b, kv, nq, hd)
    vh = jnp.repeat(v, group, axis=2)

    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kh.astype(jnp.float32)
    ) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap

    qp = q_positions[:, None, :, None]  # (b,1,q,1)
    kp = kv_positions[:, None, None, :]  # (b,1,1,kv)
    mask = jnp.ones_like(logits, dtype=bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    if kv_mask is not None:
        mask &= kv_mask[:, None, None, :]
    logits = jnp.where(mask, logits, -jnp.inf)

    probs = jax.nn.softmax(logits, axis=-1)
    # rows that are fully masked produce NaN -> zero them
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vh.astype(jnp.float32))
    return out.astype(q.dtype)
