from .ops import grouped_gemm
from .ref import grouped_gemm_ref
from .grouped_gemm import grouped_gemm_pallas

__all__ = ["grouped_gemm", "grouped_gemm_ref", "grouped_gemm_pallas"]
