"""Dispatcher for the grouped GEMM.

impl:
  - ``xla``    group-aligned padded batched matmul: rows are permuted
               into block_m-aligned group runs, expert weights gathered
               per block, one bmm — flops = dropless ideal + padding.
               This replaces lax.ragged_dot (whose XLA-CPU decomposition
               multiplies the whole buffer by every expert: measured
               E_local x inflation).
  - ``ragged`` jax.lax.ragged_dot (kept for comparison).
  - ``pallas`` / ``pallas_interpret`` the megablox-style TPU kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .grouped_gemm import grouped_gemm_pallas, pad_layout
from .ref import grouped_gemm_ref


def _auto_block_m(m: int, g: int, cap: int = 128) -> int:
    """Largest power of two <= m/(2g), clamped to [8, cap]: bounds the
    group-alignment padding overhead at ~25% while keeping MXU-friendly
    tiles for realistically-sized groups."""
    target = max(m // (2 * max(g, 1)), 8)
    b = 1 << (target.bit_length() - 1)
    return max(8, min(cap, b))


def _xla_padded_bmm(lhs, rhs, group_sizes, block_m: int = 0):
    m, k = lhs.shape
    g, _, n = rhs.shape
    block_m = block_m or _auto_block_m(m, g)
    dest, gob, m_pad = pad_layout(group_sizes, m, g, block_m)
    x_pad = jnp.zeros((m_pad, k), lhs.dtype).at[dest].set(lhs)
    xb = x_pad.reshape(m_pad // block_m, block_m, k)
    wb = rhs[gob]                                   # (blocks, k, n) gather
    out = jnp.einsum("bmk,bkn->bmn", xb, wb.astype(xb.dtype))
    return out.reshape(m_pad, n)[dest]


def grouped_gemm(lhs: jnp.ndarray, rhs: jnp.ndarray,
                 group_sizes: jnp.ndarray, *, impl: str = "xla"
                 ) -> jnp.ndarray:
    if impl == "xla":
        return _xla_padded_bmm(lhs, rhs, group_sizes)
    if impl == "ragged":
        return jax.lax.ragged_dot(lhs, rhs, group_sizes)
    if impl == "naive":
        return grouped_gemm_ref(lhs, rhs, group_sizes)
    if impl == "pallas":
        return grouped_gemm_pallas(lhs, rhs, group_sizes, interpret=False)
    if impl == "pallas_interpret":
        return grouped_gemm_pallas(lhs, rhs, group_sizes, interpret=True)
    raise ValueError(f"unknown grouped_gemm impl {impl!r}")
