"""Pallas TPU grouped GEMM (megablox-style) for MoE expert compute.

The wrapper pads each group's rows up to a multiple of ``block_m`` so no
m-tile spans two groups; the per-tile expert id is passed as a
scalar-prefetch operand and consumed by the rhs BlockSpec index_map —
each (m-tile, n-tile) program loads exactly ONE expert's (k, block_n)
weight tile from HBM. Compute is therefore the dropless ideal plus at
most (block_m - 1) padding rows per group — unlike XLA-CPU's ragged_dot
decomposition, which multiplies the whole buffer against every local
expert (measured 8x inflation for 8 groups; EXPERIMENTS.md).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(group_of_block_ref, x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)      # (block_m, k)
    w = w_ref[0].astype(jnp.float32)        # (k, block_n)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def pad_layout(group_sizes: jnp.ndarray, m: int, g: int, block_m: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Row permutation into the group-aligned padded buffer.

    Returns (dest_row (m,), group_of_block (m_pad//block_m,), m_pad).
    m_pad = m rounded up + one block_m of padding per group (static).
    """
    m_pad = ((m + block_m - 1) // block_m + g) * block_m
    padded_sizes = ((group_sizes + block_m - 1) // block_m) * block_m
    padded_starts = jnp.concatenate(
        [jnp.zeros(1, group_sizes.dtype), jnp.cumsum(padded_sizes)])[:-1]
    starts = jnp.concatenate(
        [jnp.zeros(1, group_sizes.dtype), jnp.cumsum(group_sizes)])[:-1]
    rows = jnp.arange(m)
    gid = jnp.searchsorted(jnp.cumsum(group_sizes), rows, side="right")
    gid = jnp.clip(gid, 0, g - 1)
    dest = padded_starts[gid] + (rows - starts[gid])
    block_starts = jnp.arange(m_pad // block_m) * block_m
    gob = jnp.searchsorted(jnp.cumsum(padded_sizes),
                           block_starts, side="right")
    gob = jnp.clip(gob, 0, g - 1).astype(jnp.int32)
    return dest, gob, m_pad


def grouped_gemm_pallas(lhs: jnp.ndarray, rhs: jnp.ndarray,
                        group_sizes: jnp.ndarray, *, block_m: int = 0,
                        block_n: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    from .ops import _auto_block_m
    m, k = lhs.shape
    g, _, n = rhs.shape
    block_m = block_m or _auto_block_m(m, g)
    block_n = min(block_n, n)
    pn = (-n) % block_n
    if pn:
        rhs = jnp.pad(rhs, ((0, 0), (0, 0), (0, pn)))
    dest, gob, m_pad = pad_layout(group_sizes, m, g, block_m)
    x_pad = jnp.zeros((m_pad, k), lhs.dtype).at[dest].set(lhs)

    grid = (m_pad // block_m, (n + pn) // block_n)
    out = pl.pallas_call(
        _gmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, k), lambda i, j, gob: (i, 0)),
                pl.BlockSpec((1, k, block_n),
                             lambda i, j, gob: (gob[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda i, j, gob: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, n + pn), lhs.dtype),
        interpret=interpret,
    )(gob, x_pad, rhs)
    return out[dest][:, :n]
