"""Pure-jnp oracle for the grouped GEMM (MoE expert matmul).

out[i] = lhs[i] @ rhs[group_of_row(i)] where rows of lhs are sorted by
group and group_sizes gives the contiguous group lengths. Equivalent to
jax.lax.ragged_dot; written as an explicit masked-dense loop so it is an
independent reference.
"""
from __future__ import annotations

import jax.numpy as jnp


def grouped_gemm_ref(lhs: jnp.ndarray, rhs: jnp.ndarray,
                     group_sizes: jnp.ndarray) -> jnp.ndarray:
    m, k = lhs.shape
    g, _, n = rhs.shape
    starts = jnp.concatenate([jnp.zeros(1, group_sizes.dtype),
                              jnp.cumsum(group_sizes)])
    rows = jnp.arange(m)
    out = jnp.zeros((m, n), jnp.promote_types(lhs.dtype, rhs.dtype))
    for gi in range(g):
        mask = (rows >= starts[gi]) & (rows < starts[gi + 1])
        contrib = (lhs * mask[:, None]).astype(jnp.float32) @ \
            rhs[gi].astype(jnp.float32)
        out = out + contrib.astype(out.dtype) * mask[:, None]
    return out
