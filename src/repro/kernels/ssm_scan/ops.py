"""Dispatching wrapper for the SSD scan.

impl:
  - ``xla``              chunked SSD in pure jnp (lax.scan over chunks,
                         quadratic within chunk). Default on CPU/dry-run.
  - ``xla_sequential``   the ref oracle (per-step scan).
  - ``pallas``           the TPU Pallas kernel.
  - ``pallas_interpret`` the Pallas kernel in interpret mode (CPU tests).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .ref import ssm_scan_ref
from .ssm_scan import ssm_scan_pallas

_CHUNK = 128


def _xla_chunked(x, dt, decay, B, C, initial_state, chunk=_CHUNK):
    b, s, h, hd = x.shape
    n = B.shape[-1]
    per_head = B.ndim == 4
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        bc_pad = ((0, 0), (0, pad), (0, 0), (0, 0)) if per_head else \
            ((0, 0), (0, pad), (0, 0))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        B = jnp.pad(B, bc_pad)
        C = jnp.pad(C, bc_pad)
    sp = s + pad
    nc = sp // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, hd)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    ld = jnp.log(jnp.maximum(decay.astype(jnp.float32), 1e-37)
                 ).reshape(b, nc, chunk, h)
    bc_shape = (b, nc, chunk, h, n) if per_head else (b, nc, chunk, n)
    Bf = B.astype(jnp.float32).reshape(bc_shape)
    Cf = C.astype(jnp.float32).reshape(bc_shape)

    if initial_state is None:
        S0 = jnp.zeros((b, h, hd, n), jnp.float32)
    else:
        S0 = initial_state.astype(jnp.float32)

    tri = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]

    def chunk_step(S, inp):
        xc, dtc, ldc, Bc, Cc = inp  # (b, chunk, ...)
        cum = jnp.cumsum(ldc, axis=1)                       # (b, t, h)
        gamma = cum[:, :, None, :] - cum[:, None, :, :]     # (b, i, j, h)
        # mask BEFORE exp: the upper triangle is exp(+large) = inf, and
        # where(tri, inf, 0) poisons gradients with inf * 0 = NaN
        m = jnp.exp(jnp.where(tri[None, :, :, None], gamma, -1e30))
        if per_head:
            scores = jnp.einsum("bihn,bjhn->bijh", Cc, Bc)
        else:
            scores = jnp.einsum("bin,bjn->bij", Cc, Bc)[..., None]
        w = scores * m * dtc[:, None, :, :]                 # (b,i,j,h)
        y_intra = jnp.einsum("bijh,bjhd->bihd", w, xc)
        pt = jnp.exp(cum)                                   # (b, t, h)
        if per_head:
            y_inter = jnp.einsum("bihn,bhdn->bihd", Cc, S) * pt[..., None]
        else:
            y_inter = jnp.einsum("bin,bhdn->bihd", Cc, S) * pt[..., None]
        y = y_intra + y_inter
        coeff = jnp.exp(cum[:, -1:, :] - cum) * dtc         # (b, t, h)
        if per_head:
            upd = jnp.einsum("bthd,bthn->bhdn", xc * coeff[..., None], Bc)
        else:
            upd = jnp.einsum("bthd,btn->bhdn", xc * coeff[..., None], Bc)
        S = S * pt[:, -1, :, None, None] + upd
        return S, y

    tp_bc = (1, 0, 2, 3, 4) if per_head else (1, 0, 2, 3)
    inps = (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
            ld.transpose(1, 0, 2, 3), Bf.transpose(*tp_bc),
            Cf.transpose(*tp_bc))
    S, ys = jax.lax.scan(chunk_step, S0, inps)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, hd)[:, :s]
    return y.astype(x.dtype), S


def ssm_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    decay: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    *,
    initial_state: Optional[jnp.ndarray] = None,
    impl: str = "xla",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "xla_sequential":
        return ssm_scan_ref(x, dt, decay, B, C, initial_state)
    if impl == "xla":
        # sequential ref is cheaper for decode (s == 1)
        if x.shape[1] == 1:
            return ssm_scan_ref(x, dt, decay, B, C, initial_state)
        return _xla_chunked(x, dt, decay, B, C, initial_state)
    if impl == "pallas":
        return ssm_scan_pallas(x, dt, decay, B, C, initial_state,
                               interpret=False)
    if impl == "pallas_interpret":
        return ssm_scan_pallas(x, dt, decay, B, C, initial_state,
                               interpret=True)
    raise ValueError(f"unknown ssm_scan impl {impl!r}")
