"""Pallas TPU kernel for the chunked Mamba2/SSD scan.

TPU-native blocking: the time axis is split into chunks of ``block_t``;
the grid is (batch, heads, n_chunks) with the chunk axis innermost and
sequential, so the running state S (head_dim x n) lives in VMEM scratch
across chunk steps — the HBM->VMEM traffic per chunk is just the chunk's
x/B/C/dt blocks. Within a chunk the computation is two MXU matmuls
(scores = C @ B^T masked by the decay segsum, y_intra = scores @ x) plus
rank-1 state updates, mirroring the SSD "quadratic-within-chunk,
recurrent-across-chunks" algorithm.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum(logdecay: jnp.ndarray) -> jnp.ndarray:
    """logdecay: (t,) -> Gamma[i, j] = sum_{u in (j, i]} logdecay[u], j<=i."""
    t = logdecay.shape[0]
    cum = jnp.cumsum(logdecay)
    diff = cum[:, None] - cum[None, :]  # (t, t): sum over (j, i]
    mask = jnp.arange(t)[:, None] >= jnp.arange(t)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_kernel(x_ref, dt_ref, ld_ref, b_ref, c_ref, s0_ref,
                y_ref, sfin_ref, state_ref, *, n_chunks: int, block_t: int,
                per_head: bool):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)        # (t, hd)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (t, 1) -> squeeze
    ld = ld_ref[0, 0].astype(jnp.float32)      # (t, 1)
    if per_head:
        B = b_ref[0, 0].astype(jnp.float32)    # (t, n)
        C = c_ref[0, 0].astype(jnp.float32)
    else:
        B = b_ref[0].astype(jnp.float32)       # (t, n)
        C = c_ref[0].astype(jnp.float32)
    dt = dt[:, 0]
    ld = ld[:, 0]

    S = state_ref[...]                          # (hd, n)

    # within-chunk quadratic term
    gamma = _segsum(ld)                         # (t, t)
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (t, t) = C_i . B_j
    m = jnp.exp(gamma)                          # masked: 0 above diagonal
    m = jnp.where(jnp.isfinite(gamma), m, 0.0)
    w = scores * m * dt[None, :]                # weight on x_j for y_i
    y_intra = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (t, hd)

    # contribution of the carried-in state
    cumld = jnp.cumsum(ld)
    pt = jnp.exp(cumld)                         # (t,)
    y_inter = jax.lax.dot_general(
        C, S, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * pt[:, None]  # (t, hd)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S' = P_T * S + sum_j (P_T / P_j) dt_j x_j B_j^T
    total = pt[-1]
    coeff = jnp.exp(cumld[-1] - cumld) * dt     # (t,)
    upd = jax.lax.dot_general(
        x * coeff[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (hd, n)
    state_ref[...] = S * total + upd

    @pl.when(ci == n_chunks - 1)
    def _fin():
        sfin_ref[0, 0] = state_ref[...].astype(sfin_ref.dtype)


def ssm_scan_pallas(
    x: jnp.ndarray,      # (b, s, h, hd)
    dt: jnp.ndarray,     # (b, s, h)
    decay: jnp.ndarray,  # (b, s, h)
    B: jnp.ndarray,      # (b, s, n) shared, or (b, s, h, n) per-head
    C: jnp.ndarray,      # same shape as B
    initial_state: Optional[jnp.ndarray] = None,
    *,
    block_t: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, hd = x.shape
    n = B.shape[-1]
    per_head = B.ndim == 4
    block_t = min(block_t, s)
    pad = (-s) % block_t
    if pad:
        bc_pad = ((0, 0), (0, pad), (0, 0), (0, 0)) if per_head else \
            ((0, 0), (0, pad), (0, 0))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        # pad decay with 1.0 (log 0) so padded steps leave state untouched
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0)),
                        constant_values=1.0)
        B = jnp.pad(B, bc_pad)
        C = jnp.pad(C, bc_pad)
    sp = s + pad
    n_chunks = sp // block_t

    if initial_state is None:
        initial_state = jnp.zeros((b, h, hd, n), jnp.float32)

    logdecay = jnp.log(jnp.maximum(decay.astype(jnp.float32), 1e-37))

    # layouts: (b, h, s, hd) for x/y; (b, h, s, 1) for dt/logdecay
    xt = x.transpose(0, 2, 1, 3)
    dtt = dt.transpose(0, 2, 1)[..., None]
    ldt = logdecay.transpose(0, 2, 1)[..., None]
    if per_head:
        Bt = B.transpose(0, 2, 1, 3)  # (b, h, s, n)
        Ct = C.transpose(0, 2, 1, 3)
        bc_spec = pl.BlockSpec((1, 1, block_t, n),
                               lambda bi, hi, ci: (bi, hi, ci, 0))
    else:
        Bt, Ct = B, C
        bc_spec = pl.BlockSpec((1, block_t, n),
                               lambda bi, hi, ci: (bi, ci, 0))

    grid = (b, h, n_chunks)
    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks,
                               block_t=block_t, per_head=per_head)
    y, sfin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_t, hd), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, block_t, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, block_t, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            bc_spec,
            bc_spec,
            pl.BlockSpec((1, 1, hd, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_t, hd), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, hd, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sp, hd), x.dtype),
            jax.ShapeDtypeStruct((b, h, hd, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, ldt, Bt, Ct, initial_state)

    y = y.transpose(0, 2, 1, 3)[:, :s]
    return y, sfin
