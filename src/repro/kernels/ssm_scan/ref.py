"""Pure-jnp oracle for the Mamba2/SSD selective state-space scan.

Sequential lax.scan over time — the obviously-correct reference.

Recurrence (per batch b, head h, with state S in R^{head_dim x n}):
    S_t = decay_t * S_{t-1} + dt_t * (x_t outer B_t)
    y_t = S_t @ C_t
B and C are shared across heads (n_groups = 1, as in Mamba2 defaults).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssm_scan_ref(
    x: jnp.ndarray,      # (b, s, h, hd)
    dt: jnp.ndarray,     # (b, s, h)
    decay: jnp.ndarray,  # (b, s, h)  = exp(dt * A), in (0, 1]
    B: jnp.ndarray,      # (b, s, n) shared across heads, or (b, s, h, n)
    C: jnp.ndarray,      # (b, s, n) or (b, s, h, n)
    initial_state: Optional[jnp.ndarray] = None,  # (b, h, hd, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, hd = x.shape
    n = B.shape[-1]
    if B.ndim == 3:  # broadcast shared B/C over heads
        B = jnp.broadcast_to(B[:, :, None, :], (b, s, h, n))
        C = jnp.broadcast_to(C[:, :, None, :], (b, s, h, n))
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = decay.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    if initial_state is None:
        S0 = jnp.zeros((b, h, hd, n), jnp.float32)
    else:
        S0 = initial_state.astype(jnp.float32)

    def step(S, inp):
        xt, dtt, at, Bt, Ct = inp
        # S: (b, h, hd, n); Bt/Ct: (b, h, n)
        upd = jnp.einsum("bhd,bhn->bhdn", xt * dtt[..., None], Bt)
        S = S * at[..., None, None] + upd
        yt = jnp.einsum("bhdn,bhn->bhd", S, Ct)
        return S, yt

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          af.transpose(1, 0, 2), Bf.transpose(1, 0, 2, 3),
          Cf.transpose(1, 0, 2, 3))
    S_final, ys = jax.lax.scan(step, S0, xs)
    y = ys.transpose(1, 0, 2, 3)  # (b, s, h, hd)
    return y.astype(x.dtype), S_final
