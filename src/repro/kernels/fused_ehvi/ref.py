"""XLA reference for the fused posterior-draw + box-EHVI bucket kernel.

Self-contained on purpose (``kernels/*`` never import ``core``): the
draw affine and the box overlap-volume reduction are restated here and
pinned by tests to ``core.plan._draw_launch`` +
``core.acquisition._ehvi_box_launch`` and the f64 ``mc_ehvi_nd``
oracle, so a drift in either copy fails loudly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BOX_CHUNK = 1024   # must match core.acquisition.EHVI_BOX_CHUNK


def _box_block(los, his, refs, ps):
    """Summed overlap volume of one block of boxes. los/his: (L, B, D);
    refs: (L, D); ps: (L, D, S, q) raw-scale draws. -> (L, S, q)."""
    vol = None
    for dim in range(los.shape[-1]):
        lo = los[:, None, None, :, dim]                # (L, 1, 1, B)
        hi = his[:, None, None, :, dim]
        ref = refs[:, dim][:, None, None, None]
        p = ps[:, dim, :, :, None]                     # (L, S, q, 1)
        w = jnp.clip(jnp.minimum(hi, ref) - jnp.maximum(lo, p), 0.0, None)
        vol = w if vol is None else vol * w
    return jnp.sum(vol, axis=-1)


def fused_ehvi_ref(los, his, refs, mu, var, y_mean, y_std, eps):
    """(L, q) EHVI rows of one padded (n_obj, S, q) bucket, draws fused.

    ``los``/``his``: (L, K, D) box decompositions of each lane's
    non-dominated region (padding boxes have lo = hi = +inf and
    contribute exactly zero volume); ``refs``: (L, D); ``mu``/``var``:
    (L, D, q) standardised posterior rows (+inf mean / zero variance at
    padded candidates, whose draws then land at +inf and gain nothing);
    ``y_mean``/``y_std``: (L, D) per-objective de-standardisation;
    ``eps``: (L, D, S, q) unit normals drawn at each lane's exact
    candidate count and zero-padded. The draw affine matches
    ``core.plan._draw_launch`` term for term — (mu + eps * sqrt(var)) *
    y_std + y_mean — so fusing the draw into the EHVI launch never
    changes a lane's stream. Past ``BOX_CHUNK`` boxes the box axis runs
    as a scan of fixed-size blocks (remainders padded with zero-volume
    boxes), bounding peak memory like the vmapped launch."""
    ps = mu[:, :, None, :] + eps * jnp.sqrt(var)[:, :, None, :]
    ps = ps * y_std[:, :, None, None] + y_mean[:, :, None, None]
    l, k, d = los.shape
    if k <= BOX_CHUNK:
        return jnp.mean(_box_block(los, his, refs, ps), axis=1)
    pad = (-k) % BOX_CHUNK
    if pad:
        los = jnp.pad(los, ((0, 0), (0, pad), (0, 0)),
                      constant_values=jnp.inf)
        his = jnp.pad(his, ((0, 0), (0, pad), (0, 0)),
                      constant_values=jnp.inf)
    nc = (k + pad) // BOX_CHUNK
    los_c = jnp.moveaxis(los.reshape(l, nc, BOX_CHUNK, d), 1, 0)
    his_c = jnp.moveaxis(his.reshape(l, nc, BOX_CHUNK, d), 1, 0)

    def body(acc, blk):
        lo_i, hi_i = blk
        return acc + _box_block(lo_i, hi_i, refs, ps), None

    init = jnp.zeros(ps.shape[:1] + ps.shape[2:], ps.dtype)   # (L, S, q)
    acc, _ = jax.lax.scan(body, init, (los_c, his_c))
    return jnp.mean(acc, axis=1)
