"""Pallas TPU kernel: fused posterior draws -> box-decomposition EHVI.

The MOO counterpart of ``kernels.fused_posterior``: the (n_obj, S, q)
EHVI bucket of the query plan previously ran as an unjitted draw
combine (one affine per lane) plus the vmapped box launch, with the
(L, D, S, q) raw-scale draw tensor round-tripping through HBM between
them. This kernel keeps one lane x one candidate block resident in
VMEM: it materialises the block's draws into scratch once, then
accumulates the overlap-volume product over fixed-size box blocks, so
peak memory is bounded by (S, bq, bk) and never by front depth.

Grid (L, q_pad // bq): each program owns one MOO lane and one block of
``bq`` candidates. The query plan's exact-padding contract does all the
masking for free: padding boxes have lo = hi = +inf (every overlap
clips to zero), padded candidates carry mu = +inf / var = 0 (their
draws land at +inf and gain nothing), padded objective slots are never
read (the dim loop is static over the real objective count), padded
lanes repeat lane 0 and are discarded by the executor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def _ehvi_kernel(los_ref, his_ref, refs_ref, mu_ref, var_ref, ym_ref,
                 ys_ref, eps_ref, out_ref, p_scr, acc_scr, *,
                 d: int, s: int, bk: int, nb: int):
    # raw-scale draws of this candidate block, all objectives, into VMEM
    # scratch: p = (mu + eps * sqrt(var)) * y_std + y_mean — the exact
    # affine of core.plan._draw_launch, so fusing the draw into the
    # kernel never changes a lane's stream
    for dim in range(d):
        mu_d = mu_ref[0, dim, :]                       # (bq,)
        sd = jnp.sqrt(var_ref[0, dim, :])
        e = eps_ref[0, dim * s:(dim + 1) * s, :]       # (S, bq)
        p_scr[dim * s:(dim + 1) * s, :] = (
            (mu_d[None, :] + e * sd[None, :]) * ys_ref[0, dim]
            + ym_ref[0, dim])
    acc_scr[...] = jnp.zeros_like(acc_scr)

    def body(b, _):
        # the wrapper sorts each lane's boxes into staircase order
        # (ascending lo[0]), so +inf padding boxes pool at the tail of
        # the axis: a block whose SMALLEST lo[0] is +inf holds only
        # zero-volume boxes and is skipped outright — deep-padded lanes
        # (the fused bucket pads every lane to the deepest front) pay
        # for their own boxes, not the bucket's
        @pl.when(jnp.min(los_ref[0, 0, pl.ds(b * bk, bk)]) < jnp.inf)
        def _accumulate():
            vol = None
            for dim in range(d):
                lo = los_ref[0, dim, pl.ds(b * bk, bk)]    # (bk,)
                hi = his_ref[0, dim, pl.ds(b * bk, bk)]
                ref = refs_ref[0, dim]
                p = p_scr[dim * s:(dim + 1) * s, :]        # (S, bq)
                w = jnp.clip(
                    jnp.minimum(hi, ref)[None, None, :]
                    - jnp.maximum(lo[None, None, :], p[:, :, None]),
                    0.0, None)                             # (S, bq, bk)
                vol = w if vol is None else vol * w
            acc_scr[...] += jnp.sum(vol, axis=-1)

        return 0

    jax.lax.fori_loop(0, nb, body, 0)
    out_ref[0, :] = jnp.sum(acc_scr[...], axis=0) * (1.0 / s)


def fused_ehvi_pallas(los, his, refs, mu, var, y_mean, y_std, eps, *,
                      block_q: int = 128, block_k: int = 128,
                      interpret: bool = False):
    """(L, q) EHVI rows; arguments exactly as ``fused_ehvi_ref``.

    ``block_q`` x ``block_k`` bound the kernel's VMEM high-water mark
    (the volume intermediate is (S, block_q, block_k) f32)."""
    l, k, d = los.shape
    s = eps.shape[2]
    q = mu.shape[2]
    bq = min(block_q, q)
    pq = (-q) % bq
    # sublane/lane alignment for the compiled TPU kernel only: the
    # objective axis pads to the f32 sublane tile, the box axis to a
    # lane-aligned block multiple; padded objective slots are never read
    # and padded boxes are +inf (zero volume) by the plan's contract
    d_pad = _round_up(d, 8) if not interpret else d
    bk = (min(block_k, _round_up(k, 128)) if not interpret
          else min(block_k, k))
    pk = (-k) % bk

    los_t = jnp.swapaxes(los, 1, 2)    # (L, D, K): box reads = lane slices
    his_t = jnp.swapaxes(his, 1, 2)
    # staircase order: each lane's boxes sorted by ascending lo[0]. The
    # box decomposition is disjoint, so any order sums to the same EHVI
    # (up to float summation order); sorting pools the +inf zero-volume
    # padding boxes at the tail, which turns them into whole blocks the
    # kernel's early-exit predicate can skip
    order = jnp.argsort(los_t[:, 0, :], axis=-1)       # (L, K)
    los_t = jnp.take_along_axis(los_t, order[:, None, :], axis=2)
    his_t = jnp.take_along_axis(his_t, order[:, None, :], axis=2)
    if pk:
        los_t = jnp.pad(los_t, ((0, 0), (0, 0), (0, pk)),
                        constant_values=jnp.inf)
        his_t = jnp.pad(his_t, ((0, 0), (0, 0), (0, pk)),
                        constant_values=jnp.inf)
    if d_pad > d:
        los_t = jnp.pad(los_t, ((0, 0), (0, d_pad - d), (0, 0)),
                        constant_values=jnp.inf)
        his_t = jnp.pad(his_t, ((0, 0), (0, d_pad - d), (0, 0)),
                        constant_values=jnp.inf)
        refs = jnp.pad(refs, ((0, 0), (0, d_pad - d)))
        mu = jnp.pad(mu, ((0, 0), (0, d_pad - d), (0, 0)))
        var = jnp.pad(var, ((0, 0), (0, d_pad - d), (0, 0)))
        y_mean = jnp.pad(y_mean, ((0, 0), (0, d_pad - d)))
        y_std = jnp.pad(y_std, ((0, 0), (0, d_pad - d)))
        eps = jnp.pad(eps, ((0, 0), (0, d_pad - d), (0, 0), (0, 0)))
    if pq:
        mu = jnp.pad(mu, ((0, 0), (0, 0), (0, pq)),
                     constant_values=jnp.inf)
        var = jnp.pad(var, ((0, 0), (0, 0), (0, pq)))
        eps = jnp.pad(eps, ((0, 0), (0, 0), (0, 0), (0, pq)))
    k_pad, q_pad = k + pk, q + pq
    eps2 = eps.reshape(l, d_pad * s, q_pad)

    out = pl.pallas_call(
        functools.partial(_ehvi_kernel, d=d, s=s, bk=bk, nb=k_pad // bk),
        grid=(l, q_pad // bq),
        in_specs=[
            pl.BlockSpec((1, d_pad, k_pad), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, d_pad, k_pad), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d_pad, bq), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, d_pad, bq), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d_pad * s, bq), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l, q_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((d_pad * s, bq), jnp.float32),  # raw-scale draws
            pltpu.VMEM((s, bq), jnp.float32),          # per-sample volume
        ],
        interpret=interpret,
    )(los_t, his_t, refs, mu, var, y_mean, y_std, eps2)
    return out[:, :q]
