from .fused import fused_ehvi_pallas
from .ops import fused_ehvi, fused_ehvi_launch_fn
from .ref import fused_ehvi_ref

__all__ = ["fused_ehvi", "fused_ehvi_ref", "fused_ehvi_pallas",
           "fused_ehvi_launch_fn"]
