"""Dispatcher for the fused posterior-draw + EHVI bucket kernel.

``fused_ehvi`` takes the padded lanes of one (n_obj, S, q) EHVI bucket
(the exact arrays ``core.plan.PlanExecutor`` assembles when constructed
with ``fused_ehvi=True``) and returns the (L, q) acquisition rows.
``impl`` follows the package convention: ``"xla"`` is the reference
chain, ``"pallas"`` / ``"pallas_interpret"`` the fused kernel, and
``"auto"`` routes through ``kernels.routing.resolve_impl`` on the
launch's work volume (lanes x samples x candidates x boxes — the EHVI
reduction's cost scales with all four, unlike the posterior kernel's
output-cell count).

``_fused_ehvi_launch`` is the jitted entry the plan executor calls;
``_fused_ehvi_launch_donated`` donates every argument — all eight are
rebuilt by the executor each step (stacked box decompositions, gathered
posterior rows, fresh draws), so nothing aliases a session-cached
buffer and XLA may reuse their HBM for the volume intermediates. Which
entry runs is pinned ONCE by the executor (``fused_ehvi_launch_fn``'s
``donate`` argument), so ``SearchService.precompile`` warms exactly the
entry serving dispatches.
"""
from __future__ import annotations

from functools import partial

import jax

from ..routing import resolve_impl
from .fused import fused_ehvi_pallas
from .ref import fused_ehvi_ref


def fused_ehvi(los, his, refs, mu, var, y_mean, y_std, eps, *,
               impl: str = "xla"):
    if impl == "auto":
        impl = resolve_impl(impl, cells=(los.shape[0] * eps.shape[2]
                                         * mu.shape[2] * los.shape[1]))
    if impl == "xla":
        return fused_ehvi_ref(los, his, refs, mu, var, y_mean, y_std, eps)
    if impl == "pallas":
        return fused_ehvi_pallas(los, his, refs, mu, var, y_mean, y_std,
                                 eps, interpret=False)
    if impl == "pallas_interpret":
        return fused_ehvi_pallas(los, his, refs, mu, var, y_mean, y_std,
                                 eps, interpret=True)
    raise ValueError(f"unknown fused_ehvi impl {impl!r}")


@partial(jax.jit, static_argnames=("impl",))
def _fused_ehvi_launch(los, his, refs, mu, var, y_mean, y_std, eps,
                       impl: str = "xla"):
    return fused_ehvi(los, his, refs, mu, var, y_mean, y_std, eps,
                      impl=impl)


_fused_ehvi_launch_donated = jax.jit(
    lambda los, his, refs, mu, var, y_mean, y_std, eps, impl="xla":
        fused_ehvi(los, his, refs, mu, var, y_mean, y_std, eps, impl=impl),
    static_argnames=("impl",),
    donate_argnums=(0, 1, 2, 3, 4, 5, 6, 7))


def fused_ehvi_launch_fn(donate=None):
    """The jitted launch entry: donating when ``donate`` (default: on a
    TPU backend), plain otherwise. Callers resolve the choice once and
    hold onto it — the plan executor pins it at construction so its
    precompile and its serving dispatch can never disagree."""
    if donate is None:
        donate = jax.default_backend() == "tpu"
    return _fused_ehvi_launch_donated if donate else _fused_ehvi_launch


def ref_twin():
    """The pure-XLA reference body standing in for the Pallas kernel in
    jaxpr-level analysis (``repro.analysis``): same signature, same
    masked-dataflow contract, traceable without a Pallas lowering."""
    return fused_ehvi_ref
