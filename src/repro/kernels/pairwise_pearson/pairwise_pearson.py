"""Pallas TPU kernel: blocked pairwise Pearson correlation matrix.

Grid (m_blocks, n_blocks); each program centres its (bm, d) / (bn, d)
tiles in VMEM, computes the cross-products with one MXU matmul and
normalises on the VPU. The metric vectors are short (18 floats in the
paper's setup) so d is padded to the 128 lane boundary with a validity
mask (padded lanes excluded from means/norms).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pearson_kernel(a_ref, b_ref, o_ref, *, d_valid: int):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    d = a.shape[1]
    mask = (jnp.arange(d) < d_valid).astype(jnp.float32)[None, :]
    inv = 1.0 / d_valid
    am = jnp.sum(a * mask, axis=1, keepdims=True) * inv
    bm = jnp.sum(b * mask, axis=1, keepdims=True) * inv
    ac = (a - am) * mask
    bc = (b - bm) * mask
    num = jax.lax.dot_general(ac, bc, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    an = jnp.sqrt(jnp.sum(ac * ac, axis=1))
    bn = jnp.sqrt(jnp.sum(bc * bc, axis=1))
    den = an[:, None] * bn[None, :]
    o_ref[...] = (num / jnp.maximum(den, 1e-12)).astype(o_ref.dtype)
