"""Dispatcher for pairwise Pearson correlation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..routing import resolve_impl
from .ref import pairwise_pearson_ref
from .pairwise_pearson import _pearson_kernel


def _pallas(a, b, *, block: int = 256, interpret: bool = False):
    m, d = a.shape
    n, _ = b.shape
    bm, bn = min(block, m), min(block, n)
    pm, pn = (-m) % bm, (-n) % bn
    pd = (-d) % 128 if not interpret else 0
    if pm or pd:
        a = jnp.pad(a, ((0, pm), (0, pd)))
    if pn or pd:
        b = jnp.pad(b, ((0, pn), (0, pd)))
    out = pl.pallas_call(
        functools.partial(_pearson_kernel, d_valid=d),
        grid=((m + pm) // bm, (n + pn) // bn),
        in_specs=[
            pl.BlockSpec((bm, a.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, b.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def pairwise_pearson(a: jnp.ndarray, b: jnp.ndarray, *, impl: str = "xla"
                     ) -> jnp.ndarray:
    if impl == "auto":
        impl = resolve_impl(impl, cells=a.shape[0] * b.shape[0])
    if impl == "xla":
        return pairwise_pearson_ref(a, b)
    if impl == "pallas":
        return _pallas(a, b, interpret=False)
    if impl == "pallas_interpret":
        return _pallas(a, b, interpret=True)
    raise ValueError(f"unknown pairwise_pearson impl {impl!r}")
