from .ops import pairwise_pearson
from .ref import pairwise_pearson_ref

__all__ = ["pairwise_pearson", "pairwise_pearson_ref"]
