"""Pure-jnp oracle: pairwise Pearson correlation between metric vectors.

R[i, j] = pearsonr(A[i], B[j]) for A (m, d), B (n, d) — the similarity
measure inside Karasu's Algorithm 1 (DIST). A real deployment computes
this over the whole shared repository ("proper indexing and a respective
distance operator", paper §IV-E), which is why it gets a kernel.
"""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_pearson_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    ac = a - jnp.mean(a, axis=1, keepdims=True)
    bc = b - jnp.mean(b, axis=1, keepdims=True)
    num = ac @ bc.T
    den = (jnp.sqrt(jnp.sum(ac * ac, axis=1))[:, None]
           * jnp.sqrt(jnp.sum(bc * bc, axis=1))[None, :])
    return num / jnp.maximum(den, 1e-12)
