"""Dispatcher for the fused posterior+EI bucket kernel.

``fused_posterior_ei`` takes the padded lanes of one (q, d) posterior
bucket (the exact arrays ``core.plan.PlanExecutor`` assembles) and
returns ``(mu, var, ei)``, each (m, q). ``impl`` follows the package
convention: ``"xla"`` is the vmapped reference chain, ``"pallas"`` /
``"pallas_interpret"`` the fused kernel, and ``"auto"`` routes through
``kernels.routing.resolve_impl`` on the bucket's output cell count.

``_fused_launch`` is the jitted entry the plan executor calls — one
compile per bucket shape, so it belongs to the precompilable launch
vocabulary tracked by ``launch.compile_stats``. On TPU the executor
uses ``_fused_launch_donated`` instead: the stacked observation-cache
buffers (x, mask, chol, alpha, grid, eps-free lanes) are rebuilt from
the sessions' stacks every step, so the launch donates them and XLA
reuses their HBM for the solve intermediates. CPU/GPU skip donation —
those backends cannot alias them and would warn on every launch.
"""
from __future__ import annotations

from functools import partial

import jax

from ..routing import resolve_impl
from .fused import fused_posterior_ei_pallas
from .ref import fused_posterior_ei_ref


def fused_posterior_ei(log_ls, log_sf, x, mask, chol, alpha, xq, best, *,
                       impl: str = "xla"):
    if impl == "auto":
        impl = resolve_impl(impl,
                            cells=x.shape[0] * xq.shape[1] * x.shape[1])
    if impl == "xla":
        return fused_posterior_ei_ref(log_ls, log_sf, x, mask, chol,
                                      alpha, xq, best)
    if impl == "pallas":
        return fused_posterior_ei_pallas(log_ls, log_sf, x, mask, chol,
                                         alpha, xq, best, interpret=False)
    if impl == "pallas_interpret":
        return fused_posterior_ei_pallas(log_ls, log_sf, x, mask, chol,
                                         alpha, xq, best, interpret=True)
    raise ValueError(f"unknown fused_posterior impl {impl!r}")


@partial(jax.jit, static_argnames=("impl",))
def _fused_launch(log_ls, log_sf, x, mask, chol, alpha, xq, best,
                  impl: str = "xla"):
    return fused_posterior_ei(log_ls, log_sf, x, mask, chol, alpha, xq,
                              best, impl=impl)


_fused_launch_donated = jax.jit(
    lambda log_ls, log_sf, x, mask, chol, alpha, xq, best, impl="xla":
        fused_posterior_ei(log_ls, log_sf, x, mask, chol, alpha, xq,
                           best, impl=impl),
    static_argnames=("impl",), donate_argnums=(2, 3, 4, 5, 6))


def fused_launch_fn(donate=None):
    """The jitted launch entry: donating when ``donate`` (default: on a
    TPU backend), plain otherwise. Callers resolve the choice once and
    hold onto it — the plan executor pins it at construction so its
    precompile and its serving dispatch can never disagree on which
    entry's jit cache gets warmed."""
    if donate is None:
        donate = jax.default_backend() == "tpu"
    return _fused_launch_donated if donate else _fused_launch


def ref_twin():
    """The pure-XLA reference body standing in for the Pallas kernel in
    jaxpr-level analysis (``repro.analysis``): same signature, same
    masked-dataflow contract, traceable without a Pallas lowering."""
    return fused_posterior_ei_ref
