"""Pallas TPU kernel: fused masked Cholesky-solve -> posterior -> EI.

The steady-state hot bucket of the query plan is the (q, d) posterior
launch: every fused model lane needs a pairwise Matern-5/2 cross-kernel
against its observations, a triangular solve against its Cholesky
factor, and (for single-objective tenants) the closed-form EI head. XLA
runs that as separate kernels with the (q, n) cross-kernel and the
(n, q) solve round-tripping through HBM; this kernel keeps the whole
chain of one lane x one query block resident in VMEM.

Grid (m, q_blocks): each program owns one model lane and one block of
``bq`` query points. It computes the masked cross-kernel tile on the
MXU, then runs an in-kernel forward substitution over the observation
axis (n is the small axis of the bucket — tens, not thousands — so the
O(n^2 bq) row recurrence stays VMEM-resident in a scratch buffer), and
finishes with mean, variance, and the EI head on the VPU. Padded
observations arrive masked with unit Cholesky diagonals (the query
plan's exact-padding contract), so padded rows solve to exactly zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SQRT5 = 5.0 ** 0.5
VAR_FLOOR = 1e-12        # must match core.acquisition.VAR_FLOOR
INV_SQRT2 = 2.0 ** -0.5
INV_SQRT_2PI = 0.3989422804014327


def _fused_kernel(ls_ref, sf_ref, x_ref, mask_ref, chol_ref, alpha_ref,
                  xq_ref, best_ref, mu_ref, var_ref, ei_ref,
                  kst_ref, v_ref, diag_ref, *, n: int):
    scale = jnp.exp(ls_ref[0])                     # (d,)
    sf = jnp.exp(sf_ref[0, 0])
    x = x_ref[0] * (1.0 / scale)[None, :]          # (n, d)
    xq = xq_ref[0] * (1.0 / scale)[None, :]        # (bq, d)
    mask = mask_ref[0]                             # (n,)

    # masked Matern-5/2 cross-kernel tile, distances via one MXU matmul
    ab = jax.lax.dot_general(xq, x, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = (jnp.sum(xq * xq, 1)[:, None] + jnp.sum(x * x, 1)[None, :]
          - 2.0 * ab)
    d2 = jnp.maximum(d2, 0.0)
    r = jnp.sqrt(d2 + 1e-12)
    ks = (sf * (1.0 + SQRT5 * r + 5.0 / 3.0 * d2) * jnp.exp(-SQRT5 * r)
          * mask[None, :])                         # (bq, n)

    mu = jnp.sum(ks * alpha_ref[0][None, :], axis=1)       # (bq,)

    # forward substitution v = L^{-1} ks^T, rows materialised in VMEM
    # scratch: row k only depends on rows < k, and v is zero-initialised,
    # so the running dot L[k, :] @ v picks up exactly the solved prefix
    chol = chol_ref[0]                             # (n, n)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    diag_ref[...] = jnp.sum(
        jnp.where(row_ids == col_ids, chol, 0.0), axis=1, keepdims=True)
    kst_ref[...] = ks.T
    v_ref[...] = jnp.zeros_like(v_ref)

    def body(k, _):
        l_row = chol_ref[0, pl.ds(k, 1), :]        # (1, n)
        acc = jax.lax.dot_general(
            l_row, v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)    # (1, bq)
        v_ref[pl.ds(k, 1), :] = (
            (kst_ref[pl.ds(k, 1), :] - acc) / diag_ref[pl.ds(k, 1), :])
        return 0

    jax.lax.fori_loop(0, n, body, 0)

    v = v_ref[...]
    var = jnp.maximum(sf - jnp.sum(v * v, axis=0), 1e-10)   # (bq,)

    # closed-form minimisation EI against the per-lane incumbent
    best = best_ref[0, 0]
    sigma = jnp.sqrt(jnp.maximum(var, VAR_FLOOR))
    z = (best - mu) / sigma
    big_phi = 0.5 * (1.0 + jax.lax.erf(z * INV_SQRT2))
    small_phi = jnp.exp(-0.5 * z * z) * INV_SQRT_2PI
    ei = jnp.maximum(sigma * (z * big_phi + small_phi), 0.0)

    mu_ref[0, :] = mu
    var_ref[0, :] = var
    ei_ref[0, :] = ei


def fused_posterior_ei_pallas(log_ls, log_sf, x, mask, chol, alpha, xq,
                              best, *, block_q: int = 128,
                              interpret: bool = False):
    """(mu, var, ei) for one padded (q, d) bucket, each (m, q)."""
    m, n, d = x.shape
    q = xq.shape[1]
    bq = min(block_q, q)
    pq = (-q) % bq
    # lane-dim alignment for the compiled TPU kernel only: d (kernel
    # tile), n (cross-kernel columns / solve rows) pad to 128; padded
    # coords ride unit lengthscales, padded observations a zero mask and
    # unit Cholesky diagonal — exact by the plan's padding contract
    pd = (-d) % 128 if not interpret else 0
    pn = (-n) % 128 if not interpret else 0
    if pd:
        log_ls = jnp.pad(log_ls, ((0, 0), (0, pd)))
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pd)))
        xq = jnp.pad(xq, ((0, 0), (0, 0), (0, pd)))
    if pn:
        x = jnp.pad(x, ((0, 0), (0, pn), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pn)))
        alpha = jnp.pad(alpha, ((0, 0), (0, pn)))
        chol = jnp.pad(chol, ((0, 0), (0, pn), (0, pn)))
        bump = jnp.concatenate([jnp.zeros((n,), jnp.float32),
                                jnp.ones((pn,), jnp.float32)])
        chol = chol + jnp.diag(bump)[None]
    if pq:
        xq = jnp.pad(xq, ((0, 0), (0, pq), (0, 0)), mode="edge")
    n_pad, q_pad = n + pn, q + pq
    sf2 = log_sf.reshape(m, 1)
    best2 = jnp.asarray(best, jnp.float32).reshape(m, 1)

    grid = (m, q_pad // bq)
    out_spec = pl.BlockSpec((1, bq), lambda i, j: (i, j))
    mu, var, ei = pl.pallas_call(
        functools.partial(_fused_kernel, n=n_pad),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, log_ls.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n_pad, x.shape[2]), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n_pad, n_pad), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bq, xq.shape[2]), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((m, q_pad), jnp.float32)] * 3,
        scratch_shapes=[
            pltpu.VMEM((n_pad, bq), jnp.float32),   # ks^T
            pltpu.VMEM((n_pad, bq), jnp.float32),   # v (solve rows)
            pltpu.VMEM((n_pad, 1), jnp.float32),    # Cholesky diagonal
        ],
        interpret=interpret,
    )(log_ls, sf2, x, mask, chol, alpha, xq, best2)
    return mu[:, :q], var[:, :q], ei[:, :q]
