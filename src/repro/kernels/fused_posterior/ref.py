"""Pure-XLA oracle: masked Cholesky-solve -> posterior -> EI, one bucket.

The reference twin of the fused Pallas kernel: for every fused model
lane of a (q, d) posterior bucket it reproduces ``core.gp``'s
``_batched_posterior`` math (pairwise Matern-5/2 cross-kernel masked to
the valid observations, one triangular solve against the model's
Cholesky factor) and then applies ``core.acquisition``'s closed-form
minimisation EI against a per-lane incumbent — exactly the chain of
eager launches the fused kernel collapses into one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SQRT5 = 5.0 ** 0.5
VAR_FLOOR = 1e-12        # must match core.acquisition.VAR_FLOOR
INV_SQRT2 = 2.0 ** -0.5
INV_SQRT_2PI = float(1.0 / jnp.sqrt(2.0 * jnp.pi))


def _matern52(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    d2 = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
          - 2.0 * (a @ b.T))
    r = jnp.sqrt(jnp.maximum(d2, 0.0) + 1e-12)
    return (1.0 + SQRT5 * r + 5.0 / 3.0 * d2) * jnp.exp(-SQRT5 * r)


def _ei(mu: jnp.ndarray, var: jnp.ndarray, best) -> jnp.ndarray:
    sigma = jnp.sqrt(jnp.maximum(var, VAR_FLOOR))
    z = (best - mu) / sigma
    big_phi = 0.5 * (1.0 + jax.lax.erf(z * INV_SQRT2))
    small_phi = jnp.exp(-0.5 * z * z) * INV_SQRT_2PI
    return jnp.maximum(sigma * (z * big_phi + small_phi), 0.0)


def fused_posterior_ei_ref(log_ls, log_sf, x, mask, chol, alpha, xq, best):
    """One (q, d) bucket: (mu, var, ei), each (m, q).

    log_ls (m, d), log_sf (m,), x (m, n, d), mask (m, n),
    chol (m, n, n), alpha (m, n), xq (m, q, d), best (m,).
    """
    def one(ls, sf, xi, mi, ci, ai, xqi, bi):
        scale = jnp.exp(ls)
        ks = (jnp.exp(sf) * _matern52(xqi / scale, xi / scale)
              * mi[None, :])                                   # (q, n)
        mu = ks @ ai
        v = jax.scipy.linalg.solve_triangular(ci, ks.T, lower=True)
        var = jnp.maximum(jnp.exp(sf) - jnp.sum(v * v, axis=0), 1e-10)
        return mu, var, _ei(mu, var, bi)

    return jax.vmap(one)(log_ls, log_sf, x, mask, chol, alpha, xq, best)
