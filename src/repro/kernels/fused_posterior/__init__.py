from .ops import fused_launch_fn, fused_posterior_ei
from .ref import fused_posterior_ei_ref
from .fused import fused_posterior_ei_pallas

__all__ = ["fused_posterior_ei", "fused_posterior_ei_ref",
           "fused_posterior_ei_pallas", "fused_launch_fn"]
