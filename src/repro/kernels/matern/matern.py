"""Pallas TPU kernel: blocked pairwise Matern-5/2 kernel matrix.

At repository scale (Karasu fitting thousands of support GPs, each
posterior evaluated over the full candidate set) the kernel matrix is the
GP hot spot. TPU blocking: grid (m_blocks, n_blocks); each program loads
an (bm, d) x (bn, d) tile pair into VMEM, computes squared distances via
one MXU matmul (-2 a.b^T) plus rank-1 row/col norms, and applies the
Matern-5/2 form on the VPU. d is zero-padded to the 128-lane boundary by
the wrapper; bm=bn=256 keeps the tile working set ~0.5 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT5 = 5.0 ** 0.5


def _matern_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)      # (bm, d)
    b = b_ref[...].astype(jnp.float32)      # (bn, d)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
          - 2.0 * ab)
    d2 = jnp.maximum(d2, 0.0)
    r = jnp.sqrt(d2 + 1e-12)
    o_ref[...] = ((1.0 + SQRT5 * r + 5.0 / 3.0 * d2)
                  * jnp.exp(-SQRT5 * r)).astype(o_ref.dtype)


def matern52_pallas(a: jnp.ndarray, b: jnp.ndarray, *, block: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    m, d = a.shape
    n, _ = b.shape
    bm = min(block, m)
    bn = min(block, n)
    pm, pn = (-m) % bm, (-n) % bn
    pd = (-d) % 128 if not interpret else 0
    if pm or pd:
        a = jnp.pad(a, ((0, pm), (0, pd)))
    if pn or pd:
        b = jnp.pad(b, ((0, pn), (0, pd)))
    grid = ((m + pm) // bm, (n + pn) // bn)
    out = pl.pallas_call(
        _matern_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, a.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, b.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
