"""Pure-jnp oracle: pairwise Matern-5/2 kernel matrix.

K[i, j] = (1 + sqrt5 r + 5 r^2 / 3) exp(-sqrt5 r),  r = ||a_i - b_j||_2
(inputs are pre-scaled by the ARD lengthscales by the caller).
"""
from __future__ import annotations

import jax.numpy as jnp

SQRT5 = 5.0 ** 0.5


def matern52_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    d2 = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
          - 2.0 * (a @ b.T))
    # epsilon inside the sqrt: keeps the NLML gradient finite at r=0
    r = jnp.sqrt(jnp.maximum(d2, 0.0) + 1e-12)
    return (1.0 + SQRT5 * r + 5.0 / 3.0 * d2) * jnp.exp(-SQRT5 * r)
