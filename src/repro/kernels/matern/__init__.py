from .ops import matern52
from .ref import matern52_ref
from .matern import matern52_pallas

__all__ = ["matern52", "matern52_ref", "matern52_pallas"]
