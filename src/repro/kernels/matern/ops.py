"""Dispatcher for the Matern-5/2 pairwise kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .ref import matern52_ref
from .matern import matern52_pallas


def matern52(a: jnp.ndarray, b: jnp.ndarray, *, impl: str = "xla"
             ) -> jnp.ndarray:
    if impl == "xla":
        return matern52_ref(a, b)
    if impl == "pallas":
        return matern52_pallas(a, b, interpret=False)
    if impl == "pallas_interpret":
        return matern52_pallas(a, b, interpret=True)
    raise ValueError(f"unknown matern impl {impl!r}")
