"""Dispatcher for the Matern-5/2 pairwise kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ..routing import resolve_impl
from .ref import matern52_ref
from .matern import matern52_pallas


def matern52(a: jnp.ndarray, b: jnp.ndarray, *, impl: str = "xla"
             ) -> jnp.ndarray:
    if impl == "auto":
        # per-call view only: callers fusing many queries into one
        # launch (core.gp's query plan) resolve with the fused cell
        # count themselves and pass a concrete impl down
        impl = resolve_impl(impl, cells=a.shape[-2] * b.shape[-2])
    if impl == "xla":
        return matern52_ref(a, b)
    if impl == "pallas":
        return matern52_pallas(a, b, interpret=False)
    if impl == "pallas_interpret":
        return matern52_pallas(a, b, interpret=True)
    raise ValueError(f"unknown matern impl {impl!r}")
