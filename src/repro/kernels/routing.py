"""Impl routing shared by the kernel dispatchers.

Every Pallas kernel in this package has a pure-XLA reference twin and a
dispatcher taking ``impl`` in {"xla", "pallas", "pallas_interpret"}.
``"auto"`` adds a size/backend heuristic on top: the tiled kernels only
beat XLA's fusions once the launch is large enough to amortise the grid
setup, and they only compile on TPU at all — so ``auto`` resolves to
``pallas`` exactly when the backend is a TPU **and** the number of
output cells of the launch clears a threshold, and to ``xla``
everywhere else (CPU CI, tiny launches, interpret-less GPUs).

Callers that fuse many logical queries into one launch (the
``batched_posterior`` query plan in ``core/gp.py``) resolve with the
FUSED cell count before entering jit, so the routing sees the real
batch size rather than one vmap lane's slice.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

# below this many output cells the dispatch/setup overhead of a Pallas
# launch dominates any tiling win (one 256x256 tile pair ~ 2^16 cells;
# give the kernel a few dozen tiles before switching over)
AUTO_MIN_CELLS = 1 << 21


def _auto_min_cells() -> int:
    # read at resolve time, not import time: tests and service config set
    # REPRO_PALLAS_AUTO_MIN_CELLS after ``repro`` is already imported
    raw = os.environ.get("REPRO_PALLAS_AUTO_MIN_CELLS")
    return AUTO_MIN_CELLS if raw is None else int(raw)


def resolve_impl(impl: str, *, cells: int,
                 backend: Optional[str] = None,
                 min_cells: Optional[int] = None,
                 shards: int = 1) -> str:
    """Resolve ``"auto"`` to a concrete impl; pass others through.

    ``cells`` is the total number of output elements the launch will
    produce (for a fused plan: models x query points x observations).
    ``shards`` divides it: under a ``shard_map`` over the lane axis each
    device runs the kernel on ``cells / shards`` of the work, and THAT
    per-shard volume is what must amortise a Pallas grid's setup — a
    bucket big enough to clear the threshold whole can still be too
    small per shard. ``backend`` defaults to ``jax.default_backend()``;
    injectable for tests."""
    if impl != "auto":
        return impl
    if backend is None:
        backend = jax.default_backend()
    threshold = _auto_min_cells() if min_cells is None else min_cells
    per_shard = cells // max(1, shards)
    return ("pallas" if (backend == "tpu" and per_shard >= threshold)
            else "xla")
