"""Dispatcher for the RGPE ranking loss."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..routing import resolve_impl
from .ref import ranking_loss_padded_ref, ranking_loss_ref
from .ranking_loss import _rank_kernel, _rank_padded_kernel


def _pallas(preds: jnp.ndarray, y: jnp.ndarray, *, block_s: int = 128,
            interpret: bool = False) -> jnp.ndarray:
    s, n = preds.shape
    bs = min(block_s, s)
    ps = (-s) % bs
    pn = (-n) % 128 if not interpret else 0
    if ps or pn:
        preds = jnp.pad(preds, ((0, ps), (0, pn)))
    yp = jnp.pad(y, (0, pn))[None, :] if pn else y[None, :]
    out = pl.pallas_call(
        functools.partial(_rank_kernel, n_valid=n),
        grid=((s + ps) // bs,),
        in_specs=[
            pl.BlockSpec((bs, preds.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((1, yp.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s + ps, 1), jnp.int32),
        interpret=interpret,
    )(preds, yp)
    return out[:s, 0]


def ranking_loss(preds: jnp.ndarray, y: jnp.ndarray, *, impl: str = "xla"
                 ) -> jnp.ndarray:
    if impl == "auto":
        impl = resolve_impl(impl, cells=preds.shape[0] * preds.shape[1] ** 2)
    if impl == "xla":
        return ranking_loss_ref(preds, y)
    if impl == "pallas":
        return _pallas(preds, y, interpret=False)
    if impl == "pallas_interpret":
        return _pallas(preds, y, interpret=True)
    raise ValueError(f"unknown ranking_loss impl {impl!r}")


def _pallas_padded(preds: jnp.ndarray, ys: jnp.ndarray,
                   n_valid: jnp.ndarray, *, block_s: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    r, n = preds.shape
    bs = min(block_s, r)
    pr = (-r) % bs
    pn = (-n) % 128 if not interpret else 0
    if pr or pn:
        # padding rows get n_valid = 0 below, so they count zero pairs
        preds = jnp.pad(preds, ((0, pr), (0, pn)))
        ys = jnp.pad(ys, ((0, pr), (0, pn)))
    nv = jnp.pad(jnp.asarray(n_valid, jnp.int32), (0, pr))[:, None]
    out = pl.pallas_call(
        _rank_padded_kernel,
        grid=((r + pr) // bs,),
        in_specs=[
            pl.BlockSpec((bs, preds.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bs, ys.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r + pr, 1), jnp.int32),
        interpret=interpret,
    )(preds, ys, nv)
    return out[:r, 0]


def ranking_loss_padded(preds: jnp.ndarray, ys: jnp.ndarray,
                        n_valid: jnp.ndarray, *, impl: str = "xla"
                        ) -> jnp.ndarray:
    """Ragged-batch entry point: (R, n_max) samples with per-row targets
    and valid lengths -> (R,) misrank counts. One launch scores every
    (tenant, measure) ensemble of a SearchService step."""
    if impl == "auto":
        impl = resolve_impl(impl, cells=preds.shape[0] * preds.shape[1] ** 2)
    if impl == "xla":
        return ranking_loss_padded_ref(preds, ys, n_valid)
    if impl == "pallas":
        return _pallas_padded(preds, ys, n_valid, interpret=False)
    if impl == "pallas_interpret":
        return _pallas_padded(preds, ys, n_valid, interpret=True)
    raise ValueError(f"unknown ranking_loss impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("impl",))
def _ranking_loss_launch(preds, ys, n_valid, impl: str = "xla"):
    """The jitted (tracked) entry for the padded ranking loss — part of
    the compile-once launch vocabulary (``launch.compile_stats``).
    Callers pad the row axis to the planner's lane policy and the
    sample axis to the observation policy before dispatch, so the shape
    set is closed by the cohort bounds."""
    return ranking_loss_padded(preds, ys, n_valid, impl=impl)


_ranking_loss_launch_donated = jax.jit(
    lambda preds, ys, n_valid, impl="xla":
        ranking_loss_padded(preds, ys, n_valid, impl=impl),
    static_argnames=("impl",), donate_argnums=(2,))


def ranking_loss_launch_fn(donate=None):
    """Donating twin on TPU by default. Only ``n_valid`` is donated:
    it matches the (R,) int32 output buffer exactly, while the float32
    sample matrices can never be reused for an int32 result (donating
    them would only trigger unusable-donation warnings). The counts
    are a fresh per-step stack, rebuilt before each scoring round, so
    the donation is unconditionally alias-safe."""
    if donate is None:
        donate = jax.default_backend() == "tpu"
    return _ranking_loss_launch_donated if donate else _ranking_loss_launch
