"""Pallas TPU kernel: batched pairwise ranking loss.

At repository scale the RGPE weighting evaluates S x (m+1) models x n^2
pairs; this kernel tiles the MC-sample axis into VMEM blocks of bs
samples and evaluates all n^2 comparisons per block on the VPU (n <= 128
observations per profiling search, so an (bs, n, n) bool tile fits VMEM
comfortably; n is padded to the lane boundary by the wrapper with +inf
sentinels that never flip a comparison asymmetrically — padded entries
contribute XOR(False, False) = 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank_kernel(p_ref, y_ref, o_ref, *, n_valid: int):
    p = p_ref[...].astype(jnp.float32)          # (bs, n)
    y = y_ref[...].astype(jnp.float32)          # (1, n)
    n = p.shape[1]
    valid = (jnp.arange(n) < n_valid)
    pl_ = p[:, :, None] < p[:, None, :]         # (bs, n, n)
    yl = (y[0][:, None] < y[0][None, :])[None]
    both = jnp.logical_and(valid[:, None], valid[None, :])[None]
    xor = jnp.logical_xor(pl_, yl) & both
    o_ref[...] = jnp.sum(xor.astype(jnp.int32), axis=(1, 2))[:, None]


def _rank_padded_kernel(p_ref, y_ref, nv_ref, o_ref):
    """Ragged twin of ``_rank_kernel``: every row carries its own target
    vector and valid prefix length, so one launch scores a whole batch of
    heterogeneous (tenant, measure) ensembles. Rows whose n_valid is 0
    (padding rows added by the wrapper) contribute XOR & False = 0."""
    p = p_ref[...].astype(jnp.float32)          # (bs, n)
    y = y_ref[...].astype(jnp.float32)          # (bs, n)
    nv = nv_ref[...].astype(jnp.int32)          # (bs, 1)
    bs, n = p.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bs, n), 1)
    valid = col < nv                            # (bs, n)
    pl_ = p[:, :, None] < p[:, None, :]         # (bs, n, n)
    yl = y[:, :, None] < y[:, None, :]
    both = jnp.logical_and(valid[:, :, None], valid[:, None, :])
    xor = jnp.logical_xor(pl_, yl) & both
    o_ref[...] = jnp.sum(xor.astype(jnp.int32), axis=(1, 2))[:, None]
