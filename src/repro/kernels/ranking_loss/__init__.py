from .ops import (ranking_loss, ranking_loss_launch_fn,
                  ranking_loss_padded)
from .ref import ranking_loss_padded_ref, ranking_loss_ref

__all__ = ["ranking_loss", "ranking_loss_padded", "ranking_loss_ref",
           "ranking_loss_padded_ref", "ranking_loss_launch_fn"]
