from .ops import ranking_loss
from .ref import ranking_loss_ref

__all__ = ["ranking_loss", "ranking_loss_ref"]
