"""Pure-jnp oracle: RGPE ranking loss.

loss(s) = sum_{j,k} 1[ (pred_s[j] < pred_s[k]) XOR (y[j] < y[k]) ]
for every MC sample s — the number of misranked pairs (paper §III-B).
"""
from __future__ import annotations

import jax.numpy as jnp


def ranking_loss_ref(preds: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """preds: (S, n) posterior samples; y: (n,) -> (S,) pair misrank counts."""
    p = preds.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    pl_ = p[:, :, None] < p[:, None, :]          # (S, n, n)
    yl = (yf[:, None] < yf[None, :])[None]       # (1, n, n)
    return jnp.sum(jnp.logical_xor(pl_, yl), axis=(1, 2)).astype(jnp.int32)
