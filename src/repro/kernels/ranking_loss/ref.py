"""Pure-jnp oracle: RGPE ranking loss.

loss(s) = sum_{j,k} 1[ (pred_s[j] < pred_s[k]) XOR (y[j] < y[k]) ]
for every MC sample s — the number of misranked pairs (paper §III-B).
"""
from __future__ import annotations

import jax.numpy as jnp


def ranking_loss_ref(preds: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """preds: (S, n) posterior samples; y: (n,) -> (S,) pair misrank counts."""
    p = preds.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    pl_ = p[:, :, None] < p[:, None, :]          # (S, n, n)
    yl = (yf[:, None] < yf[None, :])[None]       # (1, n, n)
    return jnp.sum(jnp.logical_xor(pl_, yl), axis=(1, 2)).astype(jnp.int32)


def ranking_loss_padded_ref(preds: jnp.ndarray, ys: jnp.ndarray,
                            n_valid: jnp.ndarray) -> jnp.ndarray:
    """Ragged batch of ranking problems, one per row.

    preds: (R, n_max) samples, ys: (R, n_max) per-row observed targets,
    n_valid: (R,) valid prefix length per row -> (R,) misrank counts over
    each row's valid block. Rows with n_valid <= 1 (including fully
    masked padding rows) have no rankable pair and score 0.
    """
    p = preds.astype(jnp.float32)
    y = ys.astype(jnp.float32)
    valid = (jnp.arange(p.shape[1])[None, :]
             < jnp.asarray(n_valid, jnp.int32)[:, None])     # (R, n_max)
    pl_ = p[:, :, None] < p[:, None, :]                      # (R, n, n)
    yl = y[:, :, None] < y[:, None, :]
    both = valid[:, :, None] & valid[:, None, :]
    return jnp.sum(jnp.logical_xor(pl_, yl) & both,
                   axis=(1, 2)).astype(jnp.int32)
