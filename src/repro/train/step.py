"""Training step factory: microbatched grad accumulation + optimizer.

``make_train_step(bundle, optimizer, ...)`` returns a pure function
    train_step(params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit with in/out shardings. Gradient accumulation is a
lax.scan over microbatches (keeps the lowered HLO one-microbatch sized);
the per-unit remat policy lives inside the model (cfg.remat).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import ModelBundle
from .optim import Optimizer
from .grad_compress import compress_gradients


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over all positions. logits fp32 (b, s, V); labels (b, s).

    The gold logit is extracted with an iota-mask reduction rather than
    take_along_axis: a gather along the vocab axis would force GSPMD to
    all-gather the (model-axis-sharded) logits, whereas the mask reduce
    stays local + one small all-reduce.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, vocab), 2)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)


def make_loss_fn(bundle: ModelBundle, aux_weight: float = 0.01):
    def loss_fn(params, mb):
        logits, aux = bundle.train_logits(params, mb)
        ce = cross_entropy(logits, mb["labels"])
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}
    return loss_fn


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(
    bundle: ModelBundle,
    optimizer: Optimizer,
    lr_schedule: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    microbatches: int = 1,
    grad_clip: float = 1.0,
    compress: Optional[str] = None,  # None | "int8" gradient compression
    grad_shardings=None,  # pytree of NamedShardings for the fp32 grad
                          # accumulator (ZeRO-2-style gradient sharding)
):
    loss_fn = make_loss_fn(bundle)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def train_step(params, opt_state, batch, step):
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                g_acc = _constrain(jax.tree.map(jnp.add, g_acc, grads))
                return (g_acc, l_acc + loss), metrics

            g0 = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss_sum), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if compress == "int8":
            grads = compress_gradients(grads)

        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        lr = lr_schedule(step)
        new_params, new_opt_state = optimizer.update(
            grads, opt_state, params, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_opt_state, metrics

    return train_step
