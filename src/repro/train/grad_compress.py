"""Int8 gradient quantization with error feedback (beyond-paper
distributed-optimization trick; off by default).

``compress_gradients`` simulates the quantize -> all-reduce -> dequantize
path in a GSPMD-friendly way: per-tensor symmetric int8 quantization
before the (XLA-inserted) gradient all-reduce would cut cross-pod
gradient traffic 4x for fp32 / 2x for bf16. For exactness accounting, an
error-feedback variant (``EFState``) carries the quantization residual
into the next step, preserving convergence (Karimireddy et al., 2019).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_gradients(grads: Params) -> Params:
    """Quantize->dequantize round trip (the all-reduce happens on the
    int8 representation when lowered; XLA sees the int8 tensor cross the
    replica boundary)."""
    def qdq(g):
        if g.ndim < 2:  # keep small vectors exact
            return g.astype(jnp.float32)
        q, s = _quantize(g)
        return _dequantize(q, s)
    return jax.tree.map(qdq, grads)


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_error_feedback(grads: Params, ef: Params
                                 ) -> Tuple[Params, Params]:
    """Returns (compressed grads, new error-feedback residuals)."""
    def step(g, e):
        gf = g.astype(jnp.float32) + e
        if g.ndim < 2:
            return gf, jnp.zeros_like(e)
        q, s = _quantize(gf)
        deq = _dequantize(q, s)
        return deq, gf - deq
    out = jax.tree.map(step, grads, ef)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_ef
