"""Optimizers: AdamW (fp32 master + moments) and Adafactor (factored
second moment, no master copy) — pure-JAX pytree implementations.

AdamW keeps a fp32 master copy of bf16 params so mixed-precision training
is loss-free; the master + moments are the ZeRO-1 shardable state (see
``launch.shardings.opt_state_specs``). Adafactor is used for the MoE
giants (qwen3-235b, arctic-480b) where fp32 Adam state cannot fit the
pod (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], Any]
    update: Callable[..., Tuple[Params, Any]]  # (grads, state, params, lr)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        # copy=True: for fp32 params astype would alias the param buffer,
        # breaking donation (same buffer donated twice)
        f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
        return {
            "master": jax.tree.map(f32, params),
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** cf
        bc2 = 1.0 - b2 ** cf

        def upd(g, m, mu, nu):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            step = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            if m.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + weight_decay * m
            m = m - lr * step
            return m, mu, nu

        out = jax.tree.map(upd, grads, state["master"], state["mu"],
                           state["nu"])
        master = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), master, params)
        return new_params, {"master": master, "mu": mu, "nu": nu,
                            "count": count}

    return Optimizer("adamw", init, update)


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018).

    State per matrix param: two vectors (row/col second-moment stats)
    instead of a full moment tensor; params are updated in their own
    dtype (fp32 recommended for the giants).
    """

    def _stats(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    def init(params):
        # stats stored as a flat list aligned with jax.tree.leaves(params)
        return {"stats": [_stats(p) for p in jax.tree.leaves(params)],
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        beta2 = 1.0 - cf ** -0.8  # per the paper's schedule

        def upd(g, p, st):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                u = g * jax.lax.rsqrt(vhat + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * pf
            return (pf - lr * u).astype(p.dtype), new_st

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = jax.tree.leaves(params)
        out = [upd(g, p, st) for g, p, st
               in zip(g_leaves, p_leaves, state["stats"])]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        return new_params, {"stats": [o[1] for o in out], "count": count}

    return Optimizer("adafactor", init, update)


def get_optimizer(name: str, **kwargs) -> Optimizer:
    if name == "adamw":
        return adamw(**kwargs)
    if name == "adafactor":
        return adafactor(**kwargs)
    raise ValueError(f"unknown optimizer {name!r}")
