"""Fault-tolerant training loop wrapper.

On thousands of nodes the failure model is: a step either completes,
hangs (straggler / dead host), or the process dies. This module provides
the host-side control plane used by ``launch/train.py``:

  - ``StepWatchdog``     per-step deadline; a step exceeding
                         ``timeout_factor x`` the rolling median is
                         flagged (on a real deployment this triggers the
                         coordinator's slice-restart; here we record and
                         surface it).
  - ``run_resilient``    checkpoint every N steps, resume from the newest
                         committed checkpoint after a (simulated or real)
                         failure, with elastic mesh resharding on resume.
  - ``FailureInjector``  deterministic fault injection for tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint


class StepWatchdog:
    def __init__(self, timeout_factor: float = 3.0, window: int = 20):
        self.timeout_factor = timeout_factor
        self._durations: List[float] = []
        self.window = window
        self.stragglers: List[int] = []

    def observe(self, step: int, duration_s: float) -> bool:
        """Returns True if this step counts as a straggler."""
        med = float(np.median(self._durations[-self.window:])) \
            if self._durations else duration_s
        self._durations.append(duration_s)
        is_straggler = len(self._durations) > 3 and \
            duration_s > self.timeout_factor * med
        if is_straggler:
            self.stragglers.append(step)
        return is_straggler


class FailureInjector:
    """Deterministically kill the loop at given steps (tests/demos)."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])
        self.injected: List[int] = []

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.injected.append(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class ResilientReport:
    steps_done: int
    restarts: int
    stragglers: List[int]
    losses: List[float]


def run_resilient(
    *,
    init_state: Callable[[], Any],          # () -> (params, opt_state)
    step_fn: Callable[..., Any],            # (params, opt, batch, step)
    batch_at: Callable[[int], Dict],
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    shardings: Optional[Any] = None,        # (param_sh, opt_sh) for elastic
    injector: Optional[FailureInjector] = None,
    max_restarts: int = 10,
    mesh_meta: Optional[Dict] = None,
) -> ResilientReport:
    """Run the training loop to completion across (injected) failures."""
    watchdog = StepWatchdog()
    restarts = 0
    losses: List[float] = []

    while True:
        # --- (re)start: restore newest committed checkpoint ---------------
        params, opt_state = init_state()
        start = 0
        latest = latest_checkpoint(ckpt_dir)
        if latest is not None:
            step0, path = latest
            params, opt_state = restore_checkpoint(
                path, (params, opt_state), shardings)
            start = step0 + 1
        try:
            for step in range(start, total_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                t0 = time.time()
                batch = batch_at(step)
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, step)
                loss = float(metrics["loss"])
                losses.append(loss)
                watchdog.observe(step, time.time() - t0)
                if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                    save_checkpoint(ckpt_dir, step, (params, opt_state),
                                    mesh_meta=mesh_meta)
            return ResilientReport(total_steps, restarts,
                                   watchdog.stragglers, losses)
        except RuntimeError as e:
            if "injected" not in str(e):
                raise
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError("too many restarts") from e
