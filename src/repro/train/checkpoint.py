"""Sharded checkpointing with atomic commit and elastic resharding.

Format: one directory per step containing
  - ``manifest.json``  : step, pytree structure, per-leaf shape/dtype,
                         mesh metadata, commit marker
  - ``shard_<i>.npz``  : leaf arrays (host-local values; on a real
                         multi-host pod each host writes its addressable
                         shards — here the single host holds everything)

Fault-tolerance properties:
  - atomic commit: data is written to ``<dir>.tmp`` and renamed only
    after the manifest is fully flushed -> a crash mid-write never
    corrupts the latest valid checkpoint;
  - ``latest_checkpoint`` skips uncommitted/corrupt directories;
  - elastic restore: ``restore`` takes the CURRENT mesh/shardings — the
    stored global arrays are re-sharded on load, so a run checkpointed
    on N pods restarts on M pods unchanged (ZeRO states included).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _leaf_key(i: int) -> str:
    return f"leaf_{i:05d}"


def save_checkpoint(directory: str, step: int, tree: Params,
                    *, mesh_meta: Optional[Dict] = None) -> str:
    """Write one atomic checkpoint under directory/step_<step>."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    meta_leaves = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # numpy's npz cannot round-trip ml_dtypes (bf16 -> void2):
            # store the raw bits as uint16 and restore via view()
            arr = arr.view(np.uint16)
        arrays[_leaf_key(i)] = arr
        meta_leaves.append({"shape": list(arr.shape),
                            "dtype": logical_dtype})
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        "leaves": meta_leaves,
        "mesh": mesh_meta or {},
        "committed": True,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def list_checkpoints(directory: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        path = os.path.join(directory, name)
        mpath = os.path.join(path, "manifest.json")
        try:
            with open(mpath) as f:
                m = json.load(f)
            if m.get("committed"):
                out.append((int(m["step"]), path))
        except Exception:
            continue  # partial/corrupt checkpoint: skip
    return sorted(out)


def latest_checkpoint(directory: str) -> Optional[Tuple[int, str]]:
    cps = list_checkpoints(directory)
    return cps[-1] if cps else None


def restore_checkpoint(path: str, target_tree: Params,
                       shardings: Optional[Params] = None) -> Params:
    """Restore into the structure of ``target_tree``; if ``shardings``
    is given, leaves are placed with those shardings (elastic reshard —
    the stored global array is valid on any mesh)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    leaves_t, treedef = jax.tree.flatten(target_tree)
    assert len(leaves_t) == len(manifest["leaves"]), \
        "checkpoint/target structure mismatch"
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None \
        else [None] * len(leaves_t)
    out = []
    for i, (tgt, sh) in enumerate(zip(leaves_t, shard_leaves)):
        arr = data[_leaf_key(i)]
        logical = manifest["leaves"][i]["dtype"]
        if str(arr.dtype) != logical:  # bit-stored exotic dtype (bf16)
            arr = arr.view(jnp.dtype(logical))
        expect = tuple(getattr(tgt, "shape", arr.shape))
        assert tuple(arr.shape) == expect, (i, arr.shape, expect)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
