"""Synthetic token data pipeline with background prefetch.

Deterministic per (seed, step) — restart/elastic-rescale resumes the
exact stream (the generator is indexed by global step, not by an
internal cursor), which is what checkpoint-restart correctness needs.
Prefetching runs on a worker thread with a bounded queue: the host
produces batch t+k while step t executes (straggler hiding on the input
side).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    """Zipfian token stream + next-token labels."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, extras: Optional[Callable[[np.random.Generator, int], Dict]] = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.extras = extras
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        tokens = rng.choice(self.vocab, size=(self.global_batch,
                                              self.seq_len + 1), p=self._p)
        batch = {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }
        if self.extras is not None:
            batch.update(self.extras(rng, step))
        return batch


class Prefetcher:
    """Bounded-queue background prefetch of batches [start, ...)."""

    def __init__(self, source: SyntheticLM, start_step: int,
                 depth: int = 2):
        self._source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
