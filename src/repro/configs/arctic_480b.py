"""arctic-480b: dense-MoE hybrid, 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8, head_dim=128) d_ff=4864 vocab=32000,
MoE 128e top-2 with a dense residual branch in parallel. Pure full
attention -> long_500k skipped. Expert weights FSDP-sharded over the
data axis in addition to expert parallelism (bf16 weights alone are
~0.96 TB); trained with Adafactor.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    block_pattern=("attn",),
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    rope_theta=10_000.0,
    tie_embeddings=False,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=128,
    block_pattern=("attn",),
    n_experts=8,
    top_k=2,
    moe_d_ff=96,
    dense_residual=True,
    tie_embeddings=False,
)
