"""whisper-large-v3: encoder-decoder audio backbone
[arXiv:2212.04356; unverified].

32 encoder + 32 decoder layers, d_model=1280 20H (MHA, head_dim=64)
d_ff=5120 vocab=51866. Conv/mel frontend is a STUB: input_specs()
supplies precomputed (batch, 1500, d_model) frame embeddings. long_500k
skipped (<=1500-frame source, short decoder by construction).

The embedding table is padded to 51872 (next multiple of 16) so the
vocab axis shards evenly over the 16-way model axis — standard
production practice (the 6 pad rows are never addressed; the logical
vocab remains 51866).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51872,  # 51866 padded to a multiple of 16 (see docstring)
    block_pattern=("attn",),
    is_encoder_decoder=True,
    n_encoder_layers=32,
    n_audio_frames=1500,
    use_bias=True,
    tie_embeddings=True,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=128,
    block_pattern=("attn",),
    is_encoder_decoder=True,
    n_encoder_layers=2,
    n_audio_frames=30,
    use_bias=True,
)
