"""minitron-8b: pruned nemotron dense transformer [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=16384 vocab=256000.
Pure full attention -> long_500k cell skipped (see DESIGN.md).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    block_pattern=("attn",),
    rope_theta=10_000.0,
    tie_embeddings=False,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="minitron-8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=128,
    block_pattern=("attn",),
    tie_embeddings=False,
)
