"""h2o-danube-1.8b: llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8, head_dim=80) d_ff=6912 vocab=32000,
window=4096 on every layer -> sub-quadratic, long_500k RUNS.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    block_pattern=("local_attn",),
    window=4096,
    rope_theta=10_000.0,
    tie_embeddings=False,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="h2o-danube-1.8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=128,
    block_pattern=("local_attn",),
    window=16,
    tie_embeddings=False,
)
