"""qwen3-moe-235b-a22b: 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4, head_dim=128) expert d_ff=1536
vocab=151936, 128 experts top-8, QK-norm. Pure full attention ->
long_500k skipped. Trained with Adafactor (Adam fp32 state would not
fit 256 chips; see launch/shardings.py).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab=151936,
    block_pattern=("attn",),
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=0,
    vocab=128,
    block_pattern=("attn",),
    n_experts=8,
    top_k=2,
    moe_d_ff=96,
    use_qk_norm=True,
    tie_embeddings=False,
)
