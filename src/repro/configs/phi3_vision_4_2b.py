"""phi-3-vision-4.2b: phi3-mini backbone + CLIP frontend (STUB)
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (MHA kv=32, head_dim=96) d_ff=8192 vocab=32064.
The CLIP ViT frontend is a STUB: input_specs() supplies precomputed
(batch, 576, d_model) patch embeddings scattered over masked token
positions. Pure full attention -> long_500k skipped (the reference
model's 128k blocksparse variant is approximated as full attention;
noted in DESIGN.md).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    block_pattern=("attn",),
    n_image_patches=576,
    rope_theta=10_000.0,
    tie_embeddings=False,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="phi3v-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=128,
    block_pattern=("attn",),
    n_image_patches=8,
    tie_embeddings=False,
)
