"""gemma3-4b: 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family; unverified].

34L d_model=2560 8H (GQA kv=4, head_dim=256) d_ff=10240 vocab=262144.
34 = 2 units of a 17-layer pattern with 3 globals each (28 local : 6
global ~= 5:1; the reference model places globals every 6th layer —
noted deviation to keep the scan-unit structure). local window = 1024,
global rope theta = 1M. long_500k RUNS (globals keep full KV; locals
keep a 1024-slot ring).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

_UNIT = ("local_attn",) * 5 + ("attn",) + ("local_attn",) * 5 + ("attn",) \
    + ("local_attn",) * 4 + ("attn",)  # 17 layers, 3 global

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    block_pattern=_UNIT,
    window=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    use_qk_norm=True,
    use_post_norm=True,
    scale_embeddings=True,
    tie_embeddings=True,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-4b-smoke",
    family="dense",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=128,
    block_pattern=("local_attn", "local_attn", "attn"),
    window=8,
    rope_theta_global=1_000_000.0,
    use_qk_norm=True,
    use_post_norm=True,
    scale_embeddings=True,
)
