"""xlstm-125m: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H vocab=50304, d_ff=0 (blocks own their projections).
Pattern (mlstm x5, slstm) x 2 (paper uses ~[7:1]; 12 layers forces 5:1
— noted deviation). Recurrent state O(1) -> long_500k RUNS.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

_UNIT = ("mlstm",) * 5 + ("slstm",)

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50304,
    block_pattern=_UNIT,
    tie_embeddings=True,
    param_dtype=jnp.float32,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-125m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=0,
    vocab=128,
    block_pattern=("mlstm", "slstm"),
)
