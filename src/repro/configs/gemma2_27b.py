"""gemma2-27b: alternating local/global attention + logit softcaps
[arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16, head_dim=128) d_ff=36864 vocab=256000.
Pattern (local, global) x 23; window 4096; attn softcap 50, final 30;
sandwich (post) norms; embeddings scaled by sqrt(d). long_500k RUNS
(half the layers are windowed; globals keep full KV, decode is linear).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    block_pattern=("local_attn", "attn"),
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norm=True,
    scale_embeddings=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma2-27b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=128,
    block_pattern=("local_attn", "attn"),
    window=8,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norm=True,
    scale_embeddings=True,
)
