"""Architecture configs (assigned pool) + input shape specs.

``get_config(arch_id)`` returns the full-size ModelConfig;
``get_smoke_config(arch_id)`` a reduced same-family config for CPU tests;
``input_specs(arch_id, shape_id)`` ShapeDtypeStruct stand-ins per cell.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCH_IDS = [
    "minitron-8b",
    "h2o-danube-1.8b",
    "gemma3-4b",
    "gemma2-27b",
    "zamba2-1.2b",
    "qwen3-moe-235b-a22b",
    "arctic-480b",
    "xlstm-125m",
    "whisper-large-v3",
    "phi-3-vision-4.2b",
]

_MODULES = {
    "minitron-8b": "minitron_8b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "gemma3-4b": "gemma3_4b",
    "gemma2-27b": "gemma2_27b",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "arctic-480b": "arctic_480b",
    "xlstm-125m": "xlstm_125m",
    "whisper-large-v3": "whisper_large_v3",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
}

SHAPE_IDS = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# per assignment: long_500k only for sub-quadratic-capable archs
LONG_500K_SKIP = {
    "minitron-8b": "pure full attention",
    "qwen3-moe-235b-a22b": "pure full attention",
    "arctic-480b": "pure full attention",
    "phi-3-vision-4.2b": "pure full attention",
    "whisper-large-v3": "enc-dec, <=1500-frame source / short decoder",
}


def _mod(arch_id: str):
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _mod(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _mod(arch_id).SMOKE_CONFIG


def cells() -> List[tuple]:
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPE_IDS:
            if s == "long_500k" and a in LONG_500K_SKIP:
                continue
            out.append((a, s))
    return out
