"""Input-shape specs per (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every model input of the cell, plus
the step kind the cell lowers:
    train_4k    -> train_step   (tokens + labels)
    prefill_32k -> prefill_step (tokens, positions; builds the KV cache)
    decode_32k  -> serve_step   (1 new token against a seq_len KV cache)
    long_500k   -> serve_step   (1 new token against a 524288-entry cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class CellSpec:
    kind: str            # train | prefill | decode
    seq_len: int         # context length (cache length for decode)
    global_batch: int
    batch: Dict[str, jax.ShapeDtypeStruct]  # model inputs


def _modality_extras(cfg: ModelConfig, b: int, s: int) -> Dict[str, Any]:
    extras: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        extras["frame_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_image_patches:
        extras["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_image_patches, cfg.d_model), jnp.bfloat16)
        extras["image_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
    return extras


def input_specs(cfg: ModelConfig, shape_id: str) -> CellSpec:
    meta = SHAPES[shape_id]
    b, s = meta["global_batch"], meta["seq_len"]
    kind = meta["kind"]
    tok = jnp.int32

    if kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "labels": jax.ShapeDtypeStruct((b, s), tok),
        }
        batch.update(_modality_extras(cfg, b, s))
    elif kind == "prefill":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "positions": jax.ShapeDtypeStruct((b, s), tok),
        }
        batch.update(_modality_extras(cfg, b, s))
    else:  # decode: one new token per sequence
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, 1), tok),
            "positions": jax.ShapeDtypeStruct((b, 1), tok),
        }
        # modality context was consumed at prefill; decode sees the cache
    return CellSpec(kind=kind, seq_len=s, global_batch=b, batch=batch)
