"""zamba2-1.2b: Mamba2 backbone + weight-tied shared attention blocks
[arXiv:2411.15242; hf].

38L d_model=2048, ssm_state=64; shared transformer block (32H, kv=32,
d_ff=8192) applied with per-use LoRA adapters, input = concat(hidden,
initial embedding). Pattern: 19-layer unit (8 mamba, shared, 9 mamba,
shared) x 2 = 38 layers with 4 shared-block applications. long_500k
RUNS (SSM state is O(1); shared attn keeps full KV, linear decode).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

_UNIT = ("mamba2",) * 8 + ("shared_attn",) + ("mamba2",) * 9 + ("shared_attn",)

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    block_pattern=_UNIT,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_lora_rank=128,
    rope_theta=10_000.0,
    tie_embeddings=True,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-1.2b-smoke",
    family="hybrid",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=128,
    block_pattern=("mamba2", "mamba2", "shared_attn"),
    ssm_state=8,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=16,
    shared_lora_rank=8,
)
