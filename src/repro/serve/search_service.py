"""Multi-tenant configuration-search service.

Karasu's premise (paper §III) is many users sharing one performance-data
repository, each running their own BO search against it. ``run_search``
serves exactly one tenant and refits its GPs in Python loops; this
module serves N tenants concurrently with the continuous-batching idiom
of ``ServeEngine``: a fixed pool of session slots, ``submit`` queues a
search, admission plays the role of prefill (the random initial
profiling runs), and every ``step`` advances ALL active sessions by one
BO iteration ("decode").

The hot path is batched across tenants: each step stacks every active
session's target-GP fit jobs — one per (tenant, measure) — into a single
``BatchedGP`` per (search space, noise) group, so the whole round costs
one vmapped Adam/Cholesky fit and one batched posterior over the full
candidate grid instead of ``tenants x measures`` sequential fits.
Support models come from one ``SupportModelStore`` shared by every
tenant and invalidated incrementally per (workload, measure) when
``add_run`` bumps that workload's repository version — results a tenant
publishes mid-search become another tenant's support data on its very
next step.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.bo import (BOConfig, KarasuContext, ProfileFn,
                           _acquisition, _model_posteriors_augmented,
                           _profile_into, _should_stop_early, _target_runs)
from repro.core.encoding import SearchSpace
from repro.core.gp import batched_posterior, fit_gp_batched
from repro.core.repository import Repository, SupportModelStore
from repro.core.rgpe import compute_weights_batched
from repro.core.types import (BOResult, Constraint, Objective, Observation,
                              RunRecord)


@dataclasses.dataclass
class SearchRequest:
    """One tenant's search: the ``run_search`` arguments as a record."""
    space: SearchSpace
    profile_fn: ProfileFn
    objective: Objective
    constraints: Sequence[Constraint] = ()
    method: str = "karasu"            # naive | augmented | karasu
    bo_config: BOConfig = dataclasses.field(default_factory=BOConfig)
    seed: int = 0
    share_as: Optional[str] = None    # publish runs to the repo under this id


@dataclasses.dataclass
class SearchCompletion:
    rid: int
    result: BOResult


class _Session:
    """Mutable per-tenant state (mirrors run_search's loop variables)."""

    def __init__(self, rid: int, req: SearchRequest):
        self.rid = rid
        self.req = req
        self.cfg = req.bo_config
        self.key = jax.random.PRNGKey(req.seed)
        self.rng = np.random.default_rng(req.seed)
        self.measures = ([req.objective.name]
                         + [c.name for c in req.constraints])
        self.xq_all = req.space.all_encoded()
        # batching/context key: spaces are interchangeable iff their
        # configs AND encodings agree — the name alone could conflate
        # two different user-built spaces that happen to share it
        self.space_key = (req.space.name, hashlib.sha1(
            np.ascontiguousarray(self.xq_all).tobytes()
            + repr(req.space.configs).encode()).hexdigest())
        self.observations: List[Observation] = []
        self.best_idx: List[int] = []
        self.profiled: set = set()
        self.stopped_at = self.cfg.max_iters
        self.meta: Dict[str, Any] = {"method": req.method, "selected": []}

    def profile(self, ci: int, repo: Optional[Repository]) -> None:
        obs = _profile_into(self.req.space, self.xq_all,
                            self.req.profile_fn, self.req.objective,
                            self.req.constraints, self.observations,
                            self.best_idx, self.profiled, ci)
        # publish only complete records: Algorithm-1 needs the metric
        # matrix, and a None-metrics record would poison the shared
        # CandidateIndex for every other tenant
        if (repo is not None and self.req.share_as is not None
                and obs.metrics is not None):
            repo.add_run(RunRecord(self.req.share_as, dict(obs.config),
                                   obs.metrics, obs.measures))

    def admit(self, repo: Optional[Repository]) -> None:
        """'Prefill': the random initialisation runs (paper §IV-B)."""
        n = min(self.cfg.n_init, len(self.req.space))
        for ci in self.rng.choice(len(self.req.space), size=n,
                                  replace=False):
            self.profile(int(ci), repo)

    def remaining(self) -> List[int]:
        return [i for i in range(len(self.req.space))
                if i not in self.profiled]

    def result(self) -> BOResult:
        self.meta["n_profiled"] = len(self.observations)
        return BOResult(observations=self.observations,
                        best_index_per_iter=self.best_idx,
                        stopped_at=self.stopped_at, meta=self.meta)


class SearchService:
    """N concurrent tenant searches over one shared repository.

    ``submit`` -> rid; ``step`` advances every active session one BO
    iteration (admitting queued sessions into free slots first);
    ``collect`` drains finished searches; ``run`` loops until idle.
    """

    def __init__(self, repository: Optional[Repository] = None, *,
                 slots: int = 8):
        self.repo = repository if repository is not None else Repository()
        self.slots = slots
        self.queue: List[_Session] = []
        self.active: Dict[int, _Session] = {}
        self.done: List[SearchCompletion] = []
        self._next_rid = 0
        # one KarasuContext (store + candidate index) per (space, noise):
        # support GPs depend on the encoder and the noise level only
        self._contexts: Dict[Tuple[Any, float], KarasuContext] = {}
        self.stats = {"steps": 0, "fit_batches": 0, "fit_jobs": 0,
                      "iterations": 0}

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: SearchRequest) -> int:
        if req.method not in ("naive", "augmented", "karasu"):
            raise ValueError(f"unknown method {req.method!r}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(_Session(rid, req))
        return rid

    def collect(self) -> List[SearchCompletion]:
        out, self.done = self.done, []
        return out

    def context_for(self, session: _Session) -> KarasuContext:
        k = (session.space_key, session.cfg.noise)
        if k not in self._contexts:
            self._contexts[k] = KarasuContext(self.repo, session.req.space,
                                              noise=session.cfg.noise)
        return self._contexts[k]

    def _admit(self) -> None:
        while self.queue and len(self.active) < self.slots:
            s = self.queue.pop(0)
            s.admit(self.repo)
            self.active[s.rid] = s

    def _finish(self, s: _Session) -> None:
        del self.active[s.rid]
        self.done.append(SearchCompletion(s.rid, s.result()))

    # -- one scheduling round -----------------------------------------------
    def step(self) -> int:
        """Admit queued sessions, then advance each active session one BO
        iteration with the target fits batched across tenants. Returns
        the number of sessions advanced."""
        self._admit()
        self.stats["steps"] += 1

        ready: List[Tuple[_Session, List[int]]] = []
        for s in list(self.active.values()):
            if len(s.observations) >= s.cfg.max_iters:
                self._finish(s)
                continue
            rem = s.remaining()
            if not rem:
                s.stopped_at = len(s.observations)
                self._finish(s)
                continue
            ready.append((s, rem))
        if not ready:
            return 0

        posts = self._batched_posteriors([s for s, _ in ready])

        advanced = 0
        for s, rem in ready:
            acq, best_raw, obj_post = _acquisition(
                posts[s.rid], s.observations, s.req.objective,
                s.req.constraints)
            acq = acq[np.asarray(rem)]

            if _should_stop_early(s.cfg, len(s.observations), acq,
                                  obj_post, best_raw):
                s.stopped_at = len(s.observations)
                self._finish(s)
                continue

            s.profile(rem[int(np.argmax(acq))], self.repo)
            advanced += 1
            self.stats["iterations"] += 1
            if len(s.observations) >= s.cfg.max_iters:
                self._finish(s)
        return advanced

    def _batched_posteriors(self, sessions: List[_Session]
                            ) -> Dict[int, Dict[str, Dict]]:
        """Fit every (session, measure) target GP in one vmapped batch
        per (space, noise) group and query the full candidate grid; then
        overlay RGPE mixtures for karasu sessions."""
        groups: Dict[Tuple[Any, float], List[_Session]] = {}
        posts: Dict[int, Dict[str, Dict]] = {}
        for s in sessions:
            if s.req.method == "augmented":
                # Extra-Trees have no batched path; keep them per-session
                posts[s.rid] = _model_posteriors_augmented(
                    s.observations, s.measures, s.cfg, s.xq_all, s.req.seed)
                continue
            groups.setdefault((s.space_key, s.cfg.noise), []).append(s)

        for (_, noise), group in groups.items():
            xs, ys, owners = [], [], []
            for s in group:
                x = np.stack([o.x for o in s.observations])
                for m in s.measures:
                    xs.append(x)
                    ys.append(np.array([o.measures[m]
                                        for o in s.observations]))
                    owners.append((s, m))
            # round the pad length up so jit shapes stay stable while the
            # whole cohort grows (padding never changes results)
            n_max = max(len(y) for y in ys)
            n_max = ((n_max + 7) // 8) * 8
            tgts = fit_gp_batched(xs, ys, noise=noise, n_max=n_max)
            self.stats["fit_batches"] += 1
            self.stats["fit_jobs"] += len(owners)

            xq_all = group[0].xq_all
            mu_all, var_all = batched_posterior(tgts, xq_all)

            for ji, (s, m) in enumerate(owners):
                posts.setdefault(s.rid, {})[m] = {
                    "mu": mu_all[ji], "var": var_all[ji],
                    "y_mean": tgts.y_mean[ji], "y_std": tgts.y_std[ji]}

            for s in group:
                if s.req.method == "karasu":
                    self._overlay_rgpe(s, tgts, owners, posts[s.rid])
        return posts

    def _overlay_rgpe(self, s: _Session, tgts, owners, post) -> None:
        """Replace a karasu session's plain target posteriors with the
        RGPE mixture built from the shared support store."""
        ctx = self.context_for(s)
        # a tenant must never pick its own published runs as "support":
        # they would score ~1.0 against themselves and sidestep the LOO
        # sampling that keeps the target honest on its training points
        exclude = (s.req.share_as,) if s.req.share_as else None
        selected = ctx.candidate_index().query(
            _target_runs(s.observations), s.cfg.n_support,
            impl=s.cfg.kernel_impl, exclude=exclude)
        s.meta["selected"].append([z for z, _ in selected])
        if not selected:
            return
        it = len(s.observations)
        job_of = {m: ji for ji, (o, m) in enumerate(owners) if o is s}
        for mi, m in enumerate(s.measures):
            bases, _ids = ctx.store.get_stacked([z for z, _ in selected], m)
            if bases is None:
                continue
            tgt = tgts.extract(job_of[m])
            w = compute_weights_batched(
                bases, tgt, jax.random.fold_in(
                    jax.random.fold_in(s.key, it), mi),
                n_samples=s.cfg.rgpe_samples, impl=s.cfg.kernel_impl)
            mu_b, var_b = batched_posterior(bases, s.xq_all)
            wb, wt = w[:-1, None], w[-1]
            mu = (wb * mu_b).sum(0) + wt * post[m]["mu"]
            var = ((wb ** 2) * var_b).sum(0) + (wt ** 2) * post[m]["var"]
            post[m] = {"mu": mu, "var": np.maximum(np.asarray(var), 1e-10),
                       "y_mean": post[m]["y_mean"],
                       "y_std": post[m]["y_std"],
                       "weights": np.asarray(w)}

    # -- driver -------------------------------------------------------------
    def run(self, max_steps: int = 10_000) -> List[SearchCompletion]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.collect()
