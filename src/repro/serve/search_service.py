"""Multi-tenant configuration-search service.

Karasu's premise (paper §III) is many users sharing one performance-data
repository, each running their own BO search against it. ``run_search``
serves exactly one tenant and refits its GPs in Python loops; this
module serves N tenants concurrently with the continuous-batching idiom
of ``ServeEngine``: a fixed pool of session slots, ``submit`` queues a
search, admission plays the role of prefill (the random initial
profiling runs), and every ``step`` advances ALL active sessions by one
BO iteration ("decode").

Two axes are batched/overlapped across tenants:

  - **Model math**: every step is an explicit collect → plan → execute
    → scatter round over the query-plan layer (``repro.serve.plan``):
    the step COLLECTS query nodes from every ready session — one
    ``PosteriorQuery`` per target stack and per RGPE support stack, one
    ``PosteriorDrawQuery`` per (MOO session, objective) lane, one
    ``EhviQuery`` per MOO session — each tagged with its owner; the
    ``StepPlanner`` groups them into buckets (owning ALL
    bucketing/padding policy); the ``PlanExecutor`` runs one fused
    launch per bucket (``impl="auto"`` routes to the Pallas matern
    kernel on TPU when the fused batch justifies it); and the step
    SCATTERS results back to their owning sessions. Target fits share
    one vmapped Adam/Cholesky per (search space, noise) group under the
    same planner policy, and ALL karasu sessions' RGPE ensembles score
    through ONE padded ranking-loss launch (``compute_weights_multi``,
    whose sample draws ride the same plan). RGPE mixing and the
    acquisitions (EI, constrained EI, MC-EHVI) are applied to the
    scattered rows as vectorised array ops, not per-session loops.
    ``fuse_posteriors=False`` restores the per-ensemble posterior loop
    and the per-candidate MC-EHVI reference, ``fuse_samples=False`` the
    per-job draw loop and per-session numpy EHVI — the
    parity/benchmark baselines.
  - **Profiling**: cluster runs execute through a ``ProfileExecutor``
    (``serve/profile_executor.py``). A session whose run is in flight
    sits in the explicit ``WAITING_PROFILE`` state while every session
    whose result landed keeps fitting/scoring — the step rate is set by
    the hardware, not by the slowest tenant's profiler. The default
    ``SyncProfileExecutor`` reproduces the fully synchronous service
    bitwise.

Sessions may be single-objective (``objective=...``) or multi-objective
(``objectives=[a, b, ...]``, paper §III-D: MC-EHVI weighted by every
constraint's probability of feasibility — 2 objectives evaluate via the
staircase envelope, n >= 3 via the non-dominated box decomposition, both
as ``EhviQuery`` plan nodes); all kinds mix freely in one step and share
the same fused fit/weight/posterior launches. ``run_search_moo`` is a
thin driver over this path.

Support models come from one ``SupportModelStore`` shared by every
tenant and invalidated incrementally per (workload, measure) when
``add_run`` bumps that workload's repository version — results a tenant
publishes mid-search become another tenant's support data on its very
next step.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acquisition import (mc_ehvi, mc_ehvi_batched, mc_ehvi_nd,
                                    pareto_of_observations,
                                    probability_of_feasibility)
from repro.core.bo import (KEY_PURPOSE_MOO_EHVI, KEY_PURPOSE_RGPE, BOConfig,
                           KarasuContext, ProfileFn, _acquisition,
                           _best_index_so_far, _feasible,
                           _model_posteriors_augmented, _should_stop_early,
                           _target_runs, derive_key)
from repro.core.encoding import SearchSpace
from repro.core.gp import (GP, BatchedGP, GPParams, _pad_stack_obs,
                           batched_posterior)
from repro.core.repository import Repository
from repro.core.rgpe import WeightJob, mix_weighted
from repro.kernels.ranking_loss import ranking_loss_launch_fn
from repro.core.types import (BOResult, Constraint, Objective, Observation,
                              RunRecord)
from repro.launch.compile_stats import CompileWatcher
from repro.serve.plan import (CohortLimits, EhviQuery, FitQuery,
                              LooSampleQuery, PlanExecutor,
                              PosteriorDrawQuery, PosteriorQuery,
                              SampleQuery, StepPlan, StepPlanner)
from repro.serve.profile_executor import (ProfileJob, ProfileOutcome,
                                          SyncProfileExecutor)

# session states
READY = "ready"                        # observations current, can fit/score
WAITING_PROFILE = "waiting_profile"    # >=1 profiling run in flight

# The service's declared PRNG schedule: every per-iteration key it
# consumes derives as derive_key(session.key, purpose, iteration,
# index) with exactly these purposes. ``repro.analysis.prng_audit``
# cross-checks this declaration against ``bo.KEY_PURPOSES`` and proves
# the enumerated tree collision-free — extend it when a new consumer
# joins the schedule.
KEY_SCHEDULE = (
    (KEY_PURPOSE_RGPE, "per-measure RGPE support/LOO draw keys"),
    (KEY_PURPOSE_MOO_EHVI, "per-objective MOO posterior-draw keys"),
)


def _absorb_target_posts(posts, owners, tgts, mu, var) -> None:
    """Record one target stack's grid-posterior rows into each owning
    (session, measure) slot — shared by the fused plan and the loop
    fallback so the posterior dict shape cannot diverge between them."""
    for ji, (s, m) in enumerate(owners):
        posts.setdefault(s.rid, {})[m] = {
            "mu": mu[ji], "var": var[ji],
            "y_mean": tgts.y_mean[ji], "y_std": tgts.y_std[ji]}


@dataclasses.dataclass
class SearchRequest:
    """One tenant's search: the ``run_search`` (or ``run_search_moo``)
    arguments as a record. Exactly one of ``objective`` /
    ``objectives`` must be set; ``objectives=[a, b, ...]`` (two or
    more) makes the session multi-objective (MC-EHVI, §III-D; n >= 3
    objectives evaluate via the box-decomposition EHVI plan node)."""
    space: SearchSpace
    profile_fn: ProfileFn
    objective: Optional[Objective] = None
    constraints: Sequence[Constraint] = ()
    method: str = "karasu"            # naive | augmented | karasu
    bo_config: BOConfig = dataclasses.field(default_factory=BOConfig)
    seed: int = 0
    share_as: Optional[str] = None    # publish runs to the repo under this id
    objectives: Optional[Sequence[Objective]] = None   # MOO: two or more
    n_mc: int = 64                    # MC-EHVI posterior draws (MOO only)


@dataclasses.dataclass
class SearchCompletion:
    rid: int
    result: BOResult


class _Session:
    """Mutable per-tenant state (mirrors run_search's loop variables)."""

    def __init__(self, rid: int, req: SearchRequest):
        self.rid = rid
        self.req = req
        self.cfg = req.bo_config
        self.key = jax.random.PRNGKey(req.seed)
        self.rng = np.random.default_rng(req.seed)
        self.objectives = (list(req.objectives)
                           if req.objectives is not None else [])
        self.is_moo = bool(self.objectives)
        obj_names = ([o.name for o in self.objectives] if self.is_moo
                     else [req.objective.name])
        self.measures = obj_names + [c.name for c in req.constraints]
        self.xq_all = req.space.all_encoded()
        # batching/context key: spaces are interchangeable iff their
        # configs AND encodings agree — the name alone could conflate
        # two different user-built spaces that happen to share it
        self.space_key = (req.space.name, hashlib.sha1(
            np.ascontiguousarray(self.xq_all).tobytes()
            + repr(req.space.configs).encode()).hexdigest())
        self.observations: List[Observation] = []
        self.best_idx: List[int] = []
        self.profiled: set = set()
        self.stopped_at = self.cfg.max_iters
        self.meta: Dict[str, Any] = {"method": req.method, "selected": []}
        if self.is_moo:
            self.meta["moo"] = True
            self.meta["objectives"] = [o.name for o in self.objectives]
        self.state = READY
        self.inflight = 0
        self._launch_seq = 0           # session-local submission index
        self._record_seq = 0           # next seq to absorb
        self._held: Dict[int, ProfileOutcome] = {}
        # warm-start cache of the incremental fit leg: measure ->
        # (observation version, log_ls, log_sf) host rows from the last
        # fit. An entry means the next fit of that measure rides the
        # short warm rung; the version records which observation set
        # produced it (diagnostics — the warm start is a valid initial
        # point for ANY later observation set of the same model).
        self.fit_cache: Dict[str, Tuple[int, np.ndarray, np.ndarray]] = {}

    def launch(self, ci: int, tag: str = "bo") -> ProfileJob:
        """Reserve candidate ``ci`` and build its executor job; the
        session waits in WAITING_PROFILE until the outcome lands."""
        self.profiled.add(int(ci))
        self.inflight += 1
        self.state = WAITING_PROFILE
        job = ProfileJob(self.rid, int(ci), self.req.space.configs[ci],
                         tag, self._launch_seq)
        self._launch_seq += 1
        return job

    def record(self, out: ProfileOutcome,
               repo: Optional[Repository]) -> None:
        """Absorb landed profiling outcomes in LAUNCH order, holding
        early arrivals back — concurrent init runs may complete in any
        order, but a session's observation sequence (and therefore its
        whole BO trajectory) must not depend on thread timing."""
        self._held[out.job.seq] = out
        errors: List[BaseException] = []
        while self._record_seq in self._held:
            nxt = self._held.pop(self._record_seq)
            self._record_seq += 1       # consume even if nxt errors
            try:
                self._record_one(nxt, repo)
            except BaseException as e:  # noqa: BLE001 — re-raised below
                # keep draining: a held successor outcome must not be
                # stranded (the executor already handed it over)
                errors.append(e)
        if errors:
            raise errors[0]

    def _record_one(self, out: ProfileOutcome,
                    repo: Optional[Repository]) -> None:
        """The bookkeeping half of core.bo._profile_into (execution
        happened in the executor)."""
        if out.error is not None:
            # settle the state machine BEFORE raising: the failed run is
            # simply absent from the observations, so a caller that
            # swallows the error keeps a live (not wedged) session
            self.inflight -= 1
            if self.inflight == 0:
                self.state = READY
            raise out.error
        obs = Observation(config=self.req.space.configs[out.job.ci],
                          x=self.xq_all[out.job.ci],
                          measures=out.measures, metrics=out.metrics)
        self.observations.append(obs)
        if self.is_moo:
            # no scalar incumbent under two objectives; the Pareto front
            # is assembled at result() time
            self.best_idx.append(len(self.observations) - 1)
        else:
            self.best_idx.append(_best_index_so_far(
                self.observations, self.req.objective, self.req.constraints))
        # publish only complete records: Algorithm-1 needs the metric
        # matrix, and a None-metrics record would poison the shared
        # CandidateIndex for every other tenant
        if (repo is not None and self.req.share_as is not None
                and obs.metrics is not None):
            repo.add_run(RunRecord(self.req.share_as, dict(obs.config),
                                   obs.metrics, obs.measures))
        self.inflight -= 1
        if self.inflight == 0:
            self.state = READY

    def init_candidates(self) -> List[int]:
        """'Prefill' picks: the random initialisation runs (§IV-B)."""
        n = min(self.cfg.n_init, len(self.req.space))
        return [int(ci) for ci in self.rng.choice(len(self.req.space),
                                                  size=n, replace=False)]

    def remaining(self) -> List[int]:
        return [i for i in range(len(self.req.space))
                if i not in self.profiled]

    def result(self) -> BOResult:
        self.meta["n_profiled"] = len(self.observations)
        if self.is_moo:
            self.meta["pareto_front"] = pareto_of_observations(
                self.observations, self.objectives, self.req.constraints)
        return BOResult(observations=self.observations,
                        best_index_per_iter=self.best_idx,
                        stopped_at=self.stopped_at, meta=self.meta)


class SearchService:
    """N concurrent tenant searches over one shared repository.

    ``submit`` -> rid; ``step`` advances every READY session one BO
    iteration (admitting queued sessions into free slots first) while
    WAITING_PROFILE sessions' runs execute on the ``executor``;
    ``collect`` drains finished searches; ``run`` loops until idle.

    ``wait_mode``:
      - ``"any"`` (default): a step scores whichever sessions' profiling
        results have landed; slow profilers never gate fast ones.
      - ``"all"``: a step first waits for every in-flight run — the
        synchronous round structure, but profiling runs still overlap
        each other on the executor.
    ``profile_timeout`` caps any blocking wait on the executor (seconds
    of wall clock, or virtual ticks on the fake); ``None`` waits until
    results land.
    ``fuse_posteriors`` (default True) collects every grid posterior of
    a step — targets, RGPE support stacks, MOO models — as
    ``PosteriorQuery`` nodes executed by the planned fused launches and
    uses the vectorised MC-EHVI; False restores the per-ensemble
    posterior loop and the per-candidate EHVI reference (the
    parity/benchmark baseline). ``fuse_samples`` (default True) does
    the same for the step's sample draws: RGPE support draws as
    ``SampleQuery``/``LooSampleQuery`` nodes and MOO EHVI
    sampling/evaluation as ``PosteriorDrawQuery``/``EhviQuery`` nodes;
    False restores the per-job / per-session loops. Fusion is visible
    in ``stats``: per-kind ``posterior_*`` / ``sample_*`` / ``ehvi_*``
    counters plus the aggregate ``plan_batches`` (fused launches) /
    ``plan_queries`` (query nodes they carried) across every planned
    round.
    """

    # how each plan-node kind rolls up into the service stats (the
    # sample-side kinds share one triple: they are all "draws the step
    # needed", whether from a support stack, a LOO target, or posterior
    # rows); the third element accumulates the kind's host-side
    # dispatch wall from the executor's per-bucket counters
    _STAT_KEYS = {"posterior": ("posterior_batches", "posterior_queries",
                                "posterior_wall_s"),
                  "sample": ("sample_batches", "sample_queries",
                             "sample_wall_s"),
                  "loo": ("sample_batches", "sample_queries",
                          "sample_wall_s"),
                  "draw": ("sample_batches", "sample_queries",
                           "sample_wall_s"),
                  "ehvi": ("ehvi_batches", "ehvi_jobs", "ehvi_wall_s"),
                  "fit": ("fit_batches", "fit_jobs", "fit_wall_s")}

    def __init__(self, repository: Optional[Repository] = None, *,
                 slots: int = 8, executor=None, wait_mode: str = "any",
                 profile_timeout: Optional[float] = None,
                 fuse_posteriors: bool = True, fuse_samples: bool = True,
                 planner: Optional[StepPlanner] = None,
                 plan_executor: Optional[PlanExecutor] = None,
                 mesh=None, data_axis: str = "data",
                 fit_steps: int = 120,
                 fit_warm_steps: Optional[int] = 16):
        if wait_mode not in ("any", "all"):
            raise ValueError(f"unknown wait_mode {wait_mode!r}")
        self.repo = repository if repository is not None else Repository()
        self.slots = slots
        self.executor = executor if executor is not None \
            else SyncProfileExecutor()
        self.wait_mode = wait_mode
        self.profile_timeout = profile_timeout
        self.fuse_posteriors = fuse_posteriors
        self.fuse_samples = fuse_samples
        # the incremental fit leg: models with cached hyperparameters
        # refit on the short warm rung, new/cold models pay the full
        # schedule. ``fit_warm_steps=None`` (or 0) disables warm starts
        # — every lane refits cold, the parity/benchmark baseline.
        self.fit_steps = int(fit_steps)
        self.fit_warm_steps = (int(fit_warm_steps)
                               if fit_warm_steps else 0)
        # ALL bucketing/padding policy lives in the planner; the service
        # only emits queries and scatters results. ``mesh`` constructs
        # BOTH defaults in sharded mode (lane pads rounded to shard
        # multiples, bucket launches shard-mapped over ``data_axis``) —
        # callers passing their own planner/executor own the pairing.
        self.planner = (planner if planner is not None
                        else StepPlanner(mesh=mesh, data_axis=data_axis))
        self.plan_executor = (
            plan_executor if plan_executor is not None
            else PlanExecutor(mesh=mesh, data_axis=data_axis))
        self.queue: List[_Session] = []
        self.active: Dict[int, _Session] = {}
        self.done: List[SearchCompletion] = []
        self._next_rid = 0
        # one KarasuContext (store + candidate index) per (space, noise):
        # support GPs depend on the encoder and the noise level only
        self._contexts: Dict[Tuple[Any, float], KarasuContext] = {}
        self.stats = {"steps": 0, "fit_batches": 0, "fit_jobs": 0,
                      "iterations": 0, "rgpe_batches": 0, "rgpe_jobs": 0,
                      "profile_waits": 0, "posterior_batches": 0,
                      "posterior_queries": 0, "sample_batches": 0,
                      "sample_queries": 0, "ehvi_batches": 0,
                      "ehvi_jobs": 0, "plan_batches": 0, "plan_queries": 0,
                      "plan_compile_misses": 0, "precompiled_buckets": 0,
                      "precompile_compiles": 0, "fit_wall_s": 0.0,
                      "posterior_wall_s": 0.0, "sample_wall_s": 0.0,
                      "ehvi_wall_s": 0.0, "plan_wall_s": 0.0,
                      "fit_warm_lanes": 0, "fit_cold_lanes": 0,
                      "fit_fused_batches": 0}
        # launch signatures covered by precompile() — empty until called
        self.precompiled_signatures: set = set()

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: SearchRequest) -> int:
        if req.method not in ("naive", "augmented", "karasu"):
            raise ValueError(f"unknown method {req.method!r}")
        if req.objectives is not None:
            if req.objective is not None:
                raise ValueError("pass either objective or objectives, "
                                 "not both")
            if len(req.objectives) < 2:
                raise ValueError("multi-objective serving needs "
                                 "objectives=[a, b, ...] (two or more)")
            if req.method == "augmented":
                raise ValueError("MOO supports methods naive|karasu")
        elif req.objective is None:
            raise ValueError("SearchRequest needs an objective "
                             "(or objectives=[a, b, ...])")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(_Session(rid, req))
        return rid

    def collect(self, *, wait: bool = False,
                timeout: Optional[float] = None) -> List[SearchCompletion]:
        """Drain finished searches. Non-blocking by default; with
        ``wait=True`` steps the service until at least one search
        finishes or ``timeout`` (seconds) elapses. A service with zero
        submitted searches always returns ``[]`` immediately."""
        if wait:
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while not self.done and (self.queue or self.active):
                if deadline is None:
                    self.step()
                    continue
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                # cap the executor waits inside step() so the overall
                # deadline is honored even while profilers are slow
                cap = (left if self.profile_timeout is None
                       else min(left, self.profile_timeout))
                self.step(profile_timeout=cap)
        out, self.done = self.done, []
        return out

    def context_for(self, session: _Session) -> KarasuContext:
        k = (session.space_key, session.cfg.noise)
        if k not in self._contexts:
            self._contexts[k] = KarasuContext(self.repo, session.req.space,
                                              noise=session.cfg.noise)
        return self._contexts[k]

    def close(self) -> None:
        self.executor.shutdown()

    # -- AOT bucket precompile ----------------------------------------------
    def precompile(self, limits: CohortLimits) -> Dict[str, int]:
        """Warm the jit cache for EVERY launch shape a cohort bounded by
        ``limits`` can produce, so serving runs at a zero-recompile
        steady state (asserted by ``stats['plan_compile_misses']``).

        The bucket vocabulary comes from the planner
        (``enumerate_buckets``); each bucket is driven through the REAL
        executor path with a dummy query pinned at the bucket's padded
        shape — executing (not just AOT-lowering) is deliberate: in
        current jax ``lower().compile()`` does not populate the jit call
        cache, and only the executed path exercises the identical impl
        routing and kernel dispatch serving will use. The target fit
        leg is part of the enumerated vocabulary (fit buckets walk both
        the warm and cold ``steps`` rungs); the legacy vmapped fit
        launches are ALSO warmed from the same limits — the support-
        model store still fits through ``fit_targets``. The padded
        ranking-loss launch (the RGPE scoring hot spot) is warmed over
        its limits-closed shape set too: its row count is the step's
        ensemble rows — at most ``max_lanes`` stacks of ``n_samples``
        draws — rounded by the lane policy, and its column count rounds
        like an observation axis. Returns ``{"buckets", "compiles"}``
        and folds both into ``stats``."""
        watch = CompileWatcher()
        buckets = self.planner.enumerate_buckets(limits)
        for bucket in buckets:
            queries, prep = self._dummy_bucket(bucket, limits)
            self.plan_executor.execute(StepPlan(
                queries,
                [dataclasses.replace(
                    bucket, indices=tuple(range(len(queries))))],
                prep))
        for noise in limits.noises:
            for n_pad in self.planner._obs_pads(limits.max_obs):
                for m_pad in self.planner._lane_pads(limits.max_lanes):
                    self.planner.fit_targets(
                        [np.zeros((n_pad, limits.d), np.float32)] * m_pad,
                        [np.arange(n_pad, dtype=np.float32)] * m_pad,
                        noise=noise, steps=limits.fit_steps)
        if limits.n_samples:
            # the launch's impl is jit-static and comes from the
            # tenants' BOConfig.kernel_impl; the cohort default ("xla")
            # is the warmed vocabulary — a per-tenant Pallas override
            # opts out of the zero-recompile claim for this leg
            launch = ranking_loss_launch_fn(donate=self.plan_executor.donate)
            row_pads = sorted({self.planner.round_models(k * s)
                               for s in limits.n_samples
                               for k in range(1, limits.max_lanes + 1)})
            for n_pad in self.planner._obs_pads(limits.max_obs):
                for r_pad in row_pads:
                    launch(jnp.zeros((r_pad, n_pad), jnp.float32),
                           jnp.zeros((r_pad, n_pad), jnp.float32),
                           jnp.zeros((r_pad,), jnp.int32), impl="xla")
        self.precompiled_signatures = {
            self.planner.launch_signature(b) for b in buckets}
        compiles = watch.misses()
        self.stats["precompiled_buckets"] += len(buckets)
        self.stats["precompile_compiles"] += compiles
        return {"buckets": len(buckets), "compiles": compiles}

    def _dummy_bucket(self, bucket, limits: CohortLimits):
        """Owner-less queries pinned at an enumerated bucket's padded
        shape (every padded length is a fixed point of the rounding
        policy, so the executor launches exactly the enumerated
        program). Values are immaterial — only shapes compile."""
        noise = limits.noises[0]
        d = limits.d
        kind, key, pads = bucket.kind, bucket.key, bucket.pads
        if kind == "posterior":
            stack = self._dummy_stack(pads["m_pad"], pads["n_pad"], d,
                                      noise)
            return [PosteriorQuery(stack, np.zeros((key[0], d),
                                                   np.float32))], {}
        if kind == "sample":
            s, q_pad, _ = key
            stack = self._dummy_stack(pads["m_pad"], pads["n_pad"], d,
                                      noise)
            keys = jax.random.split(jax.random.PRNGKey(0), pads["m_pad"])
            return [SampleQuery(stack, np.zeros((q_pad, d), np.float32),
                                keys, s)], {}
        if kind == "loo":
            s, n_pad = key
            gp = GP(jnp.zeros((n_pad, d), jnp.float32),
                    jnp.zeros((n_pad,)), jnp.zeros((n_pad,)),
                    jnp.zeros(()), jnp.ones(()),
                    GPParams(jnp.zeros((d,)), jnp.zeros(()), noise),
                    jnp.eye(n_pad, dtype=jnp.float32),
                    jnp.zeros((n_pad,)))
            return [LooSampleQuery(gp, jax.random.PRNGKey(0), s)
                    for _ in range(pads["l_pad"])], {}
        if kind == "ehvi":
            n_obj, s, q_pad = key
            box = (np.zeros((1, n_obj)), np.ones((1, n_obj)))
            if self.plan_executor.fused_ehvi:
                # posterior form: the dummy must drive the SAME fused
                # launch (and eps draw dispatch) serving will, at the
                # full lane count
                queries = [EhviQuery(
                    None, np.ones((1, n_obj)), np.full((n_obj,), 2.0),
                    mu=tuple(np.zeros((q_pad,), np.float32)
                             for _ in range(n_obj)),
                    var=tuple(np.ones((q_pad,), np.float32)
                              for _ in range(n_obj)),
                    y_mean=(0.0,) * n_obj, y_std=(1.0,) * n_obj,
                    keys=tuple(jax.random.PRNGKey(0)
                               for _ in range(n_obj)),
                    n_mc=s) for _ in range(pads["l_pad"])]
            else:
                samples = tuple(np.zeros((s, q_pad), np.float32)
                                for _ in range(n_obj))
                queries = [EhviQuery(samples, np.ones((1, n_obj)),
                                     np.full((n_obj,), 2.0))
                           for _ in range(pads["l_pad"])]
            return queries, {i: box for i in range(len(queries))}
        if kind == "fit":
            d_, steps, noise_ = key
            # nonzero distinct y: the packing standardises per lane and
            # clamps y_std, so any values compile — but a spread keeps
            # the dummy on the same numeric path as live data
            return [FitQuery(np.zeros((pads["n_pad"], d_), np.float32),
                             np.arange(pads["n_pad"], dtype=np.float32),
                             noise_, steps)
                    for _ in range(pads["m_pad"])], {}
        raise ValueError(f"unknown bucket kind {kind!r}")

    @staticmethod
    def _dummy_stack(m: int, n: int, d: int, noise: float) -> BatchedGP:
        eye = jnp.broadcast_to(jnp.eye(n, dtype=jnp.float32)[None],
                               (m, n, n))
        return BatchedGP(jnp.zeros((m, n, d), jnp.float32),
                         jnp.zeros((m, n)), jnp.ones((m, n)),
                         jnp.zeros((m,)), jnp.ones((m,)),
                         jnp.zeros((m, d)), jnp.zeros((m,)), noise,
                         eye, jnp.zeros((m, n)),
                         jnp.full((m,), n, jnp.int32))

    # -- scheduling internals -----------------------------------------------
    def _admit(self) -> None:
        while self.queue and len(self.active) < self.slots:
            s = self.queue.pop(0)
            self.active[s.rid] = s
            for ci in s.init_candidates():
                self.executor.submit(s.launch(ci, "init"),
                                     s.req.profile_fn)

    def _absorb(self, outcomes: List[ProfileOutcome]) -> None:
        """Record a batch of outcomes. One tenant's profiling error must
        not drop the rest of the batch (the executor already popped it),
        so every outcome is recorded before the first error re-raises."""
        errors: List[BaseException] = []
        for out in outcomes:
            try:
                self.active[out.job.rid].record(out, self.repo)
            except BaseException as e:          # noqa: BLE001 — re-raised
                errors.append(e)
        if errors:
            raise errors[0]

    def _finish(self, s: _Session) -> None:
        del self.active[s.rid]
        self.done.append(SearchCompletion(s.rid, s.result()))

    # -- one scheduling round -----------------------------------------------
    def step(self, *, profile_timeout: Optional[float] = None) -> int:
        """Admit queued sessions, absorb landed profiling results, then
        advance each READY session one BO iteration with the target fits
        and RGPE weightings batched across tenants. Returns the number
        of sessions whose next profiling run was launched.
        ``profile_timeout`` overrides the service-level default for this
        step's blocking executor waits (used by ``collect(wait=True)``
        to honor its own deadline)."""
        wait_t = (self.profile_timeout if profile_timeout is None
                  else profile_timeout)
        # one deadline for the WHOLE step: wait_mode="all" may wait twice
        # (drain, then collect), and the budget must not double
        deadline = (None if wait_t is None
                    else time.monotonic() + wait_t)

        def left() -> Optional[float]:
            return (None if deadline is None
                    else max(0.0, deadline - time.monotonic()))

        self.stats["steps"] += 1
        # any compile of a tracked plan launch during this step is a
        # steady-state violation candidate — surfaced, never silent
        compile_watch = CompileWatcher()
        self._admit()
        self._absorb(self.executor.poll())
        if self.wait_mode == "all" and self.executor.pending():
            self._absorb(self.executor.drain(left()))

        ready = self._ready_sessions()
        if not ready and self.executor.pending():
            # every active session is WAITING_PROFILE: block until at
            # least one result lands rather than spinning
            self.stats["profile_waits"] += 1
            self._absorb(self.executor.collect(left()))
            ready = self._ready_sessions()

        # a session whose completed runs ALL errored has nothing to fit:
        # re-admit it with a fresh random candidate instead of scoring
        # (failed candidates stay reserved in `profiled`, never retried)
        for s, rem in ready:
            if not s.observations:
                ci = rem[int(s.rng.integers(len(rem)))]
                self.executor.submit(s.launch(ci, "init"),
                                     s.req.profile_fn)
        ready = [(s, rem) for s, rem in ready if s.observations]
        if not ready:
            self._absorb(self.executor.poll())
            self.stats["plan_compile_misses"] += compile_watch.misses()
            return 0

        # the model math of the step: two planned rounds over the query
        # layer (collect -> plan -> execute -> scatter); the second
        # consumes the first's scattered posteriors
        posts = self._posterior_phase([s for s, _ in ready])
        moo_acq = self._moo_phase(
            [(s, rem) for s, rem in ready if s.is_moo], posts)

        advanced = 0
        for s, rem in ready:
            if s.is_moo:
                # MC-EHVI x PoF; no scalar incumbent, so no early stop
                acq = moo_acq[s.rid]
            else:
                acq, best_raw, obj_post = _acquisition(
                    posts[s.rid], s.observations, s.req.objective,
                    s.req.constraints)
                acq = acq[np.asarray(rem)]

                if _should_stop_early(s.cfg, len(s.observations), acq,
                                      obj_post, best_raw):
                    s.stopped_at = len(s.observations)
                    self._finish(s)
                    continue

            self.executor.submit(s.launch(rem[int(np.argmax(acq))]),
                                 s.req.profile_fn)
            advanced += 1
            self.stats["iterations"] += 1

        # with a synchronous executor every launch has already landed;
        # absorbing here preserves the one-step-one-iteration semantics
        self._absorb(self.executor.poll())
        for s in list(self.active.values()):
            if s.state == READY and len(s.observations) >= s.cfg.max_iters:
                self._finish(s)
        self.stats["plan_compile_misses"] += compile_watch.misses()
        return advanced

    def _ready_sessions(self) -> List[Tuple[_Session, List[int]]]:
        """READY sessions that still have work, finishing exhausted ones
        (max_iters reached or the whole space profiled)."""
        out: List[Tuple[_Session, List[int]]] = []
        for s in list(self.active.values()):
            if s.state != READY:
                continue
            if len(s.observations) >= s.cfg.max_iters:
                self._finish(s)
                continue
            rem = s.remaining()
            if not rem:
                s.stopped_at = len(s.observations)
                self._finish(s)
                continue
            out.append((s, rem))
        return out

    def _count_plan(self, counters: Dict[str, Dict[str, int]]) -> None:
        """Roll one planned round's per-kind counters into the service
        stats: the per-kind triples (``_STAT_KEYS``) plus the aggregate
        ``plan_batches``/``plan_queries``/``plan_wall_s``."""
        for kind, c in counters.items():
            bk, qk, wk = self._STAT_KEYS[kind]
            self.stats[bk] += c.get("launches", 0)
            self.stats[qk] += c.get("queries", 0)
            self.stats[wk] += c.get("wall_s", 0.0)
            self.stats["plan_batches"] += c.get("launches", 0)
            self.stats["plan_queries"] += c.get("queries", 0)
            self.stats["plan_wall_s"] += c.get("wall_s", 0.0)

    @staticmethod
    def _regroup_fit(entries: List[Tuple[BatchedGP, int]],
                     noise: float) -> BatchedGP:
        """Assemble one (space, noise) group's target stack from the
        fit round's per-query ``(bucket stack, lane)`` results. Warm
        and cold lanes of a group come back in DIFFERENT bucket stacks
        (the schedule length is part of the bucket key), possibly at
        different observation pads — re-pad to the common maximum
        (``_pad_stack_obs``'s exactness contract) and gather each
        lane's rows, preserving the group's owner order."""
        n_max = max(st.n_max for st, _ in entries)
        padded: Dict[int, Tuple] = {}
        rows: Dict[str, List[Any]] = {k: [] for k in (
            "x", "y", "mask", "y_mean", "y_std", "ls", "sf", "chol",
            "alpha", "cnt")}
        for st, ln in entries:
            c = padded.get(id(st))
            if c is None:
                p = n_max - st.n_max
                x, mask, chol, alpha = _pad_stack_obs(st, n_max)
                y = jnp.pad(st.y, ((0, 0), (0, p))) if p else st.y
                c = (x, y, mask, chol, alpha)
                padded[id(st)] = c
            x, y, mask, chol, alpha = c
            rows["x"].append(x[ln])
            rows["y"].append(y[ln])
            rows["mask"].append(mask[ln])
            rows["chol"].append(chol[ln])
            rows["alpha"].append(alpha[ln])
            rows["y_mean"].append(st.y_mean[ln])
            rows["y_std"].append(st.y_std[ln])
            rows["ls"].append(st.log_lengthscales[ln])
            rows["sf"].append(st.log_signal[ln])
            rows["cnt"].append(st.counts[ln])
        return BatchedGP(
            jnp.stack(rows["x"]), jnp.stack(rows["y"]),
            jnp.stack(rows["mask"]), jnp.stack(rows["y_mean"]),
            jnp.stack(rows["y_std"]), jnp.stack(rows["ls"]),
            jnp.stack(rows["sf"]), noise, jnp.stack(rows["chol"]),
            jnp.stack(rows["alpha"]), jnp.stack(rows["cnt"]))

    def _posterior_phase(self, sessions: List[_Session]
                         ) -> Dict[int, Dict[str, Dict]]:
        """COLLECT every model query of the step in two planned rounds.
        The FIT round first: one ``FitQuery`` per (session, measure)
        target model across all (space, noise) groups — warm lanes
        (hyperparameters cached from the previous step) on the short
        refine rung, cold lanes on the full schedule — executed as one
        ``kernels.fused_fit`` launch per (d, steps, noise) bucket, then
        regrouped into per-group target stacks. Then the POSTERIOR
        round: every grid-posterior query — target stacks, every karasu
        ensemble's support stack, MOO models, all tenants — planned
        into fused buckets, one launch per bucket, rows scattered back
        to their owning (session, measure) slots. RGPE weights score
        between collect and scatter (one padded ranking-loss launch per
        kernel impl, its sample draws planned through the same layer).
        With ``fuse_posteriors=False`` the posterior half degrades to
        the historical per-group + per-ensemble loop (the fit round
        still plans)."""
        groups: Dict[Tuple[Any, float], List[_Session]] = {}
        posts: Dict[int, Dict[str, Dict]] = {}
        for s in sessions:
            if s.req.method == "augmented":
                # Extra-Trees have no batched path; keep them per-session
                posts[s.rid] = _model_posteriors_augmented(
                    s.observations, s.measures, s.cfg, s.xq_all, s.req.seed)
                continue
            groups.setdefault((s.space_key, s.cfg.noise), []).append(s)

        # -- collect: the fit round ------------------------------------------
        # one FitQuery per (session, measure) model across ALL groups —
        # warm lanes (cached hyperparameters) ask for the short refine
        # rung, cold lanes the full schedule; the planner buckets them
        # by (d, steps, noise) and the executor runs ONE fused launch
        # per bucket, so a step's whole fit leg is a handful of
        # ``kernels.fused_fit`` launches instead of a vmapped 120-step
        # Adam per group
        fit_queries: List[FitQuery] = []
        fit_owners: List[Tuple[_Session, str]] = []
        group_lanes: Dict[Tuple[Any, float], List[int]] = {}
        for gk, group in groups.items():
            noise = gk[1]
            lanes = group_lanes.setdefault(gk, [])
            for s in group:
                x = np.stack([o.x for o in s.observations])
                for m in s.measures:
                    y = np.array([o.measures[m] for o in s.observations])
                    entry = (s.fit_cache.get(m) if self.fit_warm_steps
                             else None)
                    if entry is not None:
                        self.stats["fit_warm_lanes"] += 1
                        q = FitQuery(x, y, noise, self.fit_warm_steps,
                                     init_ls=entry[1], init_sf=entry[2])
                    else:
                        self.stats["fit_cold_lanes"] += 1
                        q = FitQuery(x, y, noise, self.fit_steps)
                    lanes.append(len(fit_queries))
                    fit_queries.append(q)
                    fit_owners.append((s, m))
        fc: Dict[str, Dict[str, int]] = {}
        fit_res = self.plan_executor.execute(
            self.planner.plan(fit_queries), counters=fc)
        self._count_plan(fc)
        self.stats["fit_fused_batches"] += \
            fc.get("fit", {}).get("launches", 0)
        # refresh every lane's warm-start cache from the fitted stacks
        # (one host transfer per bucket stack, not per lane)
        host: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for (s, m), (st, ln) in zip(fit_owners, fit_res):
            h = host.get(id(st))
            if h is None:
                h = (np.asarray(st.log_lengthscales),
                     np.asarray(st.log_signal))
                host[id(st)] = h
            s.fit_cache[m] = (len(s.observations), h[0][ln], h[1][ln])

        # -- collect: posteriors over the fitted stacks ----------------------
        # (session, measure, bases, WeightJob) across ALL groups
        rgpe_jobs: List[Tuple[_Session, str, Any, WeightJob]] = []
        queries: List[PosteriorQuery] = []
        for gk, group in groups.items():
            noise = gk[1]
            owners = [(s, m) for s in group for m in s.measures]
            tgts = self._regroup_fit(
                [fit_res[i] for i in group_lanes[gk]], noise)

            xq_all = group[0].xq_all
            if self.fuse_posteriors:
                queries.append(PosteriorQuery(
                    tgts, xq_all,
                    owner=lambda res, o=owners, t=tgts:
                        _absorb_target_posts(posts, o, t, *res)))
            else:
                mu_all, var_all = batched_posterior(tgts, xq_all)
                _absorb_target_posts(posts, owners, tgts, mu_all, var_all)

            for s in group:
                if s.req.method == "karasu":
                    rgpe_jobs.extend(self._rgpe_jobs(s, tgts, owners))

        weights = self._score_weights(rgpe_jobs)

        if not self.fuse_posteriors:
            for i, (s, m, bases, _job) in enumerate(rgpe_jobs):
                self._mix_rgpe(s, m, bases, weights[i], posts[s.rid])
            return posts

        # support stacks join the targets' queries; the executor fires
        # owners in query order, so mixes overlay the target rows the
        # earlier queries already absorbed into ``posts``
        for i, (s, m, bases, _job) in enumerate(rgpe_jobs):
            queries.append(PosteriorQuery(
                bases, s.xq_all,
                owner=lambda res, s=s, m=m, w=weights[i]:
                    self._mix_into(posts, s, m, w, res)))
        if not queries:
            return posts

        # -- plan / execute / scatter (owner callbacks) ----------------------
        counters: Dict[str, Dict[str, int]] = {}
        self.plan_executor.execute(self.planner.plan(queries),
                                   counters=counters)
        self._count_plan(counters)
        return posts

    def _score_weights(self, rgpe_jobs) -> Dict[int, Any]:
        """ONE padded ranking-loss launch for every ensemble of the step
        (per kernel impl — sessions normally share one); the jobs'
        sample draws ride the shared planner."""
        weights: Dict[int, Any] = {}
        by_impl: Dict[str, List[int]] = {}
        for idx, (s, *_rest) in enumerate(rgpe_jobs):
            by_impl.setdefault(s.cfg.kernel_impl, []).append(idx)
        for impl, idxs in by_impl.items():
            sc: Dict[str, int] = {}
            ws = KarasuContext.score_ensembles(
                [rgpe_jobs[i][3] for i in idxs], impl=impl,
                fuse_samples=self.fuse_samples, sample_counters=sc,
                planner=self.planner, plan_executor=self.plan_executor)
            self.stats["rgpe_batches"] += 1
            self.stats["rgpe_jobs"] += len(idxs)
            self.stats["sample_batches"] += sc.get("launches", 0)
            self.stats["sample_queries"] += sc.get("queries", 0)
            self.stats["sample_wall_s"] += sc.get("wall_s", 0.0)
            self.stats["plan_batches"] += sc.get("launches", 0)
            self.stats["plan_queries"] += sc.get("queries", 0)
            self.stats["plan_wall_s"] += sc.get("wall_s", 0.0)
            for i, w in zip(idxs, ws):
                weights[i] = w
        return weights

    @staticmethod
    def _mix_into(posts, s: _Session, m: str, w, res) -> None:
        """Owner callback of an RGPE support-stack query: overlay the
        weighted mixture on the already-scattered target posterior."""
        mu, var = res
        p = posts[s.rid][m]
        mu_m, var_m = mix_weighted(mu, var, p["mu"], p["var"], w)
        posts[s.rid][m] = {"mu": mu_m, "var": var_m,
                           "y_mean": p["y_mean"], "y_std": p["y_std"],
                           "weights": np.asarray(w)}

    def _rgpe_jobs(self, s: _Session, tgts, owners
                   ) -> List[Tuple[_Session, str, Any, WeightJob]]:
        """Queue one weighting job per measure whose support stack is
        usable; key split matches the sequential path exactly."""
        ctx = self.context_for(s)
        # a tenant must never pick its own published runs as "support":
        # they would score ~1.0 against themselves and sidestep the LOO
        # sampling that keeps the target honest on its training points
        exclude = (s.req.share_as,) if s.req.share_as else None
        selected = ctx.candidate_index().query(
            _target_runs(s.observations), s.cfg.n_support,
            impl=s.cfg.kernel_impl, exclude=exclude)
        s.meta["selected"].append([z for z, _ in selected])
        if not selected:
            return []
        it = len(s.observations)
        job_of = {m: ji for ji, (o, m) in enumerate(owners) if o is s}
        jobs = []
        for mi, m in enumerate(s.measures):
            bases, _ids = ctx.store.get_stacked([z for z, _ in selected], m)
            if bases is None:
                continue
            key = derive_key(s.key, KEY_PURPOSE_RGPE, it, mi)
            jobs.append((s, m, bases,
                         WeightJob(bases, tgts.extract(job_of[m]), key,
                                   s.cfg.rgpe_samples)))
        return jobs

    def _mix_rgpe(self, s: _Session, m: str, bases, w, post) -> None:
        """Replace one (session, measure) plain target posterior with the
        RGPE mixture built from the shared support store — the
        per-ensemble posterior loop (``fuse_posteriors=False`` only; the
        fused plan queries every stack in one launch instead)."""
        mu_b, var_b = batched_posterior(bases, s.xq_all)
        mu, var = mix_weighted(mu_b, var_b, post[m]["mu"], post[m]["var"], w)
        post[m] = {"mu": mu, "var": var,
                   "y_mean": post[m]["y_mean"],
                   "y_std": post[m]["y_std"],
                   "weights": np.asarray(w)}

    @staticmethod
    def _moo_front_ref(s: _Session) -> Tuple[np.ndarray, np.ndarray]:
        """The (observed, ref) pair EHVI is computed against: feasible
        observations (all, if none feasible yet) and the 1.1-scaled
        nadir — one rule shared by the fused and loop paths, any
        objective count."""
        names = [o.name for o in s.objectives]
        feas = [o for o in s.observations
                if _feasible(o, s.req.constraints)] or s.observations
        observed = np.array([[o.measures[n] for n in names] for o in feas])
        return observed, observed.max(axis=0) * 1.1 + 1e-9

    def _apply_pof(self, s: _Session, post: Dict[str, Dict],
                   idx: np.ndarray, acq: np.ndarray) -> np.ndarray:
        """Weight an EHVI row by every constraint's probability of
        feasibility — the scatter step both MOO paths share."""
        acq = np.asarray(acq)
        for c in s.req.constraints:
            cp = post[c.name]
            ub_std = (c.upper_bound - cp["y_mean"]) / cp["y_std"]
            pof = np.asarray(probability_of_feasibility(
                cp["mu"][idx], cp["var"][idx], float(ub_std)))
            acq = acq * pof
        return acq

    def _moo_phase(self, moo_ready: List[Tuple[_Session, List[int]]],
                   posts: Dict[int, Dict[str, Dict]]
                   ) -> Dict[int, np.ndarray]:
        """MC-EHVI x PoF for EVERY MOO session of the step (paper
        §III-D), fed by the scattered grid posteriors. Two further
        planned rounds: COLLECT one ``PosteriorDrawQuery`` per (session,
        objective) lane (fused draw launch per (n_mc, n_rem) bucket),
        scatter the draws, then COLLECT one ``EhviQuery`` per session
        (fused box-decomposition launch per (n_obj, S, q) bucket — 2-
        and n>=3-objective sessions just land in different buckets) and
        scatter the acquisition rows through the PoF weighting.
        ``fuse_samples=False`` restores the per-session sampling + numpy
        EHVI loop (the parity/bench baseline). Keys derive per
        (MOO_EHVI, iteration, objective), so fusion order can never
        change a session's draws."""
        if not moo_ready:
            return {}
        if not self.fuse_samples:
            return {s.rid: self._moo_acquisition(s, posts[s.rid], rem)
                    for s, rem in moo_ready}
        if self.plan_executor.fused_ehvi:
            return self._moo_phase_fused(moo_ready, posts)

        # -- collect / plan / execute / scatter: the draw round --------------
        samples: Dict[int, List[Optional[np.ndarray]]] = {
            s.rid: [None] * len(s.objectives) for s, _ in moo_ready}
        draw_queries: List[PosteriorDrawQuery] = []
        for s, rem in moo_ready:
            idx = np.asarray(rem)
            it = len(s.observations)
            for oi, obj in enumerate(s.objectives):
                p = posts[s.rid][obj.name]
                k = derive_key(s.key, KEY_PURPOSE_MOO_EHVI, it, oi)
                draw_queries.append(PosteriorDrawQuery(
                    p["mu"][idx], p["var"][idx], p["y_mean"], p["y_std"],
                    k, s.req.n_mc,
                    owner=lambda d, rid=s.rid, oi=oi:
                        samples[rid].__setitem__(oi, np.asarray(d))))
        dc: Dict[str, Dict[str, int]] = {}
        self.plan_executor.execute(self.planner.plan(draw_queries),
                                   counters=dc)
        self._count_plan(dc)

        # -- collect / plan / execute / scatter: the EHVI round --------------
        out: Dict[int, np.ndarray] = {}
        ehvi_queries = []
        for s, rem in moo_ready:
            observed, ref = self._moo_front_ref(s)
            ehvi_queries.append(EhviQuery(
                tuple(samples[s.rid]), observed, ref,
                owner=lambda acq, s=s, rem=rem:
                    out.__setitem__(s.rid, self._apply_pof(
                        s, posts[s.rid], np.asarray(rem), acq))))
        ec: Dict[str, Dict[str, int]] = {}
        self.plan_executor.execute(self.planner.plan(ehvi_queries),
                                   counters=ec)
        self._count_plan(ec)
        return out

    def _moo_phase_fused(self, moo_ready: List[Tuple[_Session, List[int]]],
                         posts: Dict[int, Dict[str, Dict]]
                         ) -> Dict[int, np.ndarray]:
        """The fused-EHVI MOO round: ONE planned round instead of two —
        each session emits a posterior-form ``EhviQuery`` and the draw
        affine runs inside the ``kernels.fused_ehvi`` launch, so the
        per-objective (S, q) draw tensors never round-trip through HBM.
        Keys derive per (MOO_EHVI, iteration, objective) exactly as the
        draw round does, so switching the executor to ``fused_ehvi``
        never changes a session's draws or its acquisition."""
        out: Dict[int, np.ndarray] = {}
        ehvi_queries = []
        for s, rem in moo_ready:
            idx = np.asarray(rem)
            it = len(s.observations)
            observed, ref = self._moo_front_ref(s)
            ps = [posts[s.rid][obj.name] for obj in s.objectives]
            ehvi_queries.append(EhviQuery(
                None, observed, ref,
                mu=tuple(p["mu"][idx] for p in ps),
                var=tuple(p["var"][idx] for p in ps),
                y_mean=tuple(float(p["y_mean"]) for p in ps),
                y_std=tuple(float(p["y_std"]) for p in ps),
                keys=tuple(
                    derive_key(s.key, KEY_PURPOSE_MOO_EHVI, it, oi)
                    for oi in range(len(s.objectives))),
                n_mc=s.req.n_mc,
                owner=lambda acq, s=s, rem=rem:
                    out.__setitem__(s.rid, self._apply_pof(
                        s, posts[s.rid], np.asarray(rem), acq))))
        ec: Dict[str, Dict[str, int]] = {}
        self.plan_executor.execute(self.planner.plan(ehvi_queries),
                                   counters=ec)
        self._count_plan(ec)
        return out

    def _moo_acquisition(self, s: _Session, post: Dict[str, Dict],
                         rem: List[int]) -> np.ndarray:
        """The per-session MC-EHVI x PoF loop (``fuse_samples=False``
        only — the fused path plans all sessions' draws and EHVI
        evaluations instead). Same key schedule and front rule as the
        fused path, so both produce the same acquisition up to float
        roundoff. Two objectives keep the staircase references
        (vectorised when ``fuse_posteriors``, the per-candidate
        ``_hv_2d`` loop otherwise); n >= 3 use the recursive-sweep
        ``mc_ehvi_nd`` oracle — the parity baseline of the fused box
        decomposition."""
        idx = np.asarray(rem)
        it = len(s.observations)
        samples = []
        for oi, obj in enumerate(s.objectives):
            p = post[obj.name]
            k = derive_key(s.key, KEY_PURPOSE_MOO_EHVI, it, oi)
            eps = jax.random.normal(k, (s.req.n_mc, len(rem)))
            sm = p["mu"][idx][None] + eps * jnp.sqrt(p["var"][idx])[None]
            samples.append(np.asarray(sm * p["y_std"] + p["y_mean"]))
        observed, ref = self._moo_front_ref(s)
        if len(s.objectives) == 2:
            ehvi = mc_ehvi_batched if self.fuse_posteriors else mc_ehvi
            acq = np.asarray(ehvi(samples[0], samples[1], observed, ref))
        else:
            acq = mc_ehvi_nd(samples, observed, ref)
        return self._apply_pof(s, post, idx, acq)

    # -- driver -------------------------------------------------------------
    def run(self, max_steps: int = 10_000) -> List[SearchCompletion]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.collect()
