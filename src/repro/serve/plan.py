"""The serving layer's view of the query-plan layer.

The implementation lives in ``repro.core.plan`` (the drivers —
``run_search``, ``run_search_moo``, ``KarasuContext.score_ensembles`` —
route through it too, and ``core`` must not import up into ``serve``);
this module re-exports it under the serving namespace so
``SearchService`` and service-layer tooling have one canonical import
for the step lifecycle:

    collect  — every ready session emits query nodes (owner-tagged)
    plan     — ``StepPlanner.plan`` groups them into fused buckets and
               fixes every pad decision (the ONLY home of shape policy)
    execute  — ``PlanExecutor.execute`` runs one launch per bucket
    scatter  — results return in query order / callable owners fire

See ``repro.core.plan`` for the node table and the exact-padding
contract.
"""
from repro.core.plan import (GRID_ROUND_TO, M_ROUND_POW2, OBS_ROUND_TO,
                             Bucket, CohortLimits, EhviQuery, FitQuery,
                             LooSampleQuery, PlanExecutor,
                             PosteriorDrawQuery, PosteriorQuery,
                             SampleQuery, StepPlan, StepPlanner)

__all__ = [
    "OBS_ROUND_TO", "GRID_ROUND_TO", "M_ROUND_POW2",
    "Bucket", "CohortLimits", "StepPlan", "StepPlanner", "PlanExecutor",
    "PosteriorQuery", "SampleQuery", "LooSampleQuery",
    "PosteriorDrawQuery", "EhviQuery", "FitQuery",
]
