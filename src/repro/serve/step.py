"""Serving step factories: prefill (cache build) and decode.

prefill_step consumes the full prompt, writes the KV/state caches and
returns last-position logits; decode_step consumes one new token per
sequence against the cache and returns (next_token, logits, caches).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import ModelBundle


def make_prefill_step(bundle: ModelBundle):
    def prefill_step(params, caches, tokens, positions):
        logits, new_caches = bundle.decode_step(params, caches, tokens,
                                                positions)
        return logits[:, -1:], new_caches
    return prefill_step


def make_decode_step(bundle: ModelBundle, *, temperature: float = 0.0):
    def decode_step(params, caches, tokens, positions, rng=None):
        logits, new_caches = bundle.decode_step(params, caches, tokens,
                                                positions)
        last = logits[:, -1]
        if temperature > 0.0 and rng is not None:
            next_tok = jax.random.categorical(rng, last / temperature)
        else:
            next_tok = jnp.argmax(last, axis=-1)
        return next_tok.astype(jnp.int32), last, new_caches
    return decode_step
