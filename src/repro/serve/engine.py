"""Continuous-batching serving engine.

A request is (prompt tokens, max_new_tokens). The engine keeps a fixed
pool of decode slots backed by one shared KV/state cache; finished
sequences free their slot, and queued requests are admitted by a prefill
that writes into the freed slot's cache rows. One decode step advances
every active slot (the classic iteration-level scheduling of Orca/vLLM,
mapped to fixed-shape JAX: slot count and cache length are static, slot
occupancy is a mask).

Compile-once steady state: prefill pads the prompt to a
``PREFILL_ROUND_TO`` length bucket (positions keep counting through the
pad, so the padded rows are causally masked by every later query until
decode overwrites them in place), and the decode step masks FREE slots'
cache writes out entirely — a freed slot's rows stay bit-identical
until re-admission, and slot occupancy changing never retraces. The jit
caches therefore stabilise at one prefill entry per prompt-length
bucket plus one decode entry, observable via ``compile_stats()``.
Prompt padding is only sound for attention blocks (padded rows are
dead weight the causal mask hides); recurrent blocks (mamba2 / mlstm /
slstm) fold every prefill token into their running state, so hybrid
and SSM models keep exact-length prefill.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelBundle

# prompt lengths bucket to multiples of this before the prefill jit —
# the serving twin of the query plan's round-to-8 observation axis
PREFILL_ROUND_TO = 8

# block kinds whose decode path reads the cache purely through the
# causal position mask — the only kinds prompt padding is exact for
_PAD_SAFE_KINDS = frozenset({"attn", "local_attn", "shared_attn"})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, *, slots: int = 4,
                 max_len: int = 512, extras: Optional[Dict] = None):
        self.bundle = bundle
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.caches = bundle.init_cache(params, slots, max_len,
                                        batch=extras or {},
                                        dtype=jnp.float32)
        self._decode = jax.jit(bundle.decode_step)
        self._pad_prefill = (
            frozenset(bundle.cfg.layer_kinds) <= _PAD_SAFE_KINDS)
        # ring caches evict oldest rows: a padded prompt close to the
        # window would push still-needed real rows out, so those prompts
        # fall back to exact-length prefill (checked per request)
        self._window = (bundle.cfg.window
                        if "local_attn" in bundle.cfg.layer_kinds else 0)

        def masked_decode(params, caches, tokens, positions, lane_mask):
            logits, new = bundle.decode_step(params, caches, tokens,
                                             positions)
            # batch lives at axis 1 of every cache leaf; free lanes keep
            # their old rows bit for bit
            merged = jax.tree.map(
                lambda old, upd: jnp.where(
                    lane_mask.reshape((1, -1) + (1,) * (old.ndim - 2)),
                    upd, old),
                caches, new)
            return logits, merged

        self._masked_decode = jax.jit(masked_decode)
        self.free: List[int] = list(range(slots))
        self.active: Dict[int, dict] = {}     # slot -> request state
        self.queue: List[Request] = []
        self.done: List[Completion] = []

    def compile_stats(self) -> Dict[str, int]:
        """Jit-cache entry counts: ``prefill_compiles`` is one per
        prompt-length bucket seen, ``decode_compiles`` one total in
        steady state (slot occupancy is a traced mask, not a shape)."""
        def size(fn):
            s = getattr(fn, "_cache_size", None)
            return int(s()) if callable(s) else 0

        return {"prefill_compiles": size(self._decode),
                "decode_compiles": size(self._masked_decode)}

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.free:
            req = self.queue.pop(0)
            slot = self.free.pop(0)
            # prefill into an isolated batch-1 view of this slot's cache
            # rows, then write the updated rows back — other slots' caches
            # are untouched (slot isolation).
            tokens = req.prompt.astype(np.int32)
            n_real = int(tokens.shape[0])
            if self._pad_prefill:
                # pad to the length bucket so the prefill jit cache
                # stabilises; positions keep counting through the pad, so
                # the padded rows are causally invisible to every later
                # decode step until it overwrites them in place
                pad = (-n_real) % PREFILL_ROUND_TO
                if self._window and n_real + pad > self._window:
                    pad = 0
                if pad:
                    tokens = np.pad(tokens, (0, pad))
            prompt = jnp.asarray(tokens)[None, :]
            positions = jnp.arange(prompt.shape[1], dtype=jnp.int32)[None]
            sub = jax.tree.map(lambda x: x[:, slot:slot + 1], self.caches)
            logits, sub = self._decode(self.params, sub, prompt, positions)
            self.caches = jax.tree.map(
                lambda full, s: full.at[:, slot:slot + 1].set(s),
                self.caches, sub)
            next_tok = int(np.asarray(jnp.argmax(logits[0, n_real - 1])))
            self.active[slot] = {
                "req": req, "generated": [next_tok],
                "pos": n_real,
            }

    def _step_decode(self) -> None:
        if not self.active:
            return
        tokens = np.zeros((self.slots, 1), np.int32)
        positions = np.zeros((self.slots, 1), np.int32)
        lane_mask = np.zeros((self.slots,), bool)
        for slot, st in self.active.items():
            tokens[slot, 0] = st["generated"][-1]
            positions[slot, 0] = st["pos"]
            lane_mask[slot] = True
        logits, self.caches = self._masked_decode(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(lane_mask))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        finished = []
        for slot, st in self.active.items():
            st["generated"].append(int(nxt[slot]))
            st["pos"] += 1
            if (len(st["generated"]) >= st["req"].max_new_tokens
                    or st["pos"] >= self.max_len - 1):
                finished.append(slot)
        for slot in finished:
            st = self.active.pop(slot)
            self.done.append(Completion(st["req"].rid, st["generated"]))
            self.free.append(slot)

    def run(self, max_steps: int = 1000) -> List[Completion]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self._admit()
            self._step_decode()
            steps += 1
        return self.done
