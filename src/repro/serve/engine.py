"""Continuous-batching serving engine.

A request is (prompt tokens, max_new_tokens). The engine keeps a fixed
pool of decode slots backed by one shared KV/state cache; finished
sequences free their slot, and queued requests are admitted by a prefill
that writes into the freed slot's cache rows. One decode step advances
every active slot (the classic iteration-level scheduling of Orca/vLLM,
mapped to fixed-shape JAX: slot count and cache length are static, slot
occupancy is a mask).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelBundle


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, *, slots: int = 4,
                 max_len: int = 512, extras: Optional[Dict] = None):
        self.bundle = bundle
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.caches = bundle.init_cache(params, slots, max_len,
                                        batch=extras or {},
                                        dtype=jnp.float32)
        self._decode = jax.jit(bundle.decode_step)
        self.free: List[int] = list(range(slots))
        self.active: Dict[int, dict] = {}     # slot -> request state
        self.queue: List[Request] = []
        self.done: List[Completion] = []

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.free:
            req = self.queue.pop(0)
            slot = self.free.pop(0)
            # prefill into an isolated batch-1 view of this slot's cache
            # rows, then write the updated rows back — other slots' caches
            # are untouched (slot isolation).
            prompt = jnp.asarray(req.prompt.astype(np.int32))[None, :]
            positions = jnp.arange(prompt.shape[1], dtype=jnp.int32)[None]
            sub = jax.tree.map(lambda x: x[:, slot:slot + 1], self.caches)
            logits, sub = self._decode(self.params, sub, prompt, positions)
            self.caches = jax.tree.map(
                lambda full, s: full.at[:, slot:slot + 1].set(s),
                self.caches, sub)
            next_tok = int(np.asarray(jnp.argmax(logits[0, -1])))
            self.active[slot] = {
                "req": req, "generated": [next_tok],
                "pos": int(prompt.shape[1]),
            }

    def _step_decode(self) -> None:
        if not self.active:
            return
        tokens = np.zeros((self.slots, 1), np.int32)
        positions = np.zeros((self.slots, 1), np.int32)
        for slot, st in self.active.items():
            tokens[slot, 0] = st["generated"][-1]
            positions[slot, 0] = st["pos"]
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(positions))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        finished = []
        for slot, st in self.active.items():
            st["generated"].append(int(nxt[slot]))
            st["pos"] += 1
            if (len(st["generated"]) >= st["req"].max_new_tokens
                    or st["pos"] >= self.max_len - 1):
                finished.append(slot)
        for slot in finished:
            st = self.active.pop(slot)
            self.done.append(Completion(st["req"].rid, st["generated"]))
            self.free.append(slot)

    def run(self, max_steps: int = 1000) -> List[Completion]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self._admit()
            self._step_decode()
            steps += 1
        return self.done
