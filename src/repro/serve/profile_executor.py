"""Asynchronous profiling execution for the ``SearchService``.

Karasu's wall-clock win (paper §III, §IV) is fewer *and cheaper*
profiling runs; a multi-tenant service must additionally never let one
slow tenant's cluster run gate everyone else's BO step. This module
isolates "execute profile_fn(config)" behind an executor with three
backends:

  - ``SyncProfileExecutor``        — runs the profiler inline at submit
    time. Zero concurrency; bitwise-identical to the pre-async service.
  - ``ThreadPoolProfileExecutor``  — a ``concurrent.futures`` thread
    pool. Profiling runs overlap each other and the service's fit/score
    work; completion order is wall-clock, but outcomes are always
    *returned* in submission order among the completed set, so absorbing
    them is deterministic whenever the completed set is.
  - ``ProcessPoolProfileExecutor`` — same semantics on a process pool,
    for profilers that hold the GIL (heavy numpy in the measurement
    path, C extensions that never release). Jobs, outcomes, and the
    profile_fn cross a pickle boundary — see the class docstring.
  - ``FakeProfileExecutor``        — a deterministic virtual-clock fake:
    the profiler runs inline (deterministically, in submission order)
    but its outcome is withheld until the per-job latency has elapsed on
    a tick counter. Lets tests and simulations exercise heterogeneous
    profiling latencies with zero wall-clock and zero nondeterminism.

Shared semantics:

  - ``submit(job, fn)``              — enqueue one profiling run.
  - ``poll()``                       — non-blocking; outcomes that have
    landed since the last poll/collect, in submission order.
  - ``collect(timeout, min_results)``— block until at least
    ``min_results`` outcomes are available (or timeout); returns them.
  - ``drain(timeout)``               — block until ALL in-flight runs
    land (or timeout); returns what landed.
  - ``pending()``                    — in-flight count (submitted, not
    yet returned).

``collect``/``drain`` with ``timeout=None`` wait indefinitely for real
backends; on the fake they advance the virtual clock, so they always
return. Both return *early with whatever is available* on timeout —
callers must re-poll later, and a job's result is never dropped.
Profiler exceptions are captured on the outcome (``error``), never
raised in the executor thread.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

ProfileFn = Callable[[Mapping], Tuple[Dict[str, float], np.ndarray]]


@dataclasses.dataclass(frozen=True)
class ProfileJob:
    """One profiling run: session ``rid`` wants candidate ``ci`` run.

    ``seq`` is the session-local submission index; sessions use it to
    re-order outcomes that arrive out of order (threads race marking
    their futures done), keeping every session's observation sequence
    arrival-order independent."""
    rid: int
    ci: int
    config: Mapping
    tag: str = "bo"            # "init" (admission prefill) | "bo"
    seq: int = 0


@dataclasses.dataclass
class ProfileOutcome:
    job: ProfileJob
    measures: Optional[Dict[str, float]] = None
    metrics: Optional[np.ndarray] = None
    error: Optional[BaseException] = None


def _run(job: ProfileJob, fn: ProfileFn) -> ProfileOutcome:
    try:
        measures, metrics = fn(job.config)
        return ProfileOutcome(job, measures, metrics)
    except BaseException as e:                 # noqa: BLE001 — relayed
        return ProfileOutcome(job, error=e)


class SyncProfileExecutor:
    """Inline execution: every submit completes immediately."""

    def __init__(self) -> None:
        self._ready: List[ProfileOutcome] = []

    def submit(self, job: ProfileJob, fn: ProfileFn) -> None:
        self._ready.append(_run(job, fn))

    def pending(self) -> int:
        return len(self._ready)

    def poll(self) -> List[ProfileOutcome]:
        out, self._ready = self._ready, []
        return out

    def collect(self, timeout: Optional[float] = None,
                min_results: int = 1) -> List[ProfileOutcome]:
        return self.poll()

    def drain(self, timeout: Optional[float] = None) -> List[ProfileOutcome]:
        return self.poll()

    def shutdown(self) -> None:
        self._ready.clear()


class _PoolBackedExecutor:
    """The ordered-outcome bookkeeping shared by the thread- and
    process-pool backends: submissions take a monotonically increasing
    seq, workers record outcomes under it, and ``poll``/``collect``/
    ``drain`` return completed outcomes in submission order among the
    completed set — so absorbing them is deterministic whenever the
    completed set is (e.g. under a barrier, or after a full drain)."""

    def __init__(self) -> None:
        self._lock = threading.Condition()
        self._seq = 0
        self._done: Dict[int, ProfileOutcome] = {}   # seq -> outcome
        self._inflight: set = set()

    def _next_seq(self) -> int:
        with self._lock:
            seq = self._seq
            self._seq += 1
            self._inflight.add(seq)
            return seq

    def _record(self, seq: int, out: ProfileOutcome) -> None:
        with self._lock:
            self._inflight.discard(seq)
            self._done[seq] = out
            self._lock.notify_all()

    def pending(self) -> int:
        with self._lock:
            return len(self._inflight) + len(self._done)

    def _take(self) -> List[ProfileOutcome]:
        return [self._done.pop(k) for k in sorted(self._done)]

    def poll(self) -> List[ProfileOutcome]:
        with self._lock:
            return self._take()

    def collect(self, timeout: Optional[float] = None,
                min_results: int = 1) -> List[ProfileOutcome]:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            want = min(min_results,
                       len(self._inflight) + len(self._done))
            while len(self._done) < want:
                left = (None if deadline is None
                        else deadline - time.monotonic())
                if left is not None and left <= 0:
                    break
                self._lock.wait(left)
            return self._take()

    def drain(self, timeout: Optional[float] = None) -> List[ProfileOutcome]:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._inflight:
                left = (None if deadline is None
                        else deadline - time.monotonic())
                if left is not None and left <= 0:
                    break
                self._lock.wait(left)
            return self._take()

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)


class ThreadPoolProfileExecutor(_PoolBackedExecutor):
    """Real concurrency: profiling runs execute on a thread pool while
    the service keeps fitting/scoring the sessions whose data landed."""

    def __init__(self, max_workers: int = 8) -> None:
        super().__init__()
        self._pool = ThreadPoolExecutor(max_workers=max_workers)

    def submit(self, job: ProfileJob, fn: ProfileFn) -> None:
        seq = self._next_seq()

        def work() -> None:
            self._record(seq, _run(job, fn))

        self._pool.submit(work)


class ProcessPoolProfileExecutor(_PoolBackedExecutor):
    """Profiling runs on a PROCESS pool — for profile_fns that hold the
    GIL (tight C loops, heavy in-process measurement), where threads
    serialise instead of overlapping.

    Everything submitted crosses a pickle boundary: ``ProfileJob`` /
    ``ProfileOutcome`` are plain-data dataclasses and pickle as long as
    the job's ``config`` mapping and the outcome's measures/metrics do
    (dicts, floats, numpy arrays — yes); the ``profile_fn`` must be a
    module-level callable (no lambdas/closures). A profiler exception is
    captured onto ``outcome.error`` in the worker and pickled back —
    same propagation contract as the other backends. Failures of the
    pool machinery itself (unpicklable fn, a worker dying, a broken
    pool) surface the same way, as an errored outcome for the job that
    hit them, so the service's session state machine settles instead of
    wedging.

    ``mp_context`` forwards to ``ProcessPoolExecutor`` (e.g.
    ``multiprocessing.get_context("spawn")`` where fork is unsafe)."""

    def __init__(self, max_workers: int = 8, mp_context=None) -> None:
        super().__init__()
        self._pool = ProcessPoolExecutor(max_workers=max_workers,
                                         mp_context=mp_context)

    def submit(self, job: ProfileJob, fn: ProfileFn) -> None:
        seq = self._next_seq()
        try:
            fut = self._pool.submit(_run, job, fn)
        except BaseException as e:   # noqa: BLE001 — surfaced on outcome
            # submit-time failure (pool already broken/shut down): the
            # job still owes an outcome
            self._record(seq, ProfileOutcome(job, error=e))
            return

        def on_done(f) -> None:
            try:
                out = f.result()
            except BaseException as e:  # noqa: BLE001 — pickling error,
                out = ProfileOutcome(job, error=e)  # BrokenProcessPool, ...
            self._record(seq, out)

        fut.add_done_callback(on_done)


class FakeProfileExecutor:
    """Deterministic fake with a virtual clock.

    ``latency_fn(job) -> int`` gives the number of virtual ticks the run
    takes (default 1). The profiler itself executes inline at submit
    time — in submission order, so RNG-bearing profile_fns stay
    deterministic — but the outcome only becomes visible once the clock
    passes its deadline. ``collect``/``drain`` advance the clock instead
    of sleeping, so simulated heterogeneous latencies cost no wall time.
    """

    def __init__(self, latency_fn: Optional[Callable[[ProfileJob], int]]
                 = None) -> None:
        self._latency_fn = latency_fn or (lambda job: 1)
        self._now = 0
        self._seq = 0
        # heap of (deadline, seq, outcome)
        self._scheduled: List[Tuple[int, int, ProfileOutcome]] = []
        self.ticks = 0                      # total virtual time advanced

    def submit(self, job: ProfileJob, fn: ProfileFn) -> None:
        deadline = self._now + max(1, int(self._latency_fn(job)))
        heapq.heappush(self._scheduled,
                       (deadline, self._seq, _run(job, fn)))
        self._seq += 1

    def pending(self) -> int:
        return len(self._scheduled)

    def _landed(self) -> List[ProfileOutcome]:
        out = []
        while self._scheduled and self._scheduled[0][0] <= self._now:
            out.append(heapq.heappop(self._scheduled)[2])
        return out

    def poll(self) -> List[ProfileOutcome]:
        return self._landed()

    def collect(self, timeout: Optional[float] = None,
                min_results: int = 1) -> List[ProfileOutcome]:
        """Advance the virtual clock until >= min_results outcomes land
        (the fake never blocks; ``timeout`` caps the number of ticks —
        rounded UP, so any positive timeout makes progress)."""
        out = self._landed()
        budget = (float("inf") if timeout is None
                  else int(-(-timeout // 1)))          # ceil
        want = min(min_results, len(out) + len(self._scheduled))
        while len(out) < want and self._scheduled and budget > 0:
            self._now += 1
            self.ticks += 1
            budget -= 1
            out.extend(self._landed())
        return out

    def drain(self, timeout: Optional[float] = None) -> List[ProfileOutcome]:
        n = len(self._scheduled)
        return self.collect(timeout, min_results=n) if n else self.poll()

    def shutdown(self) -> None:
        self._scheduled.clear()
