import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below runs with 512 placeholder devices -------------------
import argparse
import dataclasses
import gzip
import json
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, LONG_500K_SKIP, SHAPE_IDS, cells,
                           get_config)
from repro.configs.shapes import input_specs
from repro.distributed import DistContext, use_context
from repro.launch import hlo_stats
from repro.launch.mesh import batch_axes_for, make_production_mesh
from repro.launch.plans import LaunchPlan, get_plan, override
from repro.launch.shardings import (batch_specs, cache_specs, opt_state_specs,
                                    param_specs, to_shardings)
from repro.models import build_model
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.optim import cosine_schedule, get_optimizer
from repro.train.step import make_train_step


def _microbatches_for(plan: LaunchPlan, mesh, global_batch: int) -> int:
    """Clamp grad-accumulation so each microbatch still covers the batch
    shards."""
    bax = [a for a in ("pod", "data") if a in mesh.axis_names]
    shards = 1
    for a in bax:
        shards *= mesh.shape[a]
    mb = plan.microbatches
    while mb > 1 and (global_batch % mb != 0
                      or (global_batch // mb) % shards != 0):
        mb //= 2
    return max(mb, 1)


def lower_cell(
    arch: str,
    shape_id: str,
    *,
    multi_pod: bool = False,
    mesh=None,
    plan: Optional[LaunchPlan] = None,
    cfg_overrides: Optional[Dict[str, Any]] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Lower + compile one (arch x shape x mesh) cell; returns
    (compiled, artifact_dict)."""
    cfg = get_config(arch)
    plan = plan or get_plan(arch)
    cell_kind = "train" if shape_id.startswith("train") else "serve"
    overrides = {"attn_impl": "xla",
                 # remat is a backward-pass trade; serving never remats
                 "remat": plan.remat if cell_kind == "train" else False}
    overrides.update(cfg_overrides or {})
    cfg = dataclasses.replace(cfg, **overrides)

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    bax = batch_axes_for(mesh)
    ctx = DistContext(
        mesh=mesh, batch_axes=bax,
        ep_mode=plan.ep_mode if cfg.n_experts else "none",
        fsdp_axis="data" if plan.fsdp_experts else None)

    cell = input_specs(cfg, shape_id)
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(0)
    t0 = time.time()

    with use_context(ctx):
        params_shape = jax.eval_shape(bundle.init, key)
        pspecs = param_specs(params_shape, cfg, mesh,
                             fsdp_experts=plan.fsdp_experts)
        pshard = to_shardings(pspecs, mesh)
        bspecs = batch_specs(cell.batch, mesh, bax)
        bshard = to_shardings(bspecs, mesh)

        if cell.kind == "train":
            opt = get_optimizer(plan.optimizer)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            ospecs = opt_state_specs(opt_shape, mesh)
            oshard = to_shardings(ospecs, mesh)
            mb = _microbatches_for(plan, mesh, cell.global_batch)
            gspecs = opt_state_specs(params_shape, mesh)  # fully sharded
            gshard = to_shardings(gspecs, mesh) if mb > 1 else None
            step_fn = make_train_step(
                bundle, opt, cosine_schedule(plan.lr, 100, 10_000),
                microbatches=mb, grad_shardings=gshard)
            metrics_shard = jax.tree.map(
                lambda _: NamedSharding(mesh, P()),
                {"ce": 0, "aux": 0, "loss": 0, "grad_norm": 0, "lr": 0})
            jitted = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, bshard,
                              NamedSharding(mesh, P())),
                out_shardings=(pshard, oshard, metrics_shard),
                donate_argnums=(0, 1))
            lowered = jitted.lower(
                params_shape, opt_shape, cell.batch,
                jax.ShapeDtypeStruct((), jnp.int32))
            extra_meta = {"microbatches": mb, "optimizer": plan.optimizer}
        else:
            # serving cells: caches as explicit sharded arguments
            extras = {}
            if cfg.is_encoder_decoder:
                extras["frame_embeds"] = jax.ShapeDtypeStruct(
                    (cell.global_batch, cfg.n_audio_frames, cfg.d_model),
                    jnp.bfloat16)
            cache_shape = jax.eval_shape(
                lambda p, e: bundle.init_cache(p, cell.global_batch,
                                               cell.seq_len, batch=e),
                params_shape, extras)
            cspecs = cache_specs(cache_shape, cfg, mesh, bax,
                                 batch_size=cell.global_batch)
            cshard = to_shardings(cspecs, mesh)
            if cell.kind == "prefill":
                step_fn = make_prefill_step(bundle)
                out_shardings = (None, cshard)
            else:
                step_fn = make_decode_step(bundle)
                out_shardings = (None, None, cshard)
            jitted = jax.jit(
                step_fn,
                in_shardings=(pshard, cshard, bshard["tokens"],
                              bshard["positions"]),
                out_shardings=out_shardings,
                donate_argnums=(1,))
            lowered = jitted.lower(params_shape, cache_shape,
                                   cell.batch["tokens"],
                                   cell.batch["positions"])
            extra_meta = {"cache_len": cell.seq_len}

        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    mem = compiled.memory_analysis()
    try:
        cost = dict(compiled.cost_analysis())
    except Exception:
        cost = {}
    text = compiled.as_text()
    stats = hlo_stats.analyze(text)

    import math
    n_devices = mesh.devices.size
    param_count = sum(math.prod(x.shape)
                      for x in jax.tree.leaves(params_shape))
    param_bytes = sum(math.prod(x.shape) * x.dtype.itemsize
                      for x in jax.tree.leaves(params_shape))

    artifact = {
        "status": "ok",
        "arch": arch,
        "shape": shape_id,
        "mesh": "multi" if multi_pod else "single",
        "mesh_shape": dict(zip(mesh.axis_names,
                               [int(mesh.shape[a])
                                for a in mesh.axis_names])),
        "n_devices": int(n_devices),
        "kind": cell.kind,
        "global_batch": cell.global_batch,
        "seq_len": cell.seq_len,
        "param_count": int(param_count),
        "param_bytes_global": int(param_bytes),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_analysis_flops_unrolled_once": float(cost.get("flops", 0.0)),
        "hlo": stats,
        "timing": {"lower_s": lower_s, "compile_s": compile_s},
        "plan": {"optimizer": plan.optimizer, "ep_mode": plan.ep_mode,
                 "fsdp_experts": plan.fsdp_experts, "remat": plan.remat},
        **extra_meta,
    }
    return compiled, artifact, text


def run_cell(arch, shape_id, multi_pod, out_dir, save_hlo=False, plan=None,
             cfg_overrides=None, tag="", optimized=False):
    name = f"{arch}__{shape_id}__{'multi' if multi_pod else 'single'}"
    if tag:
        name += f"__{tag}"
    mesh = None
    if optimized:
        from repro.launch.plans import get_optimized
        plan, layout, opt_cfg = get_optimized(arch, shape_id)
        cfg_overrides = dict(opt_cfg, **(cfg_overrides or {}))
        if layout is not None:
            shape = ((2,) + layout) if multi_pod else layout
            axes = ("pod", "data", "model") if multi_pod else \
                ("data", "model")
            mesh = jax.make_mesh(shape, axes)
            name += f"__opt{layout[0]}x{layout[1]}"
    print(f"[dryrun] {name} ...", flush=True)
    try:
        compiled, artifact, text = lower_cell(
            arch, shape_id, multi_pod=multi_pod, plan=plan, mesh=mesh,
            cfg_overrides=cfg_overrides)
        artifact["status"] = "ok"
        if save_hlo:
            with gzip.open(os.path.join(out_dir, name + ".hlo.gz"),
                           "wt") as f:
                f.write(text)
        del compiled, text
    except Exception as e:  # record the failure, keep the batch going
        import traceback
        artifact = {"arch": arch, "shape": shape_id,
                    "mesh": "multi" if multi_pod else "single",
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]}
        print(f"[dryrun] FAILED {name}: {e}", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(artifact, f, indent=1)
    ok = artifact.get("status") == "ok"
    if ok:
        t = artifact["timing"]
        print(f"[dryrun] OK {name} lower={t['lower_s']:.1f}s "
              f"compile={t['compile_s']:.1f}s "
              f"temp={artifact['memory']['temp_bytes']/2**30:.2f}GiB",
              flush=True)
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply hillclimb-optimized layouts (plans.OPTIMIZED)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    todo = []
    if args.all:
        for arch, shape in cells():
            for mp in meshes:
                todo.append((arch, shape, mp))
    else:
        assert args.arch and args.shape
        for mp in meshes:
            todo.append((args.arch, args.shape, mp))

    n_ok = 0
    for arch, shape, mp in todo:
        name = f"{arch}__{shape}__{'multi' if mp else 'single'}"
        path = os.path.join(args.out, name + ".json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") == "ok":
                    print(f"[dryrun] skip existing {name}", flush=True)
                    n_ok += 1
                    continue
        n_ok += run_cell(arch, shape, mp, args.out,
                         save_hlo=args.save_hlo, optimized=args.optimized)
    print(f"[dryrun] {n_ok}/{len(todo)} cells OK", flush=True)


if __name__ == "__main__":
    main()
