"""Karasu-driven TPU mesh-configuration search (the hardware adaptation).

The "workload" is an (architecture x input shape) cell; the "resource
configuration" is a mesh/launch layout: (pods, data x model layout,
microbatch count, remat policy, EP mode, sequence parallelism). The
black-box profiling run is either

  - ``compile``  : lower + compile the cell on the candidate mesh (the
                   real dry-run) and evaluate the 3-term roofline ->
                   step-time bound, chip-seconds cost, energy; or
  - ``analytic`` : a closed-form roofline estimator (fast; tests and
                   benchmarks).

Measures: runtime (projected step time), cost (chip-hours $), energy
(kWh) — constraint: HBM fit (hbm_gib <= 16). The compact metric vector
shared with collaborators is the utilisation profile
(mxu_idle, hbm_occupancy, collective_frac, memory_frac, useful_ratio,
remat_overhead) — the TPU analogue of the paper's six sar metrics.

Collaboration: repository entries from OTHER (arch x shape) searches
transfer through RGPE exactly as in the paper — similar workloads prefer
similar layouts.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.core import (BOConfig, Constraint, Objective, Repository,
                        RunRecord, run_search, tpu_search_space)
from repro.launch.mesh import MESH_HARDWARE
from repro.launch.plans import get_plan, override


def _metrics_vector(util: Dict[str, float]) -> np.ndarray:
    """(6, 3) compact metric matrix from the utilisation profile."""
    vals = np.array([
        100.0 * (1.0 - util["mxu_util"]),      # mxu idle %
        100.0 * util["hbm_occupancy"],
        100.0 * util["collective_frac"],
        100.0 * util["memory_frac"],
        100.0 * util["useful_ratio"],
        100.0 * util["remat_overhead"],
    ])
    vals = np.clip(vals, 0.0, 100.0)
    return np.outer(vals, np.array([0.9, 1.0, 1.1])).clip(0, 100)


def _measures_from_terms(terms: Dict[str, float], chips: int,
                         hbm_gib: float) -> Dict[str, float]:
    hw = MESH_HARDWARE
    step = max(terms["compute_s"], terms["memory_s"],
               terms["collective_s"])
    util = terms["useful_time"] / step if step > 0 else 0.0
    watts = hw["chip_watts_idle"] + \
        (hw["chip_watts_peak"] - hw["chip_watts_idle"]) * util
    return {
        "runtime": step,
        "cost": chips * step / 3600.0 * hw["usd_per_chip_hour"],
        "energy": chips * watts * step / 3600.0 / 1000.0,  # kWh
        "hbm_gib": hbm_gib,
        "mfu": util,
    }


def _utilisation(terms, hbm_gib, useful_ratio):
    step = max(terms["compute_s"], terms["memory_s"],
               terms["collective_s"])
    return {
        "mxu_util": terms["useful_time"] / step if step else 0.0,
        "hbm_occupancy": min(hbm_gib / 16.0, 1.0),
        "collective_frac": terms["collective_s"] / step if step else 0.0,
        "memory_frac": terms["memory_s"] / step if step else 0.0,
        "useful_ratio": min(useful_ratio, 1.0),
        "remat_overhead": max(0.0, 1.0 - useful_ratio)
        if useful_ratio <= 1.0 else 0.0,
    }


# ---------------------------------------------------------------------------
# analytic black box
# ---------------------------------------------------------------------------


def analytic_profile(arch: str, shape_id: str, config: Mapping
                     ) -> Tuple[Dict[str, float], np.ndarray]:
    """Closed-form roofline estimate for a candidate layout."""
    hw = MESH_HARDWARE
    cfg = get_config(arch)
    meta = SHAPES[shape_id]
    b, s = meta["global_batch"], meta["seq_len"]
    pods, dp, mp = (int(config["pods"]), int(config["data"]),
                    int(config["model"]))
    mb = int(config["microbatches"])
    chips = pods * dp * mp

    # rough param/active counts
    n = cfg.param_count()
    if cfg.n_experts:
        n_attn = sum(1 for k in cfg.layer_kinds
                     if k in ("attn", "local_attn"))
        ep_params = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_experts * n_attn
        n_active = n - ep_params + ep_params * cfg.top_k // cfg.n_experts
    else:
        n_active = n

    train = meta["kind"] == "train"
    tokens = b * s if meta["kind"] != "decode" else b
    factor = 6.0 if train else 2.0
    useful_flops = factor * n_active * tokens / chips
    remat_f = 4.0 / 3.0 if (train and config.get("remat", True)) else 1.0
    compute_s = useful_flops * remat_f / hw["peak_flops_bf16"]
    useful_time = useful_flops / hw["peak_flops_bf16"]

    # memory traffic: params once per microbatch (+grads) + activations
    pbytes_local = 2.0 * n / (mp * (dp if cfg.n_experts else 1))
    act = tokens / (pods * dp) * cfg.d_model * 2.0 * cfg.n_layers \
        * (4.0 if train else 1.5)
    mem_bytes = pbytes_local * (3.0 if train else 1.0) * mb + act
    memory_s = mem_bytes / hw["hbm_bw"]

    # collectives: TP activation ARs + DP grad AR + EP terms
    toks_local = tokens / (pods * dp)
    n_ar = 4 if train else 2
    seqp = 0.5 if config.get("seq_parallel") else 1.0
    tp_bytes = n_ar * cfg.n_layers * toks_local * cfg.d_model * 2.0 \
        * 2.0 * (mp - 1) / mp * seqp * (1.5 if train else 1.0)
    dp_bytes = (2.0 * 2.0 * n / mp * (dp - 1) / dp) if train else 0.0
    ep_bytes = 0.0
    if cfg.n_experts and train:
        if config.get("ep_mode") == "a2a":
            ep_bytes = 2 * cfg.n_layers * toks_local * cfg.top_k / mp \
                * cfg.d_model * 2.0 * 3.0
        else:
            ep_bytes = 2 * cfg.n_layers * toks_local * cfg.d_model * 2.0 \
                * 2.0 * 3.0
    collective_s = (tp_bytes + dp_bytes + ep_bytes) / hw["ici_bw"]

    # HBM occupancy
    opt_f = 3.0 if train else 1.25   # fp32 master+moments (ZeRO'd) or KV
    hbm = 2.0 * n / mp / (dp if cfg.n_experts else 1) \
        + (4.0 * n / (mp * dp) * opt_f if train else 0.0) \
        + act / max(mb, 1) * 2.0
    hbm_gib = hbm / 2 ** 30

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s, "useful_time": useful_time}
    measures = _measures_from_terms(terms, chips, hbm_gib)
    util = _utilisation(terms, hbm_gib, 1.0 / remat_f)
    return measures, _metrics_vector(util)


# ---------------------------------------------------------------------------
# compile black box (the real dry-run)
# ---------------------------------------------------------------------------


def compile_profile(arch: str, shape_id: str, config: Mapping,
                    out_dir: Optional[str] = None
                    ) -> Tuple[Dict[str, float], np.ndarray]:
    import jax
    from repro.launch.dryrun import lower_cell
    from repro.launch.roofline import roofline_from_artifact

    pods, dp, mp = (int(config["pods"]), int(config["data"]),
                    int(config["model"]))
    if pods > 1:
        mesh = jax.make_mesh((pods, dp, mp), ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((dp, mp), ("data", "model"))
    plan = override(get_plan(arch),
                    microbatches=int(config["microbatches"]),
                    ep_mode=str(config.get("ep_mode", get_plan(arch).ep_mode)),
                    remat=bool(config.get("remat", True)))
    cfg_overrides = {}
    if config.get("remat_policy"):
        cfg_overrides["remat_policy"] = config["remat_policy"]
    if config.get("seq_parallel"):
        cfg_overrides["seq_shard_activations"] = True
    if config.get("moe_impl"):
        cfg_overrides["moe_impl"] = config["moe_impl"]
    compiled, artifact, _ = lower_cell(
        arch, shape_id, mesh=mesh, plan=plan, cfg_overrides=cfg_overrides)
    del compiled
    r = roofline_from_artifact(artifact)
    terms = {"compute_s": r.compute_s, "memory_s": r.memory_s,
             "collective_s": r.collective_s,
             "useful_time": (r.model_flops / artifact["n_devices"])
             / MESH_HARDWARE["peak_flops_bf16"]}
    measures = _measures_from_terms(terms, artifact["n_devices"],
                                    r.hbm_gib)
    util = _utilisation(terms, r.hbm_gib, r.useful_ratio)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"p{pods}d{dp}m{mp}mb{config['microbatches']}" \
              f"{'sp' if config.get('seq_parallel') else ''}" \
              f"{config.get('remat_policy') or ''}" \
              f"{config.get('ep_mode') or ''}"
        with open(os.path.join(
                out_dir, f"{arch}__{shape_id}__{tag}.json"), "w") as f:
            json.dump(dict(artifact, layout=dict(config)), f, indent=1)
    return measures, _metrics_vector(util)


# ---------------------------------------------------------------------------
# the search driver
# ---------------------------------------------------------------------------


def search_mesh_config(
    arch: str,
    shape_id: str,
    *,
    mode: str = "analytic",            # analytic | compile
    repository: Optional[Repository] = None,
    max_iters: int = 10,
    seed: int = 0,
    hbm_limit: float = 16.0,
    out_dir: Optional[str] = None,
    space=None,
):
    space = space or tpu_search_space()

    def profile_fn(config):
        if mode == "compile":
            return compile_profile(arch, shape_id, config, out_dir)
        return analytic_profile(arch, shape_id, config)

    method = "karasu" if repository is not None and len(repository) \
        else "naive"
    return run_search(
        space, profile_fn, Objective("runtime"),
        [Constraint("hbm_gib", hbm_limit)],
        method=method, repository=repository,
        bo_config=BOConfig(max_iters=max_iters, n_init=3, n_support=3),
        seed=seed)


def result_to_records(result, shared_id: str) -> list:
    return [RunRecord(shared_id, o.config, o.metrics, o.measures)
            for o in result.observations]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mode", default="analytic",
                    choices=["analytic", "compile"])
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repo", default=None,
                    help="path to a saved Repository json")
    ap.add_argument("--out", default="artifacts/karasu_search")
    args = ap.parse_args()

    repo = Repository.load(args.repo) if args.repo else None
    res = search_mesh_config(args.arch, args.shape, mode=args.mode,
                             repository=repo, max_iters=args.iters,
                             seed=args.seed, out_dir=args.out)
    best = res.best_index_per_iter[-1]
    print("profiled configs:")
    for i, o in enumerate(res.observations):
        star = "*" if i == best else " "
        print(f" {star} {dict(o.config)} -> step={o.measures['runtime']:.4f}s"
              f" hbm={o.measures['hbm_gib']:.1f}GiB"
              f" mfu={o.measures.get('mfu', 0):.3f}")
    if best >= 0:
        print("best:", dict(res.observations[best].config))


if __name__ == "__main__":
    # NOTE: --mode compile needs the 512-placeholder-device flag BEFORE
    # jax initialises; run as
    #   XLA_FLAGS=--xla_force_host_platform_device_count=512 \
    #     python -m repro.launch.karasu_search --mode compile ...
    main()
