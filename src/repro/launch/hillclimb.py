import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver for the three selected cells.

Cell A (paper-representative): minitron-8b train_4k — Karasu itself
        searches the mesh space with the REAL compile black box.
Cell B (most collective-bound): gemma3-4b train_4k — manual
        hypothesis->change->measure ladder.
Cell C (worst roofline fraction): arctic-480b train_4k — ladder incl.
        all-to-all expert parallelism.

Each probe writes a JSON artifact to artifacts/hillclimb/ and a line to
the iteration log; EXPERIMENTS.md §Perf is assembled from these.
"""
import json
import time

from repro.core import Repository, tpu_search_space
from repro.launch.karasu_search import (compile_profile, result_to_records,
                                        search_mesh_config)

OUT = "artifacts/hillclimb"
LOG = os.path.join(OUT, "log.jsonl")


def log_line(**kw):
    os.makedirs(OUT, exist_ok=True)
    with open(LOG, "a") as f:
        f.write(json.dumps(kw) + "\n")
    print("[hillclimb]", kw, flush=True)


def probe(cell_tag, arch, shape, config, note):
    t0 = time.time()
    try:
        measures, _ = compile_profile(arch, shape, config, out_dir=OUT)
        log_line(cell=cell_tag, arch=arch, shape=shape, note=note,
                 config={k: v for k, v in config.items()
                         if k not in ("machine_type", "node_count")},
                 runtime_s=measures["runtime"], mfu=measures["mfu"],
                 hbm_gib=measures["hbm_gib"], cost=measures["cost"],
                 wall_s=round(time.time() - t0, 1))
        return measures
    except Exception as e:
        log_line(cell=cell_tag, arch=arch, shape=shape, note=note,
                 error=f"{type(e).__name__}: {e}",
                 wall_s=round(time.time() - t0, 1))
        return None


def base_cfg(**kw):
    d = {"pods": 1, "data": 16, "model": 16, "microbatches": 8,
         "ep_mode": "none", "remat": True, "seq_parallel": False,
         "machine_type": "v5e", "node_count": 64}
    d.update(kw)
    return d


def cell_b_gemma3():
    arch, shape = "gemma3-4b", "train_4k"
    # it0: post-global-fix baseline config (einsum unembed + logits pin)
    probe("B", arch, shape, base_cfg(microbatches=2),
          "it0: global fixes (unembed einsum + logits constraint), mb=2")
    # it1: microbatches 2 -> 8 (H: temp 66 GiB -> ~1/4; collectives same)
    probe("B", arch, shape, base_cfg(microbatches=8),
          "it1: mb 2->8 (memory fit)")
    # it2: sequence parallelism (H: TP activation ARs -> RS/AG, ~1/2 bytes)
    probe("B", arch, shape, base_cfg(microbatches=8, seq_parallel=True),
          "it2: + sequence parallelism")
    # it3: narrower model axis (H: TP collectives scale with (mp-1)/mp and
    # per-shard tokens; mp16->4 cuts AR traffic ~4x; embed still shards)
    probe("B", arch, shape, base_cfg(data=64, model=4, microbatches=8,
                                     seq_parallel=True),
          "it3: + layout 64x4")
    probe("B", arch, shape, base_cfg(data=32, model=8, microbatches=8,
                                     seq_parallel=True),
          "it3b: layout 32x8 (alternative)")


def cell_c_arctic():
    arch, shape = "arctic-480b", "train_4k"
    probe("C", arch, shape, base_cfg(microbatches=16, ep_mode="allgather"),
          "it0: global fixes, allgather EP, mb=16")
    # it1: all-to-all dispatch (H: EP traffic ~ topk/ep of allgather)
    probe("C", arch, shape, base_cfg(microbatches=16, ep_mode="a2a"),
          "it1: a2a expert parallelism")
    # it2: + sequence parallel for the dense parts
    probe("C", arch, shape, base_cfg(microbatches=16, ep_mode="a2a",
                                     seq_parallel=True),
          "it2: + sequence parallelism")
    # it3: wider EP (model=32) to cut per-shard expert memory + a2a volume
    probe("C", arch, shape, base_cfg(data=8, model=32, microbatches=16,
                                     ep_mode="a2a", seq_parallel=True),
          "it3: layout 8x32")


def cell_a_minitron():
    arch, shape = "minitron-8b", "train_4k"
    # Karasu searches layouts with the real compile black box; support
    # models come from the ANALYTIC searches of two other dense archs
    # (collaborative transfer across workloads).
    from repro.launch.karasu_search import analytic_profile
    from repro.core import RunRecord
    space = tpu_search_space(pods=(1,), model_par=(4, 8, 16, 32),
                             microbatches=(4, 8, 16),
                             seq_parallel=(False, True))
    repo = Repository()
    import numpy as np
    rng = np.random.default_rng(0)
    for j, donor in enumerate(["gemma2-27b", "h2o-danube-1.8b"]):
        for ci in rng.choice(len(space), 14, replace=False):
            cfgd = space.configs[int(ci)]
            m, metr = analytic_profile(donor, "train_4k", cfgd)
            repo.add_run(RunRecord(f"anon-{j}", cfgd, metr, m))
    res = search_mesh_config(arch, shape, mode="compile", repository=repo,
                             max_iters=9, seed=0, out_dir=OUT, space=space)
    best = res.best_index_per_iter[-1]
    for i, o in enumerate(res.observations):
        log_line(cell="A", arch=arch, shape=shape,
                 note=f"karasu-compile-search iter{i}"
                      + (" (best)" if i == best else ""),
                 config={k: v for k, v in o.config.items()
                         if k not in ("machine_type", "node_count")},
                 runtime_s=o.measures["runtime"], mfu=o.measures["mfu"],
                 hbm_gib=o.measures["hbm_gib"])


if __name__ == "__main__":
    t0 = time.time()
    cell_b_gemma3()
    cell_c_arctic()
    cell_a_minitron()
    log_line(note="hillclimb complete", wall_s=round(time.time() - t0, 1))
