"""Per-arch launch plans: optimizer, microbatching, EP mode, FSDP.

These are the *baseline* production choices recorded in EXPERIMENTS.md
§Roofline; the Karasu mesh search (launch/karasu_search.py) explores the
same knobs as its resource-configuration space.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class LaunchPlan:
    arch: str
    optimizer: str = "adamw"
    microbatches: int = 1           # grad-accumulation steps (train)
    ep_mode: str = "none"           # none | allgather | a2a (MoE archs)
    fsdp_experts: bool = False      # FSDP expert weights over data axis
    remat: bool = True
    lr: float = 3e-4
    # overridable mesh logical layout (data, model); None = mesh default
    layout: Optional[tuple] = None


_PLANS = {
    "minitron-8b": LaunchPlan("minitron-8b", microbatches=4),
    "h2o-danube-1.8b": LaunchPlan("h2o-danube-1.8b", microbatches=2),
    "gemma3-4b": LaunchPlan("gemma3-4b", microbatches=2),
    "gemma2-27b": LaunchPlan("gemma2-27b", microbatches=8),
    "zamba2-1.2b": LaunchPlan("zamba2-1.2b", microbatches=2),
    "qwen3-moe-235b-a22b": LaunchPlan(
        "qwen3-moe-235b-a22b", optimizer="adafactor", microbatches=16,
        ep_mode="allgather", fsdp_experts=True),
    "arctic-480b": LaunchPlan(
        "arctic-480b", optimizer="adafactor", microbatches=16,
        ep_mode="allgather", fsdp_experts=True),
    "xlstm-125m": LaunchPlan("xlstm-125m", microbatches=1),
    "whisper-large-v3": LaunchPlan("whisper-large-v3", microbatches=2),
    "phi-3-vision-4.2b": LaunchPlan("phi-3-vision-4.2b", microbatches=2),
}


def get_plan(arch: str) -> LaunchPlan:
    return _PLANS[arch]


def override(plan: LaunchPlan, **kwargs) -> LaunchPlan:
    return dataclasses.replace(plan, **kwargs)


# ---------------------------------------------------------------------------
# Optimized layouts found by the §Perf hillclimbs (EXPERIMENTS.md):
# (data, model) logical layout + plan/config overrides per (arch, shape).
# The defaults above stay paper-faithful; `--optimized` opts in.
# ---------------------------------------------------------------------------

OPTIMIZED = {
    # verified by compile-in-the-loop probes (EXPERIMENTS.md §Perf)
    ("minitron-8b", "train_4k"): dict(layout=(32, 8), microbatches=16),
    ("gemma3-4b", "train_4k"): dict(layout=(64, 4), microbatches=16),
    # extrapolated from the verified cells (same dense-TP scaling law);
    # re-verify with `dryrun --optimized` before production use
    ("gemma2-27b", "train_4k"): dict(layout=(32, 8), microbatches=16),
    ("h2o-danube-1.8b", "train_4k"): dict(layout=(64, 4), microbatches=8),
    ("phi-3-vision-4.2b", "train_4k"): dict(layout=(64, 4),
                                            microbatches=8),
}


def get_optimized(arch: str, shape: str):
    """(plan, layout, cfg_overrides) with hillclimb results applied."""
    plan = get_plan(arch)
    opt = OPTIMIZED.get((arch, shape))
    if not opt:
        return plan, None, {}
    plan = override(plan, microbatches=opt.get("microbatches",
                                               plan.microbatches))
    cfg_overrides = {}
    if opt.get("seq_parallel"):
        cfg_overrides["seq_shard_activations"] = True
    return plan, opt.get("layout"), cfg_overrides
