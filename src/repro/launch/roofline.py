"""Roofline assembly from dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (per device, per step), TPU v5e constants from launch.mesh:
    compute_s    = HLO dot flops / peak_bf16
    memory_s     = HLO dot operand+result bytes / HBM bandwidth
    collective_s = collective link bytes / ICI bandwidth
HLO quantities come from launch.hlo_stats (trip-count-weighted static
analysis of the compiled module — jax's cost_analysis() visits loop
bodies once, so it cannot be used directly; dot-operand bytes are an
HBM-traffic proxy that ignores fusion reuse, i.e. an upper bound).

MODEL_FLOPS uses the standard 6·N·D (train) / 2·N·D (inference) with
N = active parameters (MoE counts top-k experts only).

Projected MFU ("roofline fraction") = useful-compute time / max(term):
what fraction of peak the step would sustain if the dominant roofline
term were the wall clock.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.launch.mesh import MESH_HARDWARE
from repro.models.common import ModelConfig


def active_param_fraction(cfg: ModelConfig) -> float:
    if not cfg.n_experts:
        return 1.0
    total = expert = 0
    # expert weights per layer: 3 * d * moe_d_ff * n_experts
    per_layer_expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_experts
    n_attn = sum(1 for k in cfg.layer_kinds if k in ("attn", "local_attn"))
    expert = per_layer_expert * n_attn
    return expert  # raw count; fraction handled in model_flops


def model_flops_per_step(cfg: ModelConfig, artifact: Dict) -> float:
    """Global useful flops per step (6ND train, 2ND decode/prefill)."""
    n_total = artifact["param_count"]
    if cfg.n_experts:
        n_attn = sum(1 for k in cfg.layer_kinds
                     if k in ("attn", "local_attn"))
        expert_params = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_experts \
            * n_attn
        n_active = n_total - expert_params \
            + expert_params * cfg.top_k // cfg.n_experts
    else:
        n_active = n_total
    kind = artifact["kind"]
    if kind == "train":
        tokens = artifact["global_batch"] * artifact["seq_len"]
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = artifact["global_batch"] * artifact["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * artifact["global_batch"]


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_dev: float
    useful_ratio: float          # MODEL_FLOPS / (HLO flops * devices)
    projected_mfu: float         # useful compute time / max term
    fits_hbm: bool
    hbm_gib: float
    note: str = ""

    def as_row(self) -> List:
        return [self.arch, self.shape, self.mesh,
                f"{self.compute_s*1e3:.2f}", f"{self.memory_s*1e3:.2f}",
                f"{self.collective_s*1e3:.2f}", self.dominant,
                f"{self.useful_ratio:.2f}", f"{self.projected_mfu:.3f}",
                f"{self.hbm_gib:.1f}", "yes" if self.fits_hbm else "NO"]


def roofline_from_artifact(artifact: Dict) -> Optional[Roofline]:
    if artifact.get("status") != "ok":
        return None
    hw = MESH_HARDWARE
    cfg = get_config(artifact["arch"])
    h = artifact["hlo"]
    nd = artifact["n_devices"]

    compute_s = h["dot_flops"] / hw["peak_flops_bf16"]
    memory_s = h["dot_bytes"] / hw["hbm_bw"]
    # prefer the TPU-equivalent collective volume when available (XLA-CPU
    # promotes bf16 collectives to f32; see hlo_stats.analyze)
    coll_bytes = h.get("collective_bytes_bf16eq", h["collective_bytes"])
    collective_s = coll_bytes / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops_per_step(cfg, artifact)
    hlo_total = h["dot_flops"] * nd
    useful = mf / hlo_total if hlo_total else 0.0
    useful_time = (mf / nd) / hw["peak_flops_bf16"]
    bound = max(terms.values())
    mfu = useful_time / bound if bound > 0 else 0.0

    mem = artifact["memory"]
    hbm = (mem["argument_bytes"] + mem["temp_bytes"]
           + mem["output_bytes"] - mem.get("alias_bytes", 0))
    hbm_gib = hbm / 2 ** 30
    return Roofline(
        arch=artifact["arch"], shape=artifact["shape"],
        mesh=artifact["mesh"], compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant, model_flops=mf,
        hlo_flops_per_dev=h["dot_flops"], useful_ratio=useful,
        projected_mfu=mfu, fits_hbm=hbm_gib <= 16.0, hbm_gib=hbm_gib)


def load_artifacts(out_dir: str) -> List[Dict]:
    arts = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            arts.append(json.load(f))
    return arts


HEADER = ["arch", "shape", "mesh", "compute_ms", "memory_ms",
          "collective_ms", "dominant", "useful", "proj_MFU", "HBM_GiB",
          "fits"]


def table(out_dir: str, mesh: str = "single") -> str:
    rows = [HEADER]
    for a in load_artifacts(out_dir):
        if a.get("mesh") != mesh:
            continue
        r = roofline_from_artifact(a)
        if r:
            rows.append([str(c) for c in r.as_row()])
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    return "\n".join(
        " | ".join(c.ljust(w) for c, w in zip(r, widths)) for r in rows)


if __name__ == "__main__":
    import sys
    out = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    print(table(out, mesh))
