"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — jax locks the device count on
first backend initialisation, and only ``launch/dryrun.py`` installs the
512-placeholder-device XLA flag.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) for two
    pods. 256 chips per pod (TPU v5e-256 topology)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for CPU tests (requires host-device override by caller)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes_for(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


MESH_HARDWARE = {
    # TPU v5e hardware constants used by the roofline model
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link (~per-direction)
    "hbm_per_chip": 16 * 1024**3,
    "chip_watts_idle": 70.0,
    "chip_watts_peak": 250.0,
    "usd_per_chip_hour": 1.2,
}
