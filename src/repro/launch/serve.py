"""Production serving driver: continuous-batching engine over a model
from the config registry.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --smoke --requests 8 --slots 3
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    engine = ServeEngine(bundle, params, slots=args.slots,
                         max_len=args.max_len)

    rng = np.random.default_rng(0)
    total = 0
    for rid in range(args.requests):
        n = int(rng.integers(2, args.max_new + 1))
        total += n
        engine.submit(Request(rid, rng.integers(0, cfg.vocab,
                                                size=int(rng.integers(3, 9))),
                              n))
    t0 = time.time()
    done = engine.run(max_steps=2000)
    dt = time.time() - t0
    print(f"served {len(done)}/{args.requests} requests, {total} tokens, "
          f"{dt:.1f}s ({total / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
