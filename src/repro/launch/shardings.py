"""Sharding rules: parameters, optimizer state (ZeRO-1), caches, batches.

Param specs are architecture-informed (vocab/heads/ff over ``model``);
optimizer state uses a divisibility-driven auto-spec that additionally
spreads over ``data`` (ZeRO-1). Cache specs implement flash-decoding KV
parallelism for the long-context cells (sequence over ``data`` when the
batch is too small to shard).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def batch_axes_that_divide(mesh: Mesh, b: int, axes: Tuple[str, ...]
                           ) -> Tuple[str, ...]:
    """Longest prefix of `axes` whose product divides b."""
    out, prod = [], 1
    for a in axes:
        prod *= _axis_size(mesh, a)
        if b % prod == 0:
            out.append(a)
        else:
            break
    return tuple(out)


def auto_spec(shape: Tuple[int, ...], mesh: Mesh,
              axes_pref: Tuple[str, ...] = ("data", "model")) -> P:
    """Greedy divisibility-driven spec: assign each preferred mesh axis to
    the largest still-unassigned dim it divides."""
    assign: dict = {}
    taken = set()
    for ax in axes_pref:
        size = _axis_size(mesh, ax)
        if size == 1:
            continue
        dims = sorted(range(len(shape)), key=lambda d: -shape[d])
        for d in dims:
            if d not in taken and shape[d] % size == 0 and shape[d] >= size:
                assign[d] = ax
                taken.add(d)
                break
    return P(*[assign.get(d) for d in range(len(shape))])


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_COL = ("wq/w", "wk/w", "wv/w", "gate/w", "up/w", "up_gate/w", "in_proj/w",
        "wi/w", "wf/w", "w_in/w", "lm_head/w")
_ROW = ("wo/w", "down/w", "out_proj/w")


def _base_param_spec(path: str, shape: Tuple[int, ...], ndim: int,
                     mesh: Mesh, fsdp_experts: bool) -> P:
    msize = _axis_size(mesh, "model")

    def div(d):  # dim divisible by model axis
        return shape[d] % msize == 0 and shape[d] >= msize

    if path.endswith("embed/table"):
        return P("model", None) if div(0) else P(None, None)
    # MoE expert tensors (3D: experts, in, out)
    if re.search(r"moe/(gate|up)$", path) and ndim == 3:
        f = "data" if fsdp_experts else None
        return P("model" if div(0) else None, None, f)
    if re.search(r"moe/down$", path) and ndim == 3:
        f = "data" if fsdp_experts else None
        return P("model" if div(0) else None, f, None)
    if path.endswith("moe/router"):
        return P(None, None)
    if "slstm" in path:
        return P(*([None] * ndim))  # sequential recurrent block: replicate
    for suffix in _COL:
        if path.endswith(suffix):
            return P(None, "model") if div(1) else P(None, None)
    for suffix in _ROW:
        if path.endswith(suffix):
            return P("model", None) if div(0) else P(None, None)
    if path.endswith("conv_w"):  # (k, channels) depthwise
        return P(None, "model") if div(1) else P(None, None)
    if path.endswith("conv_b"):
        return P("model") if div(0) else P(None)
    # everything else (norm scales, small biases, lora, A_log, D, ...)
    return P(*([None] * ndim))


_STACK_PREFIXES = ("units", "enc_blocks", "dec_blocks")


def param_specs(params_shape: Any, cfg: ModelConfig, mesh: Mesh,
                *, fsdp_experts: bool = False) -> Any:
    """PartitionSpec pytree matching params (works on ShapeDtypeStructs)."""

    def spec_for(path, leaf):
        pstr = _path_str(path)
        stacked = any(pstr.startswith(s) for s in _STACK_PREFIXES)
        shape = leaf.shape
        if stacked:
            base = _base_param_spec(pstr, shape[1:], leaf.ndim - 1, mesh,
                                    fsdp_experts)
            return P(None, *base)
        return _base_param_spec(pstr, shape, leaf.ndim, mesh, fsdp_experts)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# ---------------------------------------------------------------------------
# optimizer state specs (ZeRO-1)
# ---------------------------------------------------------------------------


def opt_state_specs(opt_shape: Any, mesh: Mesh) -> Any:
    """Divisibility-driven specs for optimizer state; shards over data AND
    model wherever possible (ZeRO-1 + tensor-parallel alignment)."""

    def spec_for(leaf):
        if leaf.ndim == 0:
            return P()
        return auto_spec(leaf.shape, mesh)

    return jax.tree.map(spec_for, opt_shape)


# ---------------------------------------------------------------------------
# batch + cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch_shape: Any, mesh: Mesh,
                batch_axes: Tuple[str, ...]) -> Any:
    def spec_for(leaf):
        b = leaf.shape[0]
        bax = batch_axes_that_divide(mesh, b, batch_axes)
        lead = bax if bax else None
        return P(lead, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(spec_for, batch_shape)


def cache_specs(cache_shape: Any, cfg: ModelConfig, mesh: Mesh,
                batch_axes: Tuple[str, ...], *, batch_size: int) -> Any:
    """KV/state cache specs.

    Large-batch decode: batch over (pod, data), heads/head_dim over model.
    batch=1 long-context decode: cache *sequence* over data (flash-
    decoding KV parallelism), heads/head_dim over model.
    """
    import math as _math
    bax = batch_axes_that_divide(mesh, batch_size, batch_axes)
    seq_parallel = not bax  # cannot shard batch -> shard cache sequence
    msize = _axis_size(mesh, "model")
    dsize = _axis_size(mesh, "data")
    bax_size = _math.prod(_axis_size(mesh, a) for a in bax) if bax else 1

    def spec_for(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        # every cache leaf from init_decode_cache / init_encdec_cache is
        # stacked over units/layers: first dim is the layer axis
        stacked = True
        lead: Tuple = (None,) if stacked else ()
        body = shape[1:] if stacked else shape
        bdim = 0  # batch dim within body
        spec = [None] * len(body)
        if len(body) == 0:
            return P(*lead)
        if bax and body[bdim] % bax_size == 0:
            spec[bdim] = bax
        # kv caches: (b, S, kvh, hd); pos: (b, S).
        # policy: heads over model when divisible; otherwise flash-
        # decoding style sequence sharding over model (partial softmax
        # stats + small all-reduce instead of gathering the cache).
        if pstr.endswith(("/k", "/v", "kv/k", "kv/v")) or \
                re.search(r"cross_[kv]$", pstr):
            if len(body) == 4:
                _, S, kvh, hd = body
                seq_axes = []
                if seq_parallel and S % dsize == 0:
                    seq_axes.append("data")
                if kvh % msize == 0:
                    spec[2] = "model"
                elif S % (msize * (dsize if seq_axes else 1)) == 0:
                    seq_axes.append("model")
                if seq_axes:
                    spec[1] = tuple(seq_axes)
            return P(*lead, *spec)
        if pstr.endswith("pos"):
            if len(body) == 2:
                S = body[1]
                seq_axes = []
                if seq_parallel and S % dsize == 0:
                    seq_axes.append("data")
                if cfg.n_kv_heads % msize != 0 and \
                        S % (msize * (dsize if seq_axes else 1)) == 0:
                    seq_axes.append("model")
                if seq_axes:
                    spec[1] = tuple(seq_axes)
            return P(*lead, *spec)
        # ssm / conv / lstm states: shard trailing big dims over model
        rest = auto_spec(body[1:], mesh, axes_pref=("model",))
        return P(*lead, spec[0], *rest)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
