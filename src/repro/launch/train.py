"""Production training driver.

Single-host usage (CPU dev loop / smoke):
    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b \
        --smoke --steps 30 --ckpt-dir artifacts/train

On a real pod the same driver runs under the multi-host runtime
(jax.distributed.initialize()); the mesh comes from make_production_mesh
and all sharding rules from launch.shardings. Fault tolerance: atomic
checkpoints every --ckpt-every steps, automatic resume from the newest
committed checkpoint, failure injection for drills, straggler watchdog.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.launch.plans import get_plan
from repro.models import build_model
from repro.train.data import SyntheticLM
from repro.train.fault import FailureInjector, run_resilient
from repro.train.optim import cosine_schedule, get_optimizer
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="artifacts/train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject node failures at these steps (drill)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, remat=False)
    plan = get_plan(args.arch)
    bundle = build_model(cfg)
    opt = get_optimizer(plan.optimizer)
    step_fn = jax.jit(make_train_step(
        bundle, opt, cosine_schedule(args.lr, 10, args.steps),
        microbatches=args.microbatches), donate_argnums=(0, 1))

    def extras(rng, step):
        out = {}
        if cfg.is_encoder_decoder:
            out["frame_embeds"] = rng.normal(
                size=(args.global_batch, cfg.n_audio_frames,
                      cfg.d_model)).astype("float32")
        if cfg.n_image_patches:
            import numpy as np
            out["image_embeds"] = rng.normal(
                size=(args.global_batch, cfg.n_image_patches,
                      cfg.d_model)).astype("float32")
            mask = np.zeros((args.global_batch, args.seq_len), bool)
            mask[:, 2:2 + min(cfg.n_image_patches, args.seq_len - 2)] = True
            out["image_mask"] = mask
        return out

    data = SyntheticLM(cfg.vocab, args.seq_len, args.global_batch,
                       seed=0, extras=extras)

    def init_state():
        params = bundle.init(jax.random.PRNGKey(0))
        return params, opt.init(params)

    t0 = time.time()

    def logged(params, opt_state, batch, step):
        out = step_fn(params, opt_state, batch, jnp.asarray(step, jnp.int32))
        if step % 10 == 0:
            print(f"step {step:4d}  loss {float(out[2]['loss']):.4f}",
                  flush=True)
        return out

    report = run_resilient(
        init_state=init_state, step_fn=logged,
        batch_at=lambda s: {k: jnp.asarray(v)
                            for k, v in data.batch_at(s).items()},
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        injector=FailureInjector(fail_at=args.fail_at))
    print(f"done: {report.steps_done} steps, {report.restarts} restarts, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}, "
          f"{time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
