"""Jit-cache compile accounting for the query-plan launch vocabulary.

The compile-once steady state is a claim about a FINITE set of jitted
launch functions: the fused fit, the per-kind plan launches (each with
its buffer-donating twin), and the fused posterior / fused EHVI
kernels. This module registers exactly that set and counts their
compiles via jit-cache sizes, so a service can assert "zero recompiles
after precompile" instead of hoping for it.

Counting by cache-size delta (rather than a global XLA compile hook) is
deliberate: a step also runs eager ops at genuinely varying shapes —
the remaining-candidate gathers that shrink every iteration, the
unjitted draw combine — whose op-by-op compiles are unavoidable,
cheap, and NOT part of the plan's launch vocabulary. A global counter
could never reach zero; the tracked set can, and a miss in it is
always a real hole in the precompiled bucket vocabulary.

``CompileWatcher`` snapshots the tracked cache sizes and reports the
delta; ``SearchService`` wraps each ``step`` in one to expose
``plan_compile_misses``, and ``precompile`` uses another to report how
many compiles warming the vocabulary actually cost.
"""
from __future__ import annotations

from typing import Dict

# shard-mapped launch twins register here as they are constructed: the
# static vocabulary below is closed, but the sharded twins are minted
# per (mesh, kind, donate) by ``core.plan``/``core.gp`` factories, and
# the steady-state claim must cover them too. Registration is idempotent
# for the SAME function object; re-registering a name with a different
# fn is rejected — the replaced twin's cache entries would vanish from
# the accounting, silently masking real misses (the total can even go
# DOWN). A twin registered mid-step is picked up by watchers constructed
# before it (``CompileWatcher`` re-resolves the tracked set at delta
# time), so its first compiles count as misses — which is exactly
# right, they ARE serving-time compiles.
_DYNAMIC: Dict[str, object] = {}

_STATIC_NAMES = frozenset({
    "fit", "chol_alpha", "posterior", "posterior_donated", "sample",
    "sample_donated", "loo", "loo_donated", "ehvi", "ehvi_donated",
    "fused_posterior", "fused_posterior_donated", "fused_ehvi",
    "fused_ehvi_donated", "fused_fit", "fused_fit_donated",
    "ranking_loss", "ranking_loss_donated"})


def register_launch(name: str, fn) -> None:
    """Track a dynamically-minted jitted launch (a sharded twin) in the
    compile-once accounting alongside the static vocabulary.
    Idempotent per (name, fn); a name collision with a DIFFERENT
    function raises — it would corrupt the miss accounting."""
    if name in _STATIC_NAMES:
        raise ValueError(
            f"launch name {name!r} shadows the static vocabulary")
    prev = _DYNAMIC.get(name)
    if prev is not None and prev is not fn:
        raise ValueError(
            f"launch {name!r} is already registered with a different "
            f"function; re-registration would drop its "
            f"{_cache_size(prev)} counted cache entries and corrupt "
            f"the compile-miss accounting — pick a unique name")
    _DYNAMIC[name] = fn


def tracked_launches() -> Dict[str, object]:
    """name -> jitted launch fn, lazily imported (this module must stay
    importable before the heavy model modules are)."""
    from repro.core import acquisition, gp
    from repro.kernels.fused_ehvi import ops as fused_ehvi_ops
    from repro.kernels.fused_fit import ops as fused_fit_ops
    from repro.kernels.fused_posterior import ops as fused_ops
    from repro.kernels.ranking_loss import ops as ranking_ops

    return {
        **_DYNAMIC,
        "fit": gp._fit_batched,
        "chol_alpha": gp._batched_chol_alpha,
        "posterior": gp._batched_posterior,
        "posterior_donated": gp._batched_posterior_donated,
        "sample": gp._batched_sample_launch,
        "sample_donated": gp._batched_sample_launch_donated,
        "loo": gp._batched_loo_launch,
        "loo_donated": gp._batched_loo_launch_donated,
        "ehvi": acquisition._ehvi_box_launch,
        "ehvi_donated": acquisition._ehvi_box_launch_donated,
        "fused_posterior": fused_ops._fused_launch,
        "fused_posterior_donated": fused_ops._fused_launch_donated,
        "fused_ehvi": fused_ehvi_ops._fused_ehvi_launch,
        "fused_ehvi_donated": fused_ehvi_ops._fused_ehvi_launch_donated,
        "fused_fit": fused_fit_ops._fused_fit_launch,
        "fused_fit_donated": fused_fit_ops._fused_fit_launch_donated,
        "ranking_loss": ranking_ops._ranking_loss_launch,
        "ranking_loss_donated": ranking_ops._ranking_loss_launch_donated,
    }


def _cache_size(fn) -> int:
    size = getattr(fn, "_cache_size", None)
    return int(size()) if callable(size) else 0


def cache_sizes() -> Dict[str, int]:
    """Per-launch jit-cache entry counts (one entry per compiled
    shape/static-arg combination)."""
    return {name: _cache_size(fn)
            for name, fn in tracked_launches().items()}


def total_cache_size() -> int:
    return sum(cache_sizes().values())


class CompileWatcher:
    """Delta counter over the tracked launch caches: ``misses()`` is
    how many tracked launches compiled since construction (or the last
    ``reset``). Entries are never evicted within a process, so the
    delta is exactly the number of new (shape, static-args) programs.

    The snapshot is PER NAME, and the tracked set is re-resolved at
    delta time: a sharded twin registered mid-step (after this watcher
    was constructed) is attributed in full — its baseline defaults to
    zero — and a launch absent from the delta-time set cannot offset
    other launches' misses the way a single total would."""

    def __init__(self):
        self._base = cache_sizes()

    def misses(self) -> int:
        return sum(max(0, size - self._base.get(name, 0))
                   for name, size in cache_sizes().items())

    def reset(self) -> None:
        self._base = cache_sizes()
