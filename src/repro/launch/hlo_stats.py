"""Static analysis of compiled HLO text for the roofline model.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE (verified
empirically — a 7-iteration scan reports ~1/7 of the true dot flops), so
scanned-layer models need loop-trip multiplication. This parser builds the
computation call graph, extracts while trip counts from loop conditions,
and propagates multipliers to every dot / collective:

  - dot_flops:    2 * prod(result_dims) * prod(lhs contracting dims)
  - dot_bytes:    operand + result bytes (HBM-traffic proxy; fusion reuse
                  makes this an upper bound — noted in EXPERIMENTS.md)
  - collective_bytes: per-device link traffic with ring factors
        all-reduce 2(g-1)/g * S, all-gather/all-to-all/reduce-scatter
        (g-1)/g * S, collective-permute S
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\S.*?)\s(\S+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
# one operand of an op: an optional typed shape prefix, then the %name
# (XLA prints `dot(f32[16,64]{1,0} %lhs, ...)` in compiled modules but
# bare `dot(%lhs, ...)` in hand-written ones — both must parse)
_OPERAND_RE = re.compile(
    r"(?:(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+)?%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _parse_shape(text: str) -> Tuple[int, int]:
    """First shape in `text` -> (elements, bytes). Handles tuples by
    summing components."""
    total_el, total_by = 0, 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        els = 1
        if dims:
            for d in dims.split(","):
                els *= int(d)
        total_el += els
        total_by += els * _DTYPE_BYTES[dt]
    return total_el, total_by


def _first_shape(text: str) -> Tuple[int, int]:
    m = _SHAPE_RE.search(text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return 0, 0
    els = 1
    if m.group(2):
        for d in m.group(2).split(","):
            els *= int(d)
    return els, els * _DTYPE_BYTES[m.group(1)]


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.shapes: Dict[str, str] = {}       # op name -> full def text
        self.dots: List[Tuple[int, int]] = []  # (flops, bytes)
        self.colls: List[Tuple[str, float, bool]] = []  # (kind, bytes, f32)
        self.edges: List[Tuple[str, str]] = []  # (callee, kind)
        self.consts: List[int] = []


def _dot_stats(line: str, symtab: Dict[str, str]) -> Tuple[int, int]:
    m = _DEF_RE.match(line)
    if not m:
        return 0, 0
    result_els, result_bytes = _first_shape(m.group(2))
    # contracting dims of lhs
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    ops = re.search(r"\bdot\(([^)]*)\)", line)
    flops = 0
    op_bytes = result_bytes
    if ops:
        # each operand is `[type ]%name`; resolve its shape from the
        # inline type when present, else from the defining line
        operands = [(shape or symtab.get(name, ""))
                    for shape, name in _OPERAND_RE.findall(ops.group(1))]
        lhs_m = _SHAPE_RE.search(operands[0]) if operands else None
        contract = 1
        if lhs_m and cm and cm.group(1):
            dims = [int(x) for x in lhs_m.group(2).split(",")] \
                if lhs_m.group(2) else []
            for ci in cm.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    contract *= dims[ci]
        flops = 2 * result_els * contract
        for o in operands:
            _, b = _first_shape(o)
            op_bytes += b
    return flops, op_bytes


def _collective_stats(kind: str, line: str) -> float:
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    _, local_bytes = _parse_shape(m.group(2))
    g = None
    gm = _GROUPS_RE.search(line)
    if gm:
        g = int(gm.group(2))
    else:
        gm2 = _GROUPS_EXPL_RE.search(line)
        if gm2:
            g = len(gm2.group(1).split(","))
    g = g or 2
    if kind == "all-reduce":
        return 2.0 * local_bytes * (g - 1) / g
    if kind == "collective-permute":
        return float(local_bytes)
    # all-gather result is the gathered buffer; reduce-scatter result the
    # scattered shard; all-to-all same-size. (g-1)/g of local bytes moved.
    return float(local_bytes) * (g - 1) / g


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Computation | None = None
    entry: str | None = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line):
            current = Computation(hdr.group(1))
            comps[current.name] = current
            if line.startswith("ENTRY"):
                entry = current.name
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        dm = _DEF_RE.match(line)
        if dm:
            current.shapes[dm.group(1)] = dm.group(2)
            opkind = dm.group(3)
            base = opkind.split(".")[0]
            if base == "dot":
                current.dots.append(_dot_stats(line, current.shapes))
            elif any(base.startswith(c) for c in COLLECTIVE_KINDS):
                for c in COLLECTIVE_KINDS:
                    if base.startswith(c):
                        is_f32 = dm.group(2).lstrip().startswith(
                            ("f32", "(f32"))
                        current.colls.append(
                            (c, _collective_stats(c, line), is_f32))
                        break
        wm = _WHILE_RE.search(line)
        if wm:
            # XLA's loop analysis attaches the exact trip count as
            # backend_config={"known_trip_count":{"n":...}}; prefer it
            # over the max-constant heuristic on the condition
            tm = _TRIP_RE.search(line)
            known = tm.group(1) if tm else ""
            current.edges.append((wm.group(1), "cond"))
            current.edges.append(
                (wm.group(2), f"while_body:{wm.group(1)}:{known}"))
        else:
            for cm in _CALLS_RE.finditer(line):
                current.edges.append((cm.group(1), "call"))
            for tm in _TO_APPLY_RE.finditer(line):
                current.edges.append((tm.group(1), "apply"))
        for km in _CONST_RE.finditer(line):
            current.consts.append(int(km.group(1)))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Max s32 constant reachable from the condition computation."""
    seen, stack, best = set(), [cond_name], 0
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        c = comps[name]
        if c.consts:
            best = max(best, max(c.consts))
        stack.extend(e[0] for e in c.edges)
    return max(best, 1)


def analyze(text: str) -> Dict[str, float]:
    """Returns trip-count-weighted totals per device."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"dot_flops": 0.0, "dot_bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}}

    mult: Dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    # Propagate multipliers through the call graph to a fixed point.
    # A single-visit BFS is NOT enough: a computation first discovered
    # via a low-multiplier edge (e.g. a fused computation `calls=`-ed
    # from the entry) would keep its stale multiplier for its own
    # callees when a while body later reaches it at trip-count weight —
    # exactly how scan-body dot flops used to lose the loop factor.
    # HLO call graphs are acyclic, so len(comps) sweeps always reach the
    # fixed point; the explicit bound keeps malformed (cyclic) input
    # from hanging the parser instead of returning a finite answer.
    changed = True
    sweeps = 0
    while changed and sweeps <= len(comps):
        changed = False
        sweeps += 1
        for name in list(mult):
            c = comps.get(name)
            if c is None:
                continue
            for callee, kind in c.edges:
                m = mult[name]
                if kind.startswith("while_body:"):
                    _, cond, known = kind.split(":", 2)
                    m = m * (int(known) if known
                             else _trip_count(comps, cond))
                if callee in comps and mult[callee] < m:
                    mult[callee] = m
                    changed = True

    dot_flops = dot_bytes = coll_bytes = coll_bf16eq = 0.0
    coll_by_kind: Dict[str, float] = defaultdict(float)
    n_coll = 0
    for name, c in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for f, b in c.dots:
            dot_flops += m * f
            dot_bytes += m * b
        for kind, b, is_f32 in c.colls:
            coll_bytes += m * b
            # XLA-CPU promotes bf16 collectives to f32; a TPU lowering
            # keeps bf16 — count f32 collectives at half size for the
            # TPU-equivalent estimate (fp32-native collectives are rare
            # in this codebase: grads/activations are bf16 on the wire)
            coll_bf16eq += m * (b / 2.0 if is_f32 else b)
            coll_by_kind[kind] += m * b
            n_coll += 1
    return {
        "dot_flops": dot_flops,
        "dot_bytes": dot_bytes,
        "collective_bytes": coll_bytes,
        "collective_bytes_bf16eq": coll_bf16eq,
        "collectives": dict(coll_by_kind),
        "n_collective_sites": n_coll,
    }
