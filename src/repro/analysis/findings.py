"""The structured finding model shared by every analysis rule.

A ``Finding`` pins (rule, severity, launch, path): ``launch`` is the
tracked launch family or source location the finding is about, ``path``
the evidence — a taint chain of primitives, a parameter name, a
signature tuple, a colliding key pair. Findings are data, not log
lines: the lint CLI serialises them to JSON for the CI artifact and the
golden tests assert on their fields.

Suppression: a finding may be waived with a JUSTIFICATION STRING keyed
by ``(rule, launch, path)`` in ``SUPPRESSIONS``. Suppressed findings
are kept (demoted to ``info`` and carrying the justification) so the
JSON artifact still shows them — a suppression without a justification
is impossible by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

SEVERITY_ORDER = {"info": 0, "warning": 1, "error": 2}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                 # padding-taint | donation-safety | ...
    severity: str             # error | warning | info
    launch: str               # launch family / source site ("" = global)
    path: str                 # evidence: taint chain, param, signature
    message: str = ""
    suppressed: str = ""      # justification when waived

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.launch, self.path)


# (rule, launch, path) -> justification. The only waiver on the current
# tree: the fused fit's learning rate arrives as a Python-float default
# and traces as a weak-typed f32 scalar. It is a config constant that
# never varies at serving time, so it costs exactly one jit-cache entry
# — the sharded fit twins even lift it to a static argname.
SUPPRESSIONS: Dict[Tuple[str, str, str], str] = {
    ("vocab-closure", "fit", "lr"):
        "lr is a fixed config constant (0.05): weak f32 scalar, one "
        "cache entry, lifted to a static argname on the sharded twins",
}


def apply_suppressions(
    findings: Sequence[Finding],
    suppressions: Optional[Dict[Tuple[str, str, str], str]] = None,
) -> List[Finding]:
    """Demote findings with a registered justification to ``info`` and
    attach the justification; everything else passes through."""
    table = SUPPRESSIONS if suppressions is None else suppressions
    out = []
    for f in findings:
        just = table.get(f.key())
        if just:
            f = dataclasses.replace(f, severity="info", suppressed=just)
        out.append(f)
    return out


def max_severity(findings: Sequence[Finding]) -> str:
    if not findings:
        return "info"
    return max(findings, key=lambda f: SEVERITY_ORDER[f.severity]).severity


def to_dicts(findings: Sequence[Finding]) -> List[dict]:
    return [dataclasses.asdict(f) for f in findings]
