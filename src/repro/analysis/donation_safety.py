"""Rule ``donation-safety``: donated buffers are rebuilt, never reread.

Three checks over the donation story:

1. **Donated-parameter classification** — every ``donate_argnums`` site
   (the jitted twins in ``core/gp.py`` / ``core/acquisition.py`` / the
   kernel ``ops.py`` dispatchers, found by AST, plus the sharded-twin
   table ``core.plan._shard_base``, read at runtime) may donate only
   parameters the executor rebuilds each step. Session-cached state —
   the hyperparameter rows ``log_ls``/``log_sf`` and PRNG ``keys`` —
   must never be donated: XLA would reuse the cached buffer for
   intermediates and the NEXT step would read garbage.

2. **Twin agreement** — a donating twin must accept exactly the plain
   launch's positional arity and produce identical output avals
   (``jax.eval_shape`` on the analysis fixtures): a drifting twin pair
   silently forks the launch vocabulary.

3. **Post-donation reads** — no ``PlanExecutor._exec_*`` method may
   read a DONATED launch-argument buffer after the launch call (the
   donated buffer is dead; non-donated arguments stay live, so e.g.
   the fit leg may seed its ``BatchedGP`` from x/y/mask after
   launching), and any method assembling lanes through
   ``_stack_parts`` (whose single-query case can RETURN a session's
   cached arrays) must route them through the ``_fresh_parts`` aliasing
   guard before launching.
"""
from __future__ import annotations

import ast
import inspect
from typing import Dict, List, Optional, Sequence, Tuple

from .findings import Finding

# Parameters holding session-cached state: never donatable. Everything
# the executors pass positionally besides these is rebuilt per step
# (stacked observation caches, padded grids, box decompositions, fresh
# draws) — see the per-site comments in core/gp.py and core/plan.py.
NON_DONATABLE = frozenset({"log_ls", "log_sf", "keys"})


# ---------------------------------------------------------------------------
# Check 1: donate_argnums sites donate only rebuilt buffers
# ---------------------------------------------------------------------------


def _param_names_of(node: ast.AST, tree: ast.Module
                    ) -> Optional[List[str]]:
    """Positional parameter names of a jit's first argument: a lambda,
    a ``Name`` of a module-level def, or ``<def>.__wrapped__``."""
    if isinstance(node, ast.Lambda):
        return [a.arg for a in node.args.args]
    target = None
    if isinstance(node, ast.Name):
        target = node.id
    elif (isinstance(node, ast.Attribute) and node.attr == "__wrapped__"
          and isinstance(node.value, ast.Name)):
        target = node.value.id
    if target is None:
        return None
    for item in ast.walk(tree):
        if isinstance(item, ast.FunctionDef) and item.name == target:
            return [a.arg for a in item.args.args]
    return None


def _donation_sites(tree: ast.Module) -> List[Tuple[int, List[str],
                                                    List[int]]]:
    """(lineno, param names, donated indices) for each
    ``jax.jit(..., donate_argnums=...)`` call in ``tree``."""
    sites = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and ((isinstance(node.func, ast.Attribute)
                      and node.func.attr == "jit")
                     or (isinstance(node.func, ast.Name)
                         and node.func.id == "jit"))):
            continue
        donated = None
        for kw in node.keywords:
            if kw.arg == "donate_argnums":
                if isinstance(kw.value, ast.Tuple):
                    donated = [c.value for c in kw.value.elts
                               if isinstance(c, ast.Constant)]
                elif isinstance(kw.value, ast.Constant):
                    donated = [kw.value.value]
        if donated is None or not node.args:
            continue
        names = _param_names_of(node.args[0], tree)
        if names is not None:
            sites.append((node.lineno, names, donated))
    return sites


def check_donated_params(source: str, label: str) -> List[Finding]:
    """Flag donate_argnums entries naming session-cached parameters."""
    out: List[Finding] = []
    tree = ast.parse(source)
    for lineno, names, donated in _donation_sites(tree):
        for idx in donated:
            if not isinstance(idx, int) or idx >= len(names):
                out.append(Finding(
                    "donation-safety", "error", label,
                    f"{label}:{lineno}",
                    f"donate_argnums index {idx!r} out of range for "
                    f"params {names}"))
                continue
            if names[idx] in NON_DONATABLE:
                out.append(Finding(
                    "donation-safety", "error", label,
                    f"{label}:{lineno}:{names[idx]}",
                    f"donates session-cached parameter "
                    f"{names[idx]!r} (arg {idx}); only per-step-"
                    f"rebuilt buffers may be donated"))
    return out


def _module_sources() -> List[Tuple[str, str]]:
    import repro.core.acquisition
    import repro.core.gp
    import repro.core.plan
    import repro.kernels.fused_ehvi.ops
    import repro.kernels.fused_fit.ops
    import repro.kernels.fused_posterior.ops
    import repro.kernels.ranking_loss.ops
    mods = [repro.core.gp, repro.core.acquisition, repro.core.plan,
            repro.kernels.fused_posterior.ops,
            repro.kernels.fused_ehvi.ops,
            repro.kernels.fused_fit.ops,
            repro.kernels.ranking_loss.ops]
    return [(m.__name__, inspect.getsource(m)) for m in mods]


def check_shard_base() -> List[Finding]:
    """The sharded-twin donation table must classify like the
    single-device twins: donated names rebuilt-only, and per-kind
    donated index sets must match the in-tree jit twins (drift between
    the two donation vocabularies is a silent fork)."""
    from repro.core.plan import _shard_base
    out: List[Finding] = []
    # single-device donated index sets per base-body name, from AST
    single: Dict[Tuple[str, ...], Tuple[int, ...]] = {}
    for label, src in _module_sources():
        for _lineno, names, donated in _donation_sites(ast.parse(src)):
            # "impl" is a trailing static config arg, not a buffer; the
            # runtime signatures below exclude it the same way
            single[tuple(n for n in names if n != "impl")] = \
                tuple(donated)
    for kind in ("posterior", "sample", "loo", "ehvi",
                 "fused_posterior", "fused_ehvi", "fused_fit"):
        base, _has_impl, donate_nums = _shard_base(kind)
        params = [p for p in inspect.signature(base).parameters
                  if p != "impl"]
        for idx in donate_nums:
            if params[idx] in NON_DONATABLE:
                out.append(Finding(
                    "donation-safety", "error", kind,
                    f"_shard_base:{params[idx]}",
                    f"sharded {kind} twin donates session-cached "
                    f"parameter {params[idx]!r}"))
        expected = single.get(tuple(params))
        if expected is not None and tuple(donate_nums) != expected:
            out.append(Finding(
                "donation-safety", "error", kind,
                f"_shard_base:{tuple(donate_nums)}",
                f"sharded {kind} twin donates {tuple(donate_nums)} "
                f"but the single-device twin donates {expected}"))
    return out


# ---------------------------------------------------------------------------
# Check 2: twin pairs agree on arity and output avals
# ---------------------------------------------------------------------------


def check_twin_agreement(specs=None) -> List[Finding]:
    import jax

    from .padding_taint import launch_specs
    specs = launch_specs() if specs is None else specs
    out: List[Finding] = []
    for spec in specs:
        plain, donated = (spec.twins + (None, None))[:2]
        if plain is None or donated is None:
            continue
        avals = []
        for fn in (plain, donated):
            try:
                shaped = jax.eval_shape(fn, *spec.args)
            except Exception as exc:   # arity / dtype disagreement
                out.append(Finding(
                    "donation-safety", "error", spec.name,
                    f"twin:{getattr(fn, '__name__', fn)!r}",
                    f"twin does not accept the launch arguments: "
                    f"{exc}"))
                shaped = None
            avals.append(jax.tree_util.tree_map(
                lambda l: (l.shape, str(l.dtype)), shaped))
        if None not in avals and avals[0] != avals[1]:
            out.append(Finding(
                "donation-safety", "error", spec.name, "twin:avals",
                f"plain and donated twins disagree on output avals: "
                f"{avals[0]} vs {avals[1]}"))
    return out


# ---------------------------------------------------------------------------
# Check 3: no Python-level read of a donated buffer after launch
# ---------------------------------------------------------------------------


def _call_arg_names(call: ast.Call,
                    donated: Optional[Sequence[int]] = None) -> List[str]:
    """Names of the call's positional buffer arguments. With ``donated``
    given, only the names at those positions — the buffers actually
    dead after the launch; a ``*splat`` erases the position mapping, so
    it conservatively reinstates every name."""
    names, starred = [], False
    for a in call.args:
        if isinstance(a, ast.Starred) and isinstance(a.value, ast.Name):
            names.append(a.value.id)
            starred = True
        elif isinstance(a, ast.Name):
            names.append(a.id)
    if donated is None or starred:
        return names
    return [n for i, n in enumerate(names) if i in donated]


def _launch_kind(call: ast.Call) -> Optional[str]:
    """The kind string of a ``self._launch("<kind>", ...)`` call."""
    if (call.args and isinstance(call.args[0], ast.Constant)
            and isinstance(call.args[0].value, str)):
        return call.args[0].value
    return None


def _donated_positions(kind: Optional[str]) -> Optional[Tuple[int, ...]]:
    """Donated argument positions of a launch kind, from the runtime
    sharded-twin table (the single source for per-kind donate_argnums);
    None when the kind is unknown there — the caller then treats every
    argument as potentially donated."""
    if kind is None:
        return None
    try:
        from repro.core.plan import _shard_base
        _base, _has_impl, donate_nums = _shard_base(kind)
    except Exception:
        return None
    return tuple(donate_nums)


def check_post_donation_reads(source: Optional[str] = None,
                              label: str = "core.plan") -> List[Finding]:
    """Within every ``_exec_*`` method: after the ``launch(...)`` call
    (the name bound from ``self._launch``), none of the call's DONATED
    argument buffers may be read again (non-donated arguments stay live
    by construction; when the kind's donated positions are unknown or a
    ``*splat`` hides them, every argument is treated as donated); and a
    method assembling parts via ``self._stack_parts`` must guard them
    with ``self._fresh_parts``."""
    if source is None:
        import repro.core.plan
        source = inspect.getsource(repro.core.plan)
    out: List[Finding] = []
    for node in ast.walk(ast.parse(source)):
        if not (isinstance(node, ast.FunctionDef)
                and node.name.startswith("_exec_")):
            continue
        launch_names = set()
        launch_kind: Optional[str] = None
        calls_stack_parts = calls_fresh_parts = False
        last_launch_line = None
        launch_args: List[str] = []
        for item in ast.walk(node):
            if isinstance(item, ast.Assign) and isinstance(
                    item.value, ast.Call):
                f = item.value.func
                if (isinstance(f, ast.Attribute)
                        and f.attr == "_launch"):
                    for t in item.targets:
                        if isinstance(t, ast.Name):
                            launch_names.add(t.id)
                    launch_kind = _launch_kind(item.value)
            if isinstance(item, ast.Call) and isinstance(
                    item.func, ast.Attribute):
                if item.func.attr == "_stack_parts":
                    calls_stack_parts = True
                if item.func.attr == "_fresh_parts":
                    calls_fresh_parts = True
        for item in ast.walk(node):
            if (isinstance(item, ast.Call)
                    and isinstance(item.func, ast.Name)
                    and item.func.id in launch_names):
                # a multi-line call's arguments sit past its first
                # line; reads only count after the whole call ends
                last_launch_line = getattr(item, "end_lineno",
                                           item.lineno)
                launch_args = _call_arg_names(
                    item, _donated_positions(launch_kind))
        if calls_stack_parts and not calls_fresh_parts:
            out.append(Finding(
                "donation-safety", "error", node.name,
                f"{label}:{node.lineno}:_fresh_parts",
                f"{node.name} assembles lanes via _stack_parts but "
                f"never routes them through the _fresh_parts aliasing "
                f"guard — a single-query donated launch would delete "
                f"cached stack buffers"))
        if last_launch_line is None:
            continue
        for item in ast.walk(node):
            if (isinstance(item, ast.Name)
                    and isinstance(item.ctx, ast.Load)
                    and item.id in launch_args
                    and item.lineno > last_launch_line):
                out.append(Finding(
                    "donation-safety", "error", node.name,
                    f"{label}:{item.lineno}:{item.id}",
                    f"{node.name} reads {item.id!r} after passing it "
                    f"to the (potentially donating) launch — the "
                    f"buffer may already be dead"))
    return out


def check_donation_safety() -> List[Finding]:
    out: List[Finding] = []
    for label, src in _module_sources():
        out.extend(check_donated_params(src, label))
    out.extend(check_shard_base())
    out.extend(check_twin_agreement())
    out.extend(check_post_donation_reads())
    return out
