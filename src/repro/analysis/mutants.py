"""The seeded-bug corpus: one deliberately broken twin per rule.

Each mutant reproduces a real bug class this codebase has already
legislated against (dropped mask, cross-lane reduction, post-donation
read, vocabulary hole, weak-typed closure, flattened key tag) in a
minimal form, and the mutation tests assert the corresponding analysis
rule CATCHES it — the analyzer's detection power is pinned exactly like
any other behaviour, so a future refactor cannot quietly lobotomize a
rule.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# padding-taint mutants: launch bodies violating the mask discipline
# ---------------------------------------------------------------------------


def bad_mask_posterior_spec():
    """The posterior body with the observation mask DROPPED from the
    cross-kernel: padded observation rows flow straight into every
    valid lane's mean/variance."""
    from repro.core.gp import GPParams, _kernel

    from .padding_taint import LaunchSpec, launch_specs

    def body(log_ls, log_sf, x, mask, chol, alpha, xq):
        def one(ls, sf, xi, mi, ci, ai, xqi):
            params = GPParams(ls, sf, 0.0)
            ks = _kernel(params, xqi, xi)          # BUG: no * mi[None, :]
            mu = ks @ ai
            v = jax.scipy.linalg.solve_triangular(ci, ks.T, lower=True)
            var = jnp.maximum(jnp.exp(sf) - jnp.sum(v * v, axis=0),
                              1e-10)
            return mu, var

        return jax.vmap(one)(log_ls, log_sf, x, mask, chol, alpha, xq)

    base = next(s for s in launch_specs() if s.name == "posterior")
    return dataclasses.replace(base, name="posterior[bad-mask]",
                               fn=body, twins=())


def lane_leak_posterior_spec():
    """The posterior body plus a cross-lane normalisation: throwaway
    pad lanes contaminate every real lane's mean."""
    from functools import partial

    from repro.core.gp import _batched_posterior

    from .padding_taint import launch_specs

    good = partial(_batched_posterior.__wrapped__, impl="xla")

    def body(log_ls, log_sf, x, mask, chol, alpha, xq):
        mu, var = good(log_ls, log_sf, x, mask, chol, alpha, xq)
        # BUG: reduction over the padded lane axis
        return mu - jnp.mean(mu, axis=0, keepdims=True), var

    base = next(s for s in launch_specs() if s.name == "posterior")
    return dataclasses.replace(base, name="posterior[lane-leak]",
                               fn=body, twins=())


# ---------------------------------------------------------------------------
# donation-safety mutants: source snippets with broken donation
# ---------------------------------------------------------------------------

DONATES_CACHED_PARAM_SRC = '''
import jax

def _body(log_ls, log_sf, x, mask):
    return (log_ls + log_sf[..., None]) * x * mask[..., None]

_bad_twin = jax.jit(_body, donate_argnums=(0, 2))
'''

POST_DONATION_READ_SRC = '''
class _BadExecutor:
    def _exec_posterior(self, bucket, queries, plan, impl):
        q, d = bucket.key
        parts = self._fresh_parts(
            queries, self._stack_parts(queries, bucket.pads["n_pad"],
                                       q, d))
        launch = self._launch("posterior", _plain, _donated)
        mu, var = launch(*parts)
        # BUG: parts[4] may be donated (dead) by now
        return [(mu, var, parts[4].sum())]
'''

MISSING_ALIAS_GUARD_SRC = '''
class _BadExecutor:
    def _exec_posterior(self, bucket, queries, plan, impl):
        q, d = bucket.key
        # BUG: single-query buckets may alias session caches
        parts = self._stack_parts(queries, bucket.pads["n_pad"], q, d)
        launch = self._launch("posterior", _plain, _donated)
        mu, var = launch(*parts)
        return [(mu, var)]
'''


# ---------------------------------------------------------------------------
# vocab-closure mutants
# ---------------------------------------------------------------------------


def vocab_hole_planner_factory():
    """Planner whose enumerated box ladder forgets every intermediate
    power-of-two rung: any live front below the maximum emits a
    signature the precompiled vocabulary never saw."""
    from repro.core.plan import StepPlanner, _pow2

    class VocabHolePlanner(StepPlanner):
        def _box_pads(self, max_boxes):
            return [_pow2(max_boxes)]       # BUG: ladder truncated

    return lambda shards: VocabHolePlanner(lane_shards=shards)


def fit_rung_hole_planner_factory():
    """Planner whose fit enumeration forgets the warm (short-refine)
    ``steps`` rung: the service's warm cache still emits warm-steps
    ``FitQuery`` nodes, so every warm fit bucket carries a signature
    the precompiled vocabulary never saw — a serving-time compile on
    the supposedly compile-free steady state."""
    from repro.core.plan import StepPlanner

    class FitRungHolePlanner(StepPlanner):
        def fit_step_rungs(self, limits):
            return [int(limits.fit_steps)]   # BUG: warm rung dropped

    return lambda shards: FitRungHolePlanner(lane_shards=shards)


def weak_type_posterior_spec():
    """A launch fixture smuggling a Python scalar into the traced
    arguments — the jit cache would fork per value."""
    from .padding_taint import launch_specs

    base = next(s for s in launch_specs() if s.name == "posterior")

    def body(log_ls, log_sf, x, mask, chol, alpha, xq, jitter):
        return base.fn(log_ls, log_sf, x, mask, chol + jitter, alpha,
                       xq)

    return dataclasses.replace(
        base, name="posterior[weak-type]", fn=body,
        args=base.args + (0.0,),             # BUG: weak Python float
        taints=base.taints + (np.zeros((), bool),),
        arg_names=base.arg_names + ("jitter",), twins=())


# ---------------------------------------------------------------------------
# prng-audit mutants
# ---------------------------------------------------------------------------


def colliding_derive_key(base, purpose, it, index):
    """The pre-PR-5 flattened tag: one fold of an arithmetic packing
    whose integer space aliases across components."""
    return jax.random.fold_in(base, purpose * 1000 + it * 10 + index)


ARITHMETIC_TAG_SRC = '''
import jax

def _draw_key(key, it, oi):
    return jax.random.fold_in(key, 1000 + it * 10 + oi)
'''
