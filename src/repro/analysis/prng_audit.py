"""Rule ``prng-audit``: the derive_key schedule is collision-free.

Reproducibility across the fused/vmapped/sharded execution paths rests
on every consumer deriving its keys through the same
``derive_key(base, purpose, iteration, index)`` tree. Two failure
classes are audited:

1. **Structural** (AST, over ``core/bo.py`` + the service): a
   ``fold_in`` tag built from ARITHMETIC (``purpose * K + it``) can
   collide for in-range values — every fold tag must be a plain
   name/constant, every ``derive_key`` call site must pass a
   ``KEY_PURPOSE_*`` constant, and the declared purpose registry
   (``bo.KEY_PURPOSES``, mirrored by the service's ``KEY_SCHEDULE``)
   must be distinct and complete.

2. **Behavioural** (concrete enumeration): ``derive_key`` evaluated
   over the full purpose set x iterations x indices must produce
   pairwise-distinct key data. The ranges cover the collision windows
   arithmetic encodings actually alias in (index spans crossing an
   iteration step), so the seeded-bug corpus's flattened-tag mutant is
   caught by construction.
"""
from __future__ import annotations

import ast
import inspect
from typing import Callable, List, Optional, Sequence

from .findings import Finding

AUDIT_ITERS = range(8)
AUDIT_INDICES = range(12)


def _prng_sources():
    import repro.core.bo
    import repro.serve.search_service
    return [(m.__name__, inspect.getsource(m))
            for m in (repro.core.bo, repro.serve.search_service)]


def check_fold_in_tags(source: Optional[str] = None,
                       label: str = "") -> List[Finding]:
    """Flag arithmetic fold_in tags and non-constant derive_key
    purposes."""
    sources = ([(label, source)] if source is not None
               else _prng_sources())
    out: List[Finding] = []
    for mod_label, src in sources:
        for node in ast.walk(ast.parse(src)):
            if not isinstance(node, ast.Call):
                continue
            fname = None
            if isinstance(node.func, ast.Attribute):
                fname = node.func.attr
            elif isinstance(node.func, ast.Name):
                fname = node.func.id
            if fname == "fold_in":
                for arg in node.args[1:]:
                    if isinstance(arg, ast.BinOp):
                        out.append(Finding(
                            "prng-audit", "error", mod_label,
                            f"{mod_label}:{node.lineno}",
                            "fold_in tag is an arithmetic expression "
                            "— flattened encodings alias distinct "
                            "(purpose, iteration, index) paths; fold "
                            "each component separately"))
            if fname == "derive_key" and len(node.args) >= 2:
                purpose = node.args[1]
                named = (isinstance(purpose, ast.Name)
                         and purpose.id.startswith("KEY_PURPOSE_"))
                const = isinstance(purpose, ast.Constant)
                is_def_param = isinstance(purpose, ast.Name)
                if not (named or const or is_def_param):
                    out.append(Finding(
                        "prng-audit", "warning", mod_label,
                        f"{mod_label}:{node.lineno}",
                        "derive_key purpose is not a KEY_PURPOSE_* "
                        "constant"))
    return out


def check_purpose_registry() -> List[Finding]:
    """Purposes distinct; every KEY_PURPOSE_* constant registered; the
    service's declared schedule covers the same set."""
    from repro.core import bo
    out: List[Finding] = []
    values = list(bo.KEY_PURPOSES.values())
    if len(set(values)) != len(values):
        out.append(Finding(
            "prng-audit", "error", "core.bo", "KEY_PURPOSES",
            f"purpose tags collide: {bo.KEY_PURPOSES}"))
    declared = {name: getattr(bo, name) for name in dir(bo)
                if name.startswith("KEY_PURPOSE_")}
    missing = {n: v for n, v in declared.items() if v not in values}
    if missing:
        out.append(Finding(
            "prng-audit", "error", "core.bo", "KEY_PURPOSES",
            f"purpose constants not in the registry: {missing}"))
    try:
        from repro.serve import search_service
        schedule = {p for p, _desc in search_service.KEY_SCHEDULE}
        if schedule != set(values):
            out.append(Finding(
                "prng-audit", "error", "serve.search_service",
                "KEY_SCHEDULE",
                f"service schedule purposes {schedule} != registry "
                f"{set(values)}"))
    except Exception as exc:
        out.append(Finding(
            "prng-audit", "warning", "serve.search_service",
            "KEY_SCHEDULE", f"schedule not inspectable: {exc}"))
    return out


def check_schedule_collisions(
    derive: Optional[Callable] = None,
    purposes: Optional[Sequence[int]] = None,
    iters: Sequence[int] = AUDIT_ITERS,
    indices: Sequence[int] = AUDIT_INDICES,
) -> List[Finding]:
    """Concretely enumerate the schedule and demand distinct key
    data."""
    import jax
    import numpy as np

    from repro.core import bo
    derive = bo.derive_key if derive is None else derive
    purposes = (sorted(bo.KEY_PURPOSES.values()) if purposes is None
                else purposes)
    base = jax.random.PRNGKey(0)
    seen = {}
    out: List[Finding] = []
    for p in purposes:
        for it in iters:
            for idx in indices:
                data = np.asarray(derive(base, p, it, idx)).tobytes()
                if data in seen:
                    out.append(Finding(
                        "prng-audit", "error", "derive_key",
                        f"{(p, it, idx)} == {seen[data]}",
                        "two (purpose, iteration, index) paths derive "
                        "the same key: streams would be correlated"))
                    return out
                seen[data] = (p, it, idx)
    return out


def check_prng_audit() -> List[Finding]:
    return (check_fold_in_tags() + check_purpose_registry()
            + check_schedule_collisions())
