"""Rule ``padding-taint``: padded regions cannot reach valid outputs.

One ``LaunchSpec`` per tracked launch family (fit, chol_alpha,
posterior, sample, loo, ehvi, the padded ranking loss, and the fused
Pallas kernels — posterior, EHVI, fit — via their XLA ref twins — the
jaxpr-level dataflow is the kernels' specification,
and the donated / sharded twins jit the SAME bodies, so one spec covers
the family). Each spec carries concrete example arguments exercising
every pad axis the executor can produce, a taint mask marking the FREE
padded regions, and a valid-region mask per output; the differential
interpreter in ``taint`` then proves no free pad value can perturb a
valid-region result.

Free vs contract-pinned pads: a free region may hold ANYTHING (padded
observation rows, padded alpha/y entries, padded grid columns, padded
draw columns, entire throwaway lanes) — the launch must mask it out.
A pinned region's VALUE is part of the launch contract (the padded
Cholesky block's unit diagonal / zero off-blocks, the +inf EHVI padding
boxes): launches legitimately rely on those values, so they are not
taint sources here — instead ``chol_alpha``'s spec proves the pinned
Cholesky structure is itself never contaminated by free pads, and the
executors construct the +inf paddings from constants every step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .findings import Finding
from .taint import taint_trace


@dataclasses.dataclass
class LaunchSpec:
    """A launch family's static-analysis fixture."""
    name: str                  # tracked launch family name
    fn: Callable               # unjitted body, static kwargs bound
    args: Tuple                # concrete example arguments
    taints: Tuple              # bool mask per arg: free padded regions
    valid_outs: Tuple          # bool mask per FLAT output: valid region
    arg_names: Tuple[str, ...] = ()   # for weak-type reporting
    twins: Tuple = ()          # jitted (plain, donated) pair, if any


def _zeros_like_masks(args) -> List[np.ndarray]:
    return [np.zeros(np.shape(a), bool) for a in args]


def _stack_fixture():
    """A 4-lane stacked-GP fixture: lanes 0/1 real (5 and 3 valid
    observations of 8 padded), lanes 2/3 throwaway copies of lane 0 —
    exactly what ``_stack_parts`` + ``_pad_lanes`` assemble."""
    from repro.core import gp as gp_mod
    rng = np.random.default_rng(0)
    m_valid, m_pad, n_pad, d = 2, 4, 8, 2
    ns = (5, 3)
    x = np.zeros((m_pad, n_pad, d), np.float32)
    y = np.zeros((m_pad, n_pad), np.float32)
    mask = np.zeros((m_pad, n_pad), np.float32)
    for i, n in enumerate(ns):
        x[i, :n] = rng.uniform(0.0, 1.0, (n, d))
        y[i, :n] = rng.normal(0.0, 1.0, (n,))
        mask[i, :n] = 1.0
    x[m_valid:] = x[0]
    y[m_valid:] = y[0]
    mask[m_valid:] = mask[0]
    log_ls = rng.normal(0.0, 0.3, (m_pad, d)).astype(np.float32)
    log_sf = rng.normal(0.0, 0.3, (m_pad,)).astype(np.float32)
    log_ls[m_valid:] = log_ls[0]
    log_sf[m_valid:] = log_sf[0]
    chol, alpha = gp_mod._batched_chol_alpha(log_ls, log_sf, x, y, mask,
                                             0.1)
    chol = np.asarray(chol)
    alpha = np.asarray(alpha)

    def obs_pad_mask(shape_tail=()):
        """True at padded observation rows of valid lanes and on every
        throwaway lane."""
        t = np.zeros((m_pad, n_pad) + shape_tail, bool)
        for i, n in enumerate(ns):
            t[i, n:] = True
        t[m_valid:] = True
        return t

    def lane_pad_mask(shape):
        t = np.zeros(shape, bool)
        t[m_valid:] = True
        return t

    return dict(rng=rng, m_valid=m_valid, m_pad=m_pad, n_pad=n_pad, d=d,
                ns=ns, x=x, y=y, mask=mask, log_ls=log_ls,
                log_sf=log_sf, chol=chol, alpha=alpha,
                obs_pad_mask=obs_pad_mask, lane_pad_mask=lane_pad_mask)


def _gp_specs() -> List[LaunchSpec]:
    from repro.core import gp as gp_mod
    fx = _stack_fixture()
    rng = fx["rng"]
    m_valid, m_pad, n_pad, d = (fx["m_valid"], fx["m_pad"], fx["n_pad"],
                                fx["d"])
    lane = fx["lane_pad_mask"]
    obs = fx["obs_pad_mask"]
    valid_lanes_mask = lambda shape: ~lane(shape)
    specs = []

    # --- fit: (x, y, mask, lr) -> {"ls": (m, d), "sf": (m,)} ---------
    fit_body = gp_mod._fit_batched.__wrapped__
    specs.append(LaunchSpec(
        name="fit",
        fn=lambda x, y, mask, lr: fit_body(x, y, mask, steps=2,
                                           noise=0.1, lr=lr),
        args=(fx["x"], fx["y"], fx["mask"], 0.05),
        taints=(obs((d,)), obs(), lane((m_pad, n_pad)),
                np.zeros((), bool)),
        valid_outs=(valid_lanes_mask((m_pad, d)),        # ls
                    valid_lanes_mask((m_pad,))),         # sf
        arg_names=("x", "y", "mask", "lr"),
        twins=(gp_mod._fit_batched, None)))

    # --- chol_alpha: the pinned-pad producer. Its whole valid-lane
    # Cholesky output (INCLUDING the unit-diagonal pad block downstream
    # launches rely on) must be untouchable by free pads; alpha's
    # padded entries mirror y's padded entries, so only its valid
    # entries are claimed.
    ca_valid_chol = valid_lanes_mask((m_pad, n_pad, n_pad))
    ca_valid_alpha = np.zeros((m_pad, n_pad), bool)
    for i, n in enumerate(fx["ns"]):
        ca_valid_alpha[i, :n] = True
    specs.append(LaunchSpec(
        name="chol_alpha",
        fn=partial(gp_mod._batched_chol_alpha.__wrapped__, noise=0.1),
        args=(fx["log_ls"], fx["log_sf"], fx["x"], fx["y"], fx["mask"]),
        taints=(lane((m_pad, d)), lane((m_pad,)), obs((d,)), obs(),
                lane((m_pad, n_pad))),
        valid_outs=(ca_valid_chol, ca_valid_alpha),
        arg_names=("log_ls", "log_sf", "x", "y", "mask"),
        twins=(gp_mod._batched_chol_alpha, None)))

    # --- posterior: q exact (the service always queries the full grid)
    q = 4
    xq = rng.uniform(0.0, 1.0, (m_pad, q, d)).astype(np.float32)
    xq[m_valid:] = xq[0]
    alpha_taint = obs()          # padded alpha entries + pad lanes free
    post_args = (fx["log_ls"], fx["log_sf"], fx["x"], fx["mask"],
                 fx["chol"], fx["alpha"], xq)
    post_taints = (lane((m_pad, d)), lane((m_pad,)), obs((d,)),
                   lane((m_pad, n_pad)),           # mask values pinned
                   lane((m_pad, n_pad, n_pad)),    # chol pads pinned
                   alpha_taint, lane((m_pad, q, d)))
    post_names = ("log_ls", "log_sf", "x", "mask", "chol", "alpha",
                  "xq")
    specs.append(LaunchSpec(
        name="posterior",
        fn=partial(gp_mod._batched_posterior.__wrapped__, impl="xla"),
        args=post_args, taints=post_taints,
        valid_outs=(valid_lanes_mask((m_pad, q)),
                    valid_lanes_mask((m_pad, q))),
        arg_names=post_names,
        twins=(gp_mod._batched_posterior,
               gp_mod._batched_posterior_donated)))

    # --- sample: adds the padded grid axis and the eps draw tensor ---
    s, q_s, q_pad = 3, 5, 8
    xq_s = np.zeros((m_pad, q_pad, d), np.float32)
    xq_s[:, :q_s] = rng.uniform(0.0, 1.0, (m_pad, q_s, d))
    xq_s[:, q_s:] = xq_s[:, q_s - 1:q_s]     # edge-padded grid rows
    xq_s[m_valid:] = xq_s[0]
    eps = np.zeros((m_pad, s, q_pad), np.float32)
    eps[:, :, :q_s] = rng.normal(0.0, 1.0, (m_pad, s, q_s))
    eps[m_valid:] = eps[0]
    xq_taint = np.zeros((m_pad, q_pad, d), bool)
    xq_taint[:, q_s:] = True          # edge-padded grid rows are free
    xq_taint[m_valid:] = True
    eps_taint = np.zeros((m_pad, s, q_pad), bool)
    eps_taint[:, :, q_s:] = True      # zero-padded draw columns free
    eps_taint[m_valid:] = True
    sample_valid = np.zeros((m_pad, s, q_pad), bool)
    sample_valid[:m_valid, :, :q_s] = True
    specs.append(LaunchSpec(
        name="sample",
        fn=partial(gp_mod._batched_sample_launch.__wrapped__,
                   impl="xla"),
        args=(fx["log_ls"], fx["log_sf"], fx["x"], fx["mask"],
              fx["chol"], fx["alpha"], xq_s, eps),
        taints=(lane((m_pad, d)), lane((m_pad,)), obs((d,)),
                lane((m_pad, n_pad)), lane((m_pad, n_pad, n_pad)),
                alpha_taint, xq_taint, eps_taint),
        valid_outs=(sample_valid,),
        arg_names=post_names + ("eps",),
        twins=(gp_mod._batched_sample_launch,
               gp_mod._batched_sample_launch_donated)))

    # --- loo: block-padded per-target chol/alpha/y + padded draws ----
    n_loo, l_valid, l_pad, s_loo = int(fx["ns"][0]), 2, 4, 3
    p = n_pad - n_loo
    chol_l = np.zeros((l_pad, n_pad, n_pad), np.float32)
    alpha_l = np.zeros((l_pad, n_pad), np.float32)
    y_l = np.zeros((l_pad, n_pad), np.float32)
    bump = np.diag(np.concatenate([np.zeros(n_loo), np.ones(p)]))
    for j in range(l_valid):
        # lane 0's valid block reused per target: structure is what the
        # rule exercises, not the particular factor
        chol_l[j, :n_loo, :n_loo] = fx["chol"][0][:n_loo, :n_loo]
        chol_l[j] += bump.astype(np.float32)
        alpha_l[j, :n_loo] = fx["alpha"][0][:n_loo]
        y_l[j, :n_loo] = fx["y"][0][:n_loo]
    chol_l[l_valid:] = chol_l[0]
    alpha_l[l_valid:] = alpha_l[0]
    y_l[l_valid:] = y_l[0]
    eps_l = np.zeros((l_pad, s_loo, n_pad), np.float32)
    eps_l[:, :, :n_loo] = rng.normal(0.0, 1.0, (l_pad, s_loo, n_loo))

    def loo_pad(shape_tail=()):
        t = np.zeros((l_pad, n_pad) + shape_tail, bool)
        t[:, n_loo:] = True
        t[l_valid:] = True
        return t

    lane_l = np.zeros((l_pad, n_pad, n_pad), bool)
    lane_l[l_valid:] = True
    eps_l_taint = np.zeros((l_pad, s_loo, n_pad), bool)
    eps_l_taint[:, :, n_loo:] = True
    eps_l_taint[l_valid:] = True
    loo_valid = np.zeros((l_pad, s_loo, n_pad), bool)
    loo_valid[:l_valid, :, :n_loo] = True
    specs.append(LaunchSpec(
        name="loo",
        fn=gp_mod._batched_loo_launch.__wrapped__,
        args=(chol_l, alpha_l, y_l, eps_l),
        taints=(lane_l,          # chol pads pinned, only lanes free
                loo_pad(), loo_pad(), eps_l_taint),
        valid_outs=(loo_valid,),
        arg_names=("chol", "alpha", "y", "eps"),
        twins=(gp_mod._batched_loo_launch,
               gp_mod._batched_loo_launch_donated)))
    return specs


def _ehvi_fixture():
    """A 4-lane EHVI bucket (2 real lanes), 2 objectives, 5 of 8
    candidates valid, front boxes padded with the +inf pinned boxes."""
    from repro.core.acquisition import nondominated_boxes, pareto_front
    rng = np.random.default_rng(1)
    l_valid, l_pad, n_obj, s, q_v, q_pad = 2, 4, 2, 4, 5, 8
    observed = rng.normal(0.0, 1.0, (3, n_obj))
    ref = np.full((n_obj,), 3.0)
    lo, hi = nondominated_boxes(pareto_front(observed), ref)
    k = lo.shape[0]
    k_pad = 1 << (k - 1).bit_length()
    los = np.full((l_pad, k_pad, n_obj), np.inf, np.float32)
    his = np.full((l_pad, k_pad, n_obj), np.inf, np.float32)
    los[:, :k] = lo
    his[:, :k] = hi
    refs = np.broadcast_to(ref.astype(np.float32),
                           (l_pad, n_obj)).copy()
    return dict(rng=rng, l_valid=l_valid, l_pad=l_pad, n_obj=n_obj,
                s=s, q_v=q_v, q_pad=q_pad, los=los, his=his, refs=refs)


def _ehvi_specs() -> List[LaunchSpec]:
    from repro.core import acquisition as acq
    from repro.kernels.fused_ehvi import ops as fe_ops
    fx = _ehvi_fixture()
    rng = fx["rng"]
    l_valid, l_pad, n_obj, s, q_v, q_pad = (
        fx["l_valid"], fx["l_pad"], fx["n_obj"], fx["s"], fx["q_v"],
        fx["q_pad"])

    def lane(shape):
        t = np.zeros(shape, bool)
        t[l_valid:] = True
        return t

    def cols(shape, axis=-1):
        """Free padded candidate columns (last axis) + pad lanes."""
        t = np.zeros(shape, bool)
        t[..., q_v:] = True
        t[l_valid:] = True
        return t

    valid_rows = np.zeros((l_pad, q_pad), bool)
    valid_rows[:l_valid, :q_v] = True

    # --- vmapped ehvi: (los, his, refs, ps) -> (L, q) ----------------
    ps = rng.normal(0.0, 1.0,
                    (l_pad, n_obj, s, q_pad)).astype(np.float32)
    ps[..., q_v:] = np.inf          # executor pads candidates at +inf
    specs = [LaunchSpec(
        name="ehvi",
        fn=acq._ehvi_box_eval,
        args=(fx["los"], fx["his"], fx["refs"], ps),
        taints=(lane(fx["los"].shape),    # +inf boxes pinned
                lane(fx["his"].shape),
                lane(fx["refs"].shape),
                cols(ps.shape)),
        valid_outs=(valid_rows,),
        arg_names=("los", "his", "refs", "ps"),
        twins=(acq._ehvi_box_launch, acq._ehvi_box_launch_donated))]

    # --- fused ehvi (ref twin): draw affine fused in ------------------
    mu = np.zeros((l_pad, n_obj, q_pad), np.float32)
    mu[:, :, :q_v] = rng.normal(0.0, 1.0, (l_pad, n_obj, q_v))
    mu[:, :, q_v:] = np.inf
    var = np.zeros((l_pad, n_obj, q_pad), np.float32)
    var[:, :, :q_v] = rng.uniform(0.1, 1.0, (l_pad, n_obj, q_v))
    y_mean = rng.normal(0.0, 1.0, (l_pad, n_obj)).astype(np.float32)
    y_std = rng.uniform(0.5, 1.5, (l_pad, n_obj)).astype(np.float32)
    eps = np.zeros((l_pad, n_obj, s, q_pad), np.float32)
    eps[..., :q_v] = rng.normal(0.0, 1.0, (l_pad, n_obj, s, q_v))
    specs.append(LaunchSpec(
        name="fused_ehvi",
        fn=fe_ops.ref_twin(),
        args=(fx["los"], fx["his"], fx["refs"], mu, var, y_mean, y_std,
              eps),
        taints=(lane(fx["los"].shape), lane(fx["his"].shape),
                lane(fx["refs"].shape), cols(mu.shape),
                cols(var.shape), lane(y_mean.shape),
                lane(y_std.shape), cols(eps.shape)),
        valid_outs=(valid_rows,),
        arg_names=("los", "his", "refs", "mu", "var", "y_mean",
                   "y_std", "eps"),
        twins=(fe_ops._fused_ehvi_launch,
               fe_ops._fused_ehvi_launch_donated)))
    return specs


def _fused_posterior_spec() -> LaunchSpec:
    from repro.core import gp as gp_mod
    from repro.kernels.fused_posterior import ops as fp_ops
    fx = _stack_fixture()
    rng = fx["rng"]
    m_valid, m_pad, n_pad, d = (fx["m_valid"], fx["m_pad"], fx["n_pad"],
                                fx["d"])
    q = 4
    xq = rng.uniform(0.0, 1.0, (m_pad, q, d)).astype(np.float32)
    xq[m_valid:] = xq[0]
    best = rng.normal(0.0, 1.0, (m_pad,)).astype(np.float32)
    best[m_valid:] = best[0]
    lane = fx["lane_pad_mask"]
    obs = fx["obs_pad_mask"]
    valid = np.zeros((m_pad, q), bool)
    valid[:m_valid] = True
    return LaunchSpec(
        name="fused_posterior",
        fn=fp_ops.ref_twin(),
        args=(fx["log_ls"], fx["log_sf"], fx["x"], fx["mask"],
              fx["chol"], fx["alpha"], xq, best),
        taints=(lane((m_pad, d)), lane((m_pad,)), obs((d,)),
                lane((m_pad, n_pad)), lane((m_pad, n_pad, n_pad)),
                obs(), lane((m_pad, q, d)), lane((m_pad,))),
        valid_outs=(valid, valid, valid),
        arg_names=("log_ls", "log_sf", "x", "mask", "chol", "alpha",
                   "xq", "best"),
        twins=(fp_ops._fused_launch, fp_ops._fused_launch_donated))


def _fused_fit_spec() -> LaunchSpec:
    """The fused fit leg: warm-start rows ride the lane axis, padded
    observation rows must have exactly zero gradient (the masked-NLML
    contract in ``kernels/fused_fit/ref.py``), and the emitted Cholesky
    must keep its pinned pad block untouchable — the posterior legs
    consume it directly."""
    from repro.kernels.fused_fit import ops as ff_ops
    fx = _stack_fixture()
    m_valid, m_pad, n_pad, d = (fx["m_valid"], fx["m_pad"], fx["n_pad"],
                                fx["d"])
    lane = fx["lane_pad_mask"]
    obs = fx["obs_pad_mask"]
    valid_alpha = np.zeros((m_pad, n_pad), bool)
    for i, n in enumerate(fx["ns"]):
        valid_alpha[i, :n] = True
    return LaunchSpec(
        name="fused_fit",
        fn=partial(ff_ops.ref_twin(), steps=2, noise=0.1, lr=0.05),
        args=(fx["x"], fx["y"], fx["mask"], fx["log_ls"], fx["log_sf"]),
        taints=(obs((d,)), obs(), lane((m_pad, n_pad)),  # mask pinned
                lane((m_pad, d)), lane((m_pad,))),
        valid_outs=(~lane((m_pad, d)),                   # log_ls
                    ~lane((m_pad,)),                     # log_sf
                    ~lane((m_pad, n_pad, n_pad)),        # chol, pad
                    valid_alpha),                        # block included
        arg_names=("x", "y", "mask", "init_ls", "init_sf"),
        twins=(ff_ops._fused_fit_launch, ff_ops._fused_fit_launch_donated))


def _ranking_loss_spec() -> LaunchSpec:
    """The padded RGPE scoring launch: pad rows (n_valid = 0) and each
    row's pad columns are free; the per-row validity mask must fence
    them out of every real row's misrank count."""
    from repro.kernels.ranking_loss import ops as rl_ops
    from repro.kernels.ranking_loss.ref import ranking_loss_padded_ref
    rng = np.random.default_rng(2)
    r_valid, r_pad, n_pad = 3, 4, 8
    nvs = (5, 5, 3)
    preds = np.zeros((r_pad, n_pad), np.float32)
    ys = np.zeros((r_pad, n_pad), np.float32)
    nv = np.zeros((r_pad,), np.int32)
    for i, n in enumerate(nvs):
        preds[i, :n] = rng.normal(0.0, 1.0, (n,))
        ys[i, :n] = rng.normal(0.0, 1.0, (n,))
        nv[i] = n
    taint = np.zeros((r_pad, n_pad), bool)
    for i, n in enumerate(nvs):
        taint[i, n:] = True
    taint[r_valid:] = True
    valid = np.zeros((r_pad,), bool)
    valid[:r_valid] = True
    return LaunchSpec(
        name="ranking_loss",
        fn=ranking_loss_padded_ref,
        args=(preds, ys, nv),
        taints=(taint, taint.copy(), np.zeros((r_pad,), bool)),  # nv pinned
        valid_outs=(valid,),
        arg_names=("preds", "ys", "n_valid"),
        twins=(rl_ops._ranking_loss_launch,
               rl_ops._ranking_loss_launch_donated))


_SPECS: Optional[List[LaunchSpec]] = None


def launch_specs(refresh: bool = False) -> List[LaunchSpec]:
    """The analysis fixtures for every tracked launch family, built
    once per process (fixture construction runs a real ``chol_alpha``
    launch)."""
    global _SPECS
    if _SPECS is None or refresh:
        _SPECS = (_gp_specs() + _ehvi_specs()
                  + [_fused_posterior_spec(), _fused_fit_spec(),
                     _ranking_loss_spec()])
    return _SPECS


def check_padding_taint(
        specs: Optional[Sequence[LaunchSpec]] = None) -> List[Finding]:
    """Run the taint interpreter over every spec; a finding is a free
    padded source reaching a valid-region output position."""
    specs = launch_specs() if specs is None else specs
    out: List[Finding] = []
    for spec in specs:
        taints = [np.zeros(np.shape(a), bool) if t is False else t
                  for a, t in zip(spec.args, spec.taints)]
        res = taint_trace(spec.fn, spec.args, taints)
        if len(res.out_taints) != len(spec.valid_outs):
            out.append(Finding(
                "padding-taint", "error", spec.name, "<outputs>",
                f"spec expects {len(spec.valid_outs)} outputs, launch "
                f"produced {len(res.out_taints)}"))
            continue
        for j, (taint, valid) in enumerate(zip(res.out_taints,
                                               spec.valid_outs)):
            leak = taint & valid
            if leak.any():
                path = " -> ".join(res.out_paths[j]) or "<direct>"
                out.append(Finding(
                    "padding-taint", "error", spec.name, path,
                    f"free padded region reaches {int(leak.sum())} "
                    f"valid position(s) of output {j}"))
    return out
