"""``python -m repro.analysis.lint`` — run every analysis rule.

Exit status is 1 iff any unsuppressed error-severity finding remains
(the CI gate), 0 otherwise. ``--format=json`` emits the structured
findings document the CI job uploads as an artifact; ``--format=text``
prints one line per finding. Suppressed findings stay visible in both
formats, demoted to ``info`` and carrying their justification.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional, Sequence

from .findings import Finding, apply_suppressions, to_dicts

RULES = ("padding-taint", "donation-safety", "vocab-closure",
         "prng-audit")


def run_rule(rule: str) -> List[Finding]:
    if rule == "padding-taint":
        from .padding_taint import check_padding_taint
        return check_padding_taint()
    if rule == "donation-safety":
        from .donation_safety import check_donation_safety
        return check_donation_safety()
    if rule == "vocab-closure":
        from .vocab_closure import check_vocab_closure
        return check_vocab_closure()
    if rule == "prng-audit":
        from .prng_audit import check_prng_audit
        return check_prng_audit()
    raise ValueError(f"unknown rule {rule!r} (have {RULES})")


def run_all(rules: Sequence[str] = RULES) -> List[Finding]:
    """All findings across ``rules``, suppressions applied."""
    findings: List[Finding] = []
    for rule in rules:
        findings.extend(run_rule(rule))
    return apply_suppressions(findings)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="static launch-invariant analysis")
    parser.add_argument("--format", choices=("text", "json"),
                        default="text")
    parser.add_argument("--output", default=None,
                        help="also write the report to this path")
    parser.add_argument("--rules", default=",".join(RULES),
                        help="comma-separated rule subset")
    args = parser.parse_args(argv)
    rules = tuple(r.strip() for r in args.rules.split(",") if r.strip())
    for rule in rules:
        if rule not in RULES:
            parser.error(f"unknown rule {rule!r} (have {RULES})")

    t0 = time.perf_counter()
    per_rule = {rule: apply_suppressions(run_rule(rule))
                for rule in rules}
    wall = time.perf_counter() - t0
    findings = [f for fs in per_rule.values() for f in fs]
    errors = [f for f in findings if f.severity == "error"]

    if args.format == "json":
        report = {
            "findings": to_dicts(findings),
            "summary": {
                "rules": {rule: len(fs)
                          for rule, fs in per_rule.items()},
                "errors": len(errors),
                "suppressed": sum(1 for f in findings if f.suppressed),
                "wall_s": round(wall, 3),
            },
        }
        text = json.dumps(report, indent=2, sort_keys=True)
    else:
        lines = []
        for f in findings:
            tag = f" [suppressed: {f.suppressed}]" if f.suppressed \
                else ""
            lines.append(f"{f.severity:7s} {f.rule:16s} "
                         f"{f.launch or '-':18s} {f.path}  "
                         f"{f.message}{tag}")
        lines.append(f"{len(findings)} finding(s), {len(errors)} "
                     f"error(s), {len(rules)} rule(s) in {wall:.1f}s")
        text = "\n".join(lines)
    print(text)
    if args.output:
        with open(args.output, "w") as fh:
            fh.write(text + "\n")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
