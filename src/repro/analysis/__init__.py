"""Static launch-invariant analysis over the query-plan vocabulary.

Four rules, each a static twin of a contract the serving path otherwise
only samples dynamically:

- ``padding-taint`` (`padding_taint`): jaxpr-level taint propagation
  proving no padded lane/obs/grid/box region can reach a launch's
  valid-region outputs.
- ``donation-safety`` (`donation_safety`): every ``donate_argnums``
  twin donates only per-step-rebuilt buffers, twins agree on arg
  shapes/dtypes, and no executor method reads a donated buffer after
  its launch.
- ``vocab-closure`` (`vocab_closure`): ``enumerate_buckets`` /
  ``launch_signature`` closure under the planner's rounding policy and
  mesh lane-lifting, plus weak-type launch-argument detection.
- ``prng-audit`` (`prng_audit`): the ``derive_key``/``fold_in``
  schedule is collision-free over its purpose/iteration/index paths.

``python -m repro.analysis.lint`` runs all four; ``mutants`` holds the
seeded-bug corpus that pins each rule's detection power.
"""
from .findings import (Finding, SUPPRESSIONS, apply_suppressions,
                       max_severity)

__all__ = ["Finding", "SUPPRESSIONS", "apply_suppressions",
           "max_severity", "run_all"]


def __getattr__(name):
    # lazy: ``python -m repro.analysis.lint`` must not find the lint
    # module pre-imported by its own package (runpy double-import)
    if name == "run_all":
        from .lint import run_all
        return run_all
    raise AttributeError(name)
