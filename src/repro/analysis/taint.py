"""Differential taint propagation over a launch body's jaxpr.

The padding contracts this repo lives by ("masked rows cannot change
valid outputs", "throwaway lanes are throwaway") are DATAFLOW claims,
and a jaxpr is the exact dataflow graph the compiler sees. This module
evaluates a launch body's jaxpr equation by equation (mirroring
``jax.core.eval_jaxpr``) carrying a boolean taint mask per value, and
propagates taint DIFFERENTIALLY: for every equation with tainted
inputs, the primitive is re-executed with the tainted positions bumped
(floats by a large delta, bools flipped, ints incremented) and an
output position is tainted iff any bump changes it bitwise.

Differential propagation is what makes mask discipline legible without
a sanitizer whitelist: ``k * mask`` with ``mask == 0`` is bitwise
invariant under any bump of ``k``'s masked entries, so multiplicative
masking, ``where`` selects and structural zeros all sanitize
automatically — while a DROPPED mask shows up as a bitwise diff in the
valid region with no false positives (no dependence means identical
outputs). Two deltas of different sign/magnitude guard against a bump
landing on a fixed point of the op (e.g. clipping).

Higher-order equations (pjit, scan, cond, ...) are probed atomically
through their ``bind``: taint granularity inside them is lost but
soundness of the in/out dependence test is not.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import numpy as np

from jax import core as jax_core

# Two probes per tainted equation: large positive and a sign-flipped,
# non-power-of-two magnitude — a value whose effect survives rounding
# and is unlikely to sit on a fixed point of both probes at once.
DELTAS = (1e3, -37.0)


def _bump(val, taint: np.ndarray, delta: float):
    """Return ``val`` with tainted positions perturbed."""
    v = np.asarray(val)
    t = np.asarray(taint, bool)
    if not t.any():
        return val
    if np.issubdtype(v.dtype, np.floating):
        return jax.numpy.asarray(np.where(t, v + np.asarray(delta, v.dtype),
                                          v))
    if np.issubdtype(v.dtype, np.bool_):
        return jax.numpy.asarray(np.where(t, ~v, v))
    return jax.numpy.asarray(np.where(t, v + 1, v))


def _diff(a, b) -> np.ndarray:
    """Bitwise difference mask; NaN == NaN (a bump that turns one NaN
    into another NaN carries no information)."""
    a = np.asarray(a)
    b = np.asarray(b)
    if np.issubdtype(a.dtype, np.floating):
        both_nan = np.isnan(a) & np.isnan(b)
        return (a != b) & ~both_nan
    return a != b


@dataclasses.dataclass
class TaintResult:
    out_vals: List[Any]
    out_taints: List[np.ndarray]          # aligned with flat outputs
    # per flat output: the producing-eqn chain (primitive names, source
    # to sink) along which taint reached it; [] when untainted
    out_paths: List[List[str]]


def taint_trace(fn: Callable, args: Sequence, taints: Sequence,
                *, deltas: Tuple[float, ...] = DELTAS) -> TaintResult:
    """Trace ``fn(*args)`` to a jaxpr and propagate ``taints`` (one
    boolean mask per argument, True = tainted source) to the flat
    outputs."""
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr, consts = closed.jaxpr, closed.consts

    env: Dict[Any, Tuple[Any, np.ndarray]] = {}
    producer: Dict[Any, int] = {}          # outvar -> eqn index

    def read(v):
        if isinstance(v, jax_core.Literal):
            return v.val, np.zeros(np.shape(v.val), bool)
        return env[v]

    for v, c in zip(jaxpr.constvars, consts):
        env[v] = (c, np.zeros(np.shape(c), bool))
    flat_taints = [np.broadcast_to(np.asarray(t, bool), np.shape(a))
                   for a, t in zip(args, taints)]
    for v, a, t in zip(jaxpr.invars, args, flat_taints):
        env[v] = (jax.numpy.asarray(a), t)

    eqn_names: List[str] = []
    # eqn index -> indices of eqns (or -1 for an argument source) whose
    # tainted outputs fed its tainted inputs
    tainted_feeders: Dict[int, List[int]] = {}

    for i, eqn in enumerate(jaxpr.eqns):
        eqn_names.append(eqn.primitive.name)
        subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
        pairs = [read(v) for v in eqn.invars]
        vals = [p[0] for p in pairs]
        in_taints = [p[1] for p in pairs]
        ans = eqn.primitive.bind(*subfuns, *vals, **bind_params)
        outs = ans if eqn.primitive.multiple_results else [ans]
        out_taints = [np.zeros(np.shape(o), bool) for o in outs]
        if any(t.any() for t in in_taints):
            for delta in deltas:
                bumped = [_bump(v, t, delta)
                          for v, t in zip(vals, in_taints)]
                ans_b = eqn.primitive.bind(*subfuns, *bumped,
                                           **bind_params)
                outs_b = (ans_b if eqn.primitive.multiple_results
                          else [ans_b])
                for j, (o, ob) in enumerate(zip(outs, outs_b)):
                    out_taints[j] = out_taints[j] | _diff(o, ob)
            if any(t.any() for t in out_taints):
                feeders = []
                for v, t in zip(eqn.invars, in_taints):
                    if not isinstance(v, jax_core.Literal) and t.any():
                        feeders.append(producer.get(v, -1))
                tainted_feeders[i] = feeders
        for v, o, t in zip(eqn.outvars, outs, out_taints):
            env[v] = (o, t)
            producer[v] = i

    def chain(idx: int, depth: int = 0) -> List[str]:
        if idx < 0 or depth > 64 or idx not in tainted_feeders:
            return [eqn_names[idx]] if idx >= 0 else []
        feeders = tainted_feeders[idx]
        head = chain(feeders[0], depth + 1) if feeders else []
        return head + [eqn_names[idx]]

    out_vals, out_taints, out_paths = [], [], []
    for v in jaxpr.outvars:
        val, t = read(v)
        out_vals.append(val)
        out_taints.append(t)
        out_paths.append(chain(producer[v]) if (t.any() and v in producer)
                         else [])
    return TaintResult(out_vals, out_taints, out_paths)
