"""Rule ``vocab-closure``: live signatures stay inside the enumerated
launch vocabulary, and launch arguments carry strong types.

The compile-once steady state rests on ``enumerate_buckets(limits)``
covering every ``launch_signature`` a live cohort within ``limits`` can
emit — dynamically asserted as ``plan_compile_misses == 0``; checked
STATICALLY here by walking the planner's reachable bucket states (every
exact observation count, lane count, sample/objective knob, candidate
count and front size inside the limits, through the REAL
``StepPlanner.plan`` so the ``_pads_*`` policy itself is exercised) and
testing each emitted signature for membership in the enumerated set —
under every mesh lane-lifting divisor in play (``lane_shards`` 1/2/4).

The second check guards the jit-cache axis the signature tuple cannot
see: weak-typed launch arguments. A Python scalar traced into a launch
gets a weak dtype; if it ever varies, each value mints a NEW cache
entry with an identical signature — the vocabulary fractures invisibly.
Every launch argument in the analysis fixtures must trace strong;
waivers (the fit's constant ``lr``) carry a justification in
``findings.SUPPRESSIONS``.
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .findings import Finding

# the representative serving envelope the lint CLI proves closure for:
# wide enough to exercise every rounding regime (multi-bucket obs axis,
# pow2+shard lane ladder, both EHVI box regimes for small fronts)
def lint_limits():
    from repro.core.plan import CohortLimits
    return CohortLimits(d=4, q_grid=20, max_obs=24, max_lanes=8,
                        n_samples=(32,), n_mc=(16,),
                        n_objectives=(2, 3), max_ehvi_boxes=64)


def _stack(m: int, n: int, d: int):
    """A shape-only stand-in for ``BatchedGP``: the planner reads just
    ``.x`` (shapes), ``.m`` and ``.n_max``."""
    return SimpleNamespace(x=np.zeros((m, n, d), np.float32), m=m,
                           n_max=n)


def signature_universe(planner, limits) -> set:
    return {planner.launch_signature(b)
            for b in planner.enumerate_buckets(limits)}


def _ehvi_fronts(limits, rng) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Observed fronts of 0..3 points per objective count — box counts
    from 1 (empty front) up through multi-box staircases, all inside
    ``max_ehvi_boxes`` for the envelopes this rule runs at."""
    fronts = []
    for n_obj in limits.n_objectives:
        ref = np.full((n_obj,), 3.0)
        for pts in range(4):
            fronts.append((rng.normal(0.0, 1.0, (pts, n_obj)), ref))
    return fronts


def iter_live_plans(planner, limits) -> Iterable:
    """Exhaustively yield planned cohort steps over the reachable
    exact-shape states: every observation count and lane count for
    posterior/sample/loo buckets, every remaining-candidate count and
    front in ``_ehvi_fronts`` for EHVI buckets (lane counts thinned to
    {1, 2, max} there — the lane axis rounds identically across
    kinds)."""
    from repro.core.plan import (EhviQuery, FitQuery, LooSampleQuery,
                                 PosteriorQuery, SampleQuery)
    rng = np.random.default_rng(7)
    d, qg = limits.d, limits.q_grid
    grid = np.zeros((qg, d), np.float32)
    lane_counts = range(1, limits.max_lanes + 1)
    thin_lanes = sorted({1, 2, limits.max_lanes})
    for n in range(1, limits.max_obs + 1):
        for lanes in lane_counts:
            yield planner.plan(
                [PosteriorQuery(_stack(1, n, d), grid)] * lanes)
        # one multi-model stack occupying all lanes at once
        yield planner.plan(
            [PosteriorQuery(_stack(limits.max_lanes, n, d), grid)])
        for s in limits.n_samples:
            own = np.zeros((n, d), np.float32)   # RGPE: own inputs
            for lanes in thin_lanes:
                yield planner.plan(
                    [SampleQuery(_stack(1, n, d), own, None, s)]
                    * lanes)
                yield planner.plan(
                    [LooSampleQuery(SimpleNamespace(n=n), None, s)]
                    * lanes)
        # fit buckets: both steps rungs (warm refine + cold full) at
        # every noise level. The live rungs come from LIMITS directly —
        # mirroring the service, whose warm cache decides a query's
        # steps — NOT from the planner's fit_step_rungs policy, so a
        # planner that drops a rung from its enumeration surfaces here
        # as an unenumerated live signature
        live_rungs = sorted(
            {int(limits.fit_steps)}
            | ({int(limits.fit_warm_steps)}
               if limits.fit_warm_steps else set()))
        for steps in live_rungs:
            for noise in limits.noises:
                for lanes in thin_lanes:
                    yield planner.plan(
                        [FitQuery(np.zeros((n, d), np.float32),
                                  np.zeros((n,), np.float32),
                                  noise, steps)] * lanes)
    fronts = _ehvi_fronts(limits, rng)
    for n_obj in limits.n_objectives:
        for s in limits.n_mc:
            for q in range(1, qg + 1):
                row = np.zeros((q,), np.float32)
                for observed, ref in fronts:
                    if observed.shape[-1] != n_obj:
                        continue
                    for lanes in thin_lanes:
                        yield planner.plan([EhviQuery(
                            samples=None, observed=observed, ref=ref,
                            mu=(row,) * n_obj, var=(row,) * n_obj,
                            y_mean=(0.0,) * n_obj,
                            y_std=(1.0,) * n_obj,
                            keys=(None,) * n_obj, n_mc=s)] * lanes)


def check_closure(
    limits=None,
    planner_factory: Optional[Callable[[int], object]] = None,
    shard_sizes: Sequence[int] = (1, 2, 4),
) -> List[Finding]:
    """Every live signature must be enumerated, per mesh divisor."""
    from repro.core.plan import StepPlanner
    limits = lint_limits() if limits is None else limits
    if planner_factory is None:
        planner_factory = lambda s: StepPlanner(lane_shards=s)
    out: List[Finding] = []
    seen_bad = set()
    for shards in shard_sizes:
        planner = planner_factory(shards)
        universe = signature_universe(planner, limits)
        for plan in iter_live_plans(planner, limits):
            for bucket in plan.buckets:
                if bucket.kind == "draw":   # unjitted: no vocabulary
                    continue
                sig = planner.launch_signature(bucket)
                if sig not in universe and sig not in seen_bad:
                    seen_bad.add(sig)
                    out.append(Finding(
                        "vocab-closure", "error", bucket.kind,
                        repr(sig),
                        f"live cohort emits a signature outside "
                        f"enumerate_buckets (lane_shards={shards}): "
                        f"a serving step would compile mid-flight"))
    return out


def check_weak_types(specs=None) -> List[Finding]:
    """Every traced launch argument in the analysis fixtures must be
    strong-typed; weak scalars fracture the jit cache invisibly."""
    import jax

    from .padding_taint import launch_specs
    specs = launch_specs() if specs is None else specs
    out: List[Finding] = []
    for spec in specs:
        closed = jax.make_jaxpr(spec.fn)(*spec.args)
        names = (spec.arg_names if len(spec.arg_names)
                 == len(closed.jaxpr.invars)
                 else [f"arg{i}" for i in
                       range(len(closed.jaxpr.invars))])
        for name, var in zip(names, closed.jaxpr.invars):
            if getattr(var.aval, "weak_type", False):
                out.append(Finding(
                    "vocab-closure", "error", spec.name, name,
                    f"launch argument {name!r} traces weak-typed (a "
                    f"Python scalar reached the launch): every "
                    f"distinct value would mint its own jit-cache "
                    f"entry under one signature"))
    return out


def check_vocab_closure() -> List[Finding]:
    return check_closure() + check_weak_types()
