"""Teads-engineering-style linear power model (paper §IV-A).

Energy is derived from a linear profile depending only on CPU load,
bounded by the idle and full-load wattage of the instance; xlarge and
2xlarge draw 2x and 4x the power of large.
"""
from __future__ import annotations

# (idle W, peak W) for the '.large' size per family
_LARGE_WATTS = {
    "c4": (6.0, 16.0),
    "m4": (7.0, 19.0),
    "r4": (8.5, 24.0),
}
_SIZE_SCALE = {"large": 1.0, "xlarge": 2.0, "2xlarge": 4.0}


def node_watts(machine_type: str, cpu_util: float) -> float:
    family, size = machine_type.split(".")
    idle, peak = _LARGE_WATTS[family]
    s = _SIZE_SCALE[size]
    u = min(max(cpu_util, 0.0), 1.0)
    return (idle + (peak - idle) * u) * s


def energy_kwh(machine_type: str, node_count: int, runtime_s: float,
               cpu_util: float) -> float:
    return node_watts(machine_type, cpu_util) * node_count * runtime_s \
        / 3600.0 / 1000.0
