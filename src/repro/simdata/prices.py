"""AWS on-demand prices (USD/hour, us-east-1, July-2023 era) for the
scout-like machine types — per the paper's cost derivation (§IV-A)."""

ON_DEMAND_USD_PER_HOUR = {
    "c4.large": 0.100,
    "c4.xlarge": 0.199,
    "c4.2xlarge": 0.398,
    "m4.large": 0.100,
    "m4.xlarge": 0.200,
    "m4.2xlarge": 0.400,
    "r4.large": 0.133,
    "r4.xlarge": 0.266,
    "r4.2xlarge": 0.532,
}


def price_per_hour(machine_type: str) -> float:
    return ON_DEMAND_USD_PER_HOUR[machine_type]
