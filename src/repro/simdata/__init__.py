from .scout_like import (WORKLOADS, ScoutEmulator, WorkloadSpec,
                         make_emulator)
from .prices import ON_DEMAND_USD_PER_HOUR
from .power import energy_kwh, node_watts

__all__ = ["WORKLOADS", "ScoutEmulator", "WorkloadSpec", "make_emulator",
           "ON_DEMAND_USD_PER_HOUR", "energy_kwh", "node_watts"]
