"""Scout-like dataset emulator (paper §IV-A).

The original scout dataset (18 workloads x 69 AWS configs, 1242 runs) is
not redistributable offline, so this module generates a statistically
faithful emulation: HiBench / spark-perf workloads on Hadoop 2.7 /
Spark 1.5 / Spark 2.1, each with an Amdahl-type runtime surface

    T(mt, n) = serial + work * spill_penalty / (n * cores * speed)
             + shuffle * c * n^gamma / net_scale

with per-workload coefficients drawn from per-ALGORITHM hyperpriors (so
same-algorithm workloads genuinely look alike — the structure Karasu's
Algorithm 1 exploits), heteroscedastic multiplicative noise, cost from
real on-demand prices, energy from the linear power model, and
correlated sar-style metrics compacted by the paper's agg function.

Each workload carries private (framework, algorithm, dataset) tags used
ONLY by the evaluation harness to build the data-availability cases A-D;
the shared RunRecords never contain them.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.aggregation import SAR_METRICS, aggregate_metrics
from repro.core.encoding import machine_features, scout_search_space
from repro.core.types import RunRecord
from .power import energy_kwh
from .prices import price_per_hour


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    workload_id: str
    framework: str      # hadoop2.7 | spark1.5 | spark2.1   (private tag)
    algorithm: str      # private tag
    dataset: str        # private tag
    # runtime-surface coefficients
    work: float         # core-seconds of parallel work
    serial: float       # serial seconds
    shuffle: float      # shuffle volume coefficient
    gamma: float        # communication growth exponent
    mem_demand: float   # GB needed before spilling
    cpu_frac: float     # cpu- vs io-bound mix in [0,1]
    noise: float        # multiplicative noise sigma


# algorithm hyperpriors: (work_mu, shuffle_mu, mem_mu, cpu_frac_mu)
_ALGO_PRIORS = {
    "pagerank": (9.5, 3.2, 5.0, 0.55),
    "terasort": (9.0, 4.0, 5.5, 0.35),
    "wordcount": (8.8, 2.0, 4.0, 0.65),
    "kmeans": (9.8, 2.5, 4.5, 0.80),
    "naive-bayes": (9.0, 2.2, 4.8, 0.70),
    "join": (9.2, 3.8, 5.2, 0.40),
    "regression": (9.6, 2.4, 4.2, 0.85),
    "als": (9.9, 3.0, 5.0, 0.75),
    "pca": (9.4, 2.8, 4.6, 0.78),
}

# the 18 scout-like workloads: (framework, algorithm, dataset)
WORKLOADS: Tuple[Tuple[str, str, str], ...] = (
    ("hadoop2.7", "pagerank", "web-small"),
    ("hadoop2.7", "terasort", "tera-300g"),
    ("hadoop2.7", "wordcount", "wiki-50g"),
    ("hadoop2.7", "join", "tpch-100"),
    ("hadoop2.7", "naive-bayes", "news-20"),
    ("spark1.5", "pagerank", "web-small"),
    ("spark1.5", "terasort", "tera-300g"),
    ("spark1.5", "wordcount", "wiki-50g"),
    ("spark1.5", "kmeans", "points-100m"),
    ("spark1.5", "regression", "features-10m"),
    ("spark2.1", "pagerank", "web-large"),
    ("spark2.1", "terasort", "tera-1t"),
    ("spark2.1", "kmeans", "points-100m"),
    ("spark2.1", "kmeans", "points-1b"),
    ("spark2.1", "naive-bayes", "news-20"),
    ("spark2.1", "regression", "features-10m"),
    ("spark2.1", "als", "ratings-1b"),
    ("spark2.1", "pca", "features-10m"),
)

_FRAMEWORK_SPEED = {"hadoop2.7": 0.72, "spark1.5": 0.95, "spark2.1": 1.1}


def _seed_from(s: str) -> int:
    return int(hashlib.sha256(s.encode()).hexdigest()[:8], 16)


def make_workload(framework: str, algorithm: str, dataset: str,
                  *, salt: str = "") -> WorkloadSpec:
    wid = f"{framework}/{algorithm}/{dataset}{salt}"
    rng = np.random.default_rng(_seed_from(wid))
    wmu, smu, mmu, cmu = _ALGO_PRIORS[algorithm]
    dscale = 1.0 + 1.5 * (rng.random() if "large" in dataset or "1b" in
                          dataset or "1t" in dataset else 0.0)
    return WorkloadSpec(
        workload_id=wid,
        framework=framework, algorithm=algorithm, dataset=dataset,
        work=float(np.exp(rng.normal(wmu, 0.25))) * dscale,
        serial=float(np.exp(rng.normal(3.6, 0.4))),
        shuffle=float(np.exp(rng.normal(smu, 0.3))) * dscale,
        gamma=float(rng.uniform(0.15, 0.55)),
        mem_demand=float(np.exp(rng.normal(mmu, 0.3))) * dscale,
        cpu_frac=float(np.clip(rng.normal(cmu, 0.08), 0.1, 0.95)),
        noise=float(rng.uniform(0.02, 0.06)),
    )


class ScoutEmulator:
    """Black-box executor: run(workload, config) -> (measures, metrics)."""

    def __init__(self, specs: Sequence[WorkloadSpec]):
        self.specs = {s.workload_id: s for s in specs}
        self.space = scout_search_space()

    def workload_ids(self) -> List[str]:
        return list(self.specs.keys())

    def _runtime(self, w: WorkloadSpec, mt: str, n: int,
                 rng: Optional[np.random.Generator]) -> Tuple[float, Dict]:
        f = machine_features(mt)
        speed = _FRAMEWORK_SPEED[w.framework] * (0.9 + 0.05 * f["net_scale"])
        total_mem = f["mem_gb"] * n
        spill = max(0.0, w.mem_demand / total_mem - 1.0)
        spill_pen = 1.0 + (1.0 - w.cpu_frac) * 2.0 * spill + 0.6 * spill
        compute = w.work * spill_pen / (n * f["cores"] * speed)
        comm = w.shuffle * (n ** w.gamma) / (8.0 * f["net_scale"])
        t = w.serial + compute + comm
        if rng is not None:
            t *= float(np.exp(rng.normal(0.0, w.noise)))
        parts = {"compute": compute, "comm": comm, "spill": spill,
                 "total_mem": total_mem, "features": f}
        return t, parts

    def run(self, workload_id: str, config: Mapping,
            rng: Optional[np.random.Generator] = None
            ) -> Tuple[Dict[str, float], np.ndarray]:
        """Execute one profiling run; returns (measures, agg metrics)."""
        w = self.specs[workload_id]
        mt, n = str(config["machine_type"]), int(config["node_count"])
        t, parts = self._runtime(w, mt, n, rng)
        cpu_util = min(0.98, w.cpu_frac * parts["compute"] / max(t, 1e-9)
                       + 0.05)
        cost = t / 3600.0 * price_per_hour(mt) * n
        energy = energy_kwh(mt, n, t, cpu_util)
        measures = {"runtime": t, "cost": cost, "energy": energy}
        metrics = self._metrics(w, parts, t, cpu_util, n, rng)
        return measures, metrics

    def _metrics(self, w: WorkloadSpec, parts: Dict, t: float,
                 cpu_util: float, n: int,
                 rng: Optional[np.random.Generator]) -> np.ndarray:
        """sar-style samples over (machines x time), then agg()."""
        r = rng or np.random.default_rng(_seed_from(w.workload_id + "m"))
        spill = parts["spill"]
        mem_used = min(0.97, w.mem_demand / parts["total_mem"])
        net_util = min(0.95, parts["comm"] / max(t, 1e-9) + 0.02)
        disk = min(0.95, (1.0 - w.cpu_frac) * 0.5 + 0.4 * spill)
        swap = min(0.9, 0.8 * spill)
        vmeff = max(0.05, 1.0 - 0.7 * spill)
        means = np.array([
            100.0 * (1.0 - cpu_util),   # cpu.%idle
            100.0 * mem_used,           # memory.%memused
            100.0 * disk,               # disk.%util
            100.0 * net_util,           # network.%ifutil
            100.0 * swap,               # swap.%swpused
            100.0 * vmeff,              # paging.%vmeff
        ])
        spread = np.array([0.25, 0.08, 0.30, 0.35, 0.10, 0.12])
        samples = means[:, None] * (
            1.0 + spread[:, None] * r.standard_normal((6, 8 * max(n, 2))))
        samples = np.clip(samples, 0.0, 100.0)
        return aggregate_metrics(samples)

    # -- dataset-style helpers ----------------------------------------------
    def full_table(self, workload_id: str) -> List[Tuple[Mapping, Dict]]:
        """(config, measures) for all 69 configs — noise-free surface used
        to define ground-truth optima and runtime-target percentiles."""
        out = []
        for c in self.space.configs:
            m, _ = self.run(workload_id, c, rng=None)
            out.append((c, m))
        return out

    def runtime_target(self, workload_id: str, percentile: float) -> float:
        ts = [m["runtime"] for _, m in self.full_table(workload_id)]
        return float(np.percentile(ts, percentile))

    def optimal_cost(self, workload_id: str, runtime_target: float,
                     measure: str = "cost") -> float:
        vals = [m[measure] for _, m in self.full_table(workload_id)
                if m["runtime"] <= runtime_target]
        return float(min(vals)) if vals else float("nan")

    def make_record(self, shared_id: str, workload_id: str, config: Mapping,
                    rng: Optional[np.random.Generator] = None) -> RunRecord:
        measures, metrics = self.run(workload_id, config, rng)
        return RunRecord(workload_id=shared_id, config=dict(config),
                         metrics=metrics, measures=measures)


def make_emulator(*, extra: Sequence[Tuple[str, str, str]] = (),
                  salt: str = "") -> ScoutEmulator:
    specs = [make_workload(f, a, d, salt=salt)
             for f, a, d in tuple(WORKLOADS) + tuple(extra)]
    return ScoutEmulator(specs)
