"""Ranking-weighted Gaussian Process Ensemble (paper §III-B, after
Feurer et al. 2022).

Given base GPs fit on support workloads' shared observations and a target
GP fit on the target's own (few) observations:

 1. sample each model's predictions at the target's observed configs
    (base models: marginal posterior; target: leave-one-out posterior);
 2. score every sample with the *ranking loss* — the number of misranked
    pairs vs the target's observed y (prediction scale never matters,
    which is what makes cross-workload transfer possible);
 3. weight a_i = fraction of samples where model i achieves the minimum
    loss (ties split evenly);
 4. weight-dilution prevention: a base model is dropped when its median
    loss exceeds the 95th percentile of the target model's loss.

The ensemble posterior is the a-weighted mixture:
    mu = sum a_i mu_i,  var = sum a_i^2 var_i .

The O(S * n^2) pairwise loss over MC samples is the compute hot spot at
scale; ``repro.kernels.ranking_loss`` provides the Pallas-tiled version.

Two paths share the same weighting math: the sequential reference
(``compute_weights`` over a list of GPs) and the batched path
(``compute_weights_batched`` over one ``BatchedGP``), which draws every
base model's samples from a single vmapped posterior and scores all
(m+1) x S samples with ONE ranking-loss kernel call. Both paths split
the PRNG key identically, so they produce the same weights up to float
roundoff.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ranking_loss import (ranking_loss,
                                        ranking_loss_launch_fn,
                                        ranking_loss_padded)
from .gp import (GP, BatchedGP, batched_posterior, batched_sample,
                 gp_loo_samples, gp_posterior, gp_sample)
from .plan import (LooSampleQuery, PlanExecutor, SampleQuery,
                   StepPlanner, flatten_counters)


@dataclasses.dataclass(frozen=True)
class Ensemble:
    models: Tuple[GP, ...]         # base models + target LAST
    weights: jnp.ndarray           # (m + 1,), on the simplex

    @property
    def target(self) -> GP:
        return self.models[-1]


def compute_weights(
    base_models: Sequence[GP],
    target: GP,
    key: jax.Array,
    *,
    n_samples: int = 256,
    dilution_percentile: float = 95.0,
    impl: str = "xla",
) -> jnp.ndarray:
    """Returns (m+1,) weights; index -1 is the target model."""
    x_tar, y_tar = target.x, target.y
    n = int(y_tar.shape[0])
    m = len(base_models)
    if n < 2:
        # a single observation cannot rank pairs: spread weight uniformly
        # so the support models carry the prior (this is what lets Karasu
        # diverge from the baselines already at profiling run 2, fig. 3)
        return jnp.full((m + 1,), 1.0 / (m + 1))

    keys = jax.random.split(key, m + 1)
    losses = []
    for i, gp in enumerate(base_models):
        s = gp_sample(gp, x_tar, keys[i], n_samples)      # (S, n)
        losses.append(ranking_loss(s, y_tar, impl=impl))  # (S,)
    s_tar = gp_loo_samples(target, keys[-1], n_samples)
    losses.append(ranking_loss(s_tar, y_tar, impl=impl))
    loss_mat = jnp.stack(losses)                          # (m+1, S)
    return _weights_from_losses(loss_mat, dilution_percentile)


def _weights_from_losses(loss_mat: jnp.ndarray,
                         dilution_percentile: float) -> jnp.ndarray:
    """(m+1, S) ranking losses (target last) -> simplex weights."""
    # weight-dilution prevention (Feurer et al. §4.2)
    tar_pct = jnp.percentile(loss_mat[-1], dilution_percentile)
    medians = jnp.median(loss_mat, axis=1)
    diluted = medians > tar_pct
    diluted = diluted.at[-1].set(False)                   # never drop target
    loss_mat = jnp.where(diluted[:, None], jnp.inf, loss_mat)

    # a_i = E_s[ 1(i in argmin) / |argmin| ]
    mins = jnp.min(loss_mat, axis=0, keepdims=True)
    is_min = (loss_mat == mins).astype(jnp.float32)
    w = jnp.mean(is_min / jnp.sum(is_min, axis=0, keepdims=True), axis=1)
    return w / jnp.sum(w)


def _weights_from_losses_batched(loss_mats: jnp.ndarray,
                                 dilution_percentile: float) -> jnp.ndarray:
    """(J, m+1, S) stacked ranking losses -> (J, m+1) simplex weights.
    Same per-job ops as ``_weights_from_losses``, vectorised over the
    job axis so a scoring round reduces all ensembles of a (m, S) shape
    group in one pass of array ops instead of a per-job Python loop."""
    tar_pct = jnp.percentile(loss_mats[:, -1, :], dilution_percentile,
                             axis=-1)
    medians = jnp.median(loss_mats, axis=-1)
    diluted = medians > tar_pct[:, None]
    diluted = diluted.at[:, -1].set(False)                # never drop target
    lm = jnp.where(diluted[:, :, None], jnp.inf, loss_mats)

    mins = jnp.min(lm, axis=1, keepdims=True)
    is_min = (lm == mins).astype(jnp.float32)
    w = jnp.mean(is_min / jnp.sum(is_min, axis=1, keepdims=True), axis=2)
    return w / jnp.sum(w, axis=1, keepdims=True)


def compute_weights_batched(
    bases: BatchedGP,
    target: GP,
    key: jax.Array,
    *,
    n_samples: int = 256,
    dilution_percentile: float = 95.0,
    impl: str = "xla",
) -> jnp.ndarray:
    """Batched twin of ``compute_weights``: one vmapped posterior for all
    base models and one ranking-loss call over the stacked (m+1) x S
    samples. Splits the key exactly like the sequential path, so both
    return the same weights (modulo float roundoff)."""
    x_tar, y_tar = target.x, target.y
    n = int(y_tar.shape[0])
    m = bases.m
    if n < 2:
        return jnp.full((m + 1,), 1.0 / (m + 1))

    keys = jax.random.split(key, m + 1)
    s_base = batched_sample(bases, x_tar, keys[:m], n_samples,
                            impl=impl)                       # (m, S, n)
    s_tar = gp_loo_samples(target, keys[-1], n_samples)      # (S, n)
    stacked = jnp.concatenate([s_base.reshape(m * n_samples, n), s_tar])
    loss = ranking_loss(stacked, y_tar, impl=impl)           # ((m+1)*S,)
    loss_mat = loss.reshape(m + 1, n_samples)
    return _weights_from_losses(loss_mat, dilution_percentile)


@dataclasses.dataclass(frozen=True)
class WeightJob:
    """One RGPE weighting problem — (support stack, target, PRNG key) for
    a single (tenant, measure) ensemble. ``n_samples`` may differ per job
    (the padded scorer handles ragged sample counts like ragged n_obs)."""
    bases: BatchedGP
    target: GP
    key: jax.Array
    n_samples: int = 256


def compute_weights_multi(
    jobs: Sequence[WeightJob],
    *,
    dilution_percentile: float = 95.0,
    impl: str = "xla",
    fuse_samples: bool = True,
    sample_counters: Optional[dict] = None,
    planner: Optional[StepPlanner] = None,
    plan_executor: Optional[PlanExecutor] = None,
) -> List[jnp.ndarray]:
    """Score MANY ensembles with ONE padded ranking-loss launch.

    Cross-tenant twin of ``compute_weights_batched``: every job draws its
    samples exactly as the per-ensemble path does (same key splits, same
    shapes, so weights agree to float roundoff), then all jobs' sample
    rows are padded to a common n_max and scored by a single
    ``ranking_loss_padded`` call — ragged n_obs is handled by per-row
    validity masks, mirroring ``BatchedGP``'s padding contract. Jobs with
    n_obs < 2 short-circuit to uniform weights (no rankable pair).

    With ``fuse_samples`` (the default) every job emits its draws as
    query-plan nodes — one ``SampleQuery`` per support stack and one
    ``LooSampleQuery`` per target — and ONE planned ``PlanExecutor``
    round runs one launch per (S, q, d) / (S, n) bucket, the same
    planner a ``SearchService`` step routes its grid posteriors
    through (pass ``planner`` / ``plan_executor`` to share policy and
    launch dispatch — a service with donating or fused launches pins
    them there; defaults otherwise). Draw streams are identical to the
    per-job
    ``batched_sample`` / ``gp_loo_samples`` loops
    (``fuse_samples=False``), so weights agree to float roundoff.
    ``sample_counters`` (flat ``launches``/``queries``) reports the
    fused launch count. The final weight reduction runs vectorised per
    (m, S) shape group (``_weights_from_losses_batched``) on both
    paths.
    """
    out: List[Optional[jnp.ndarray]] = [None] * len(jobs)
    live: List[Tuple[int, WeightJob, jax.Array]] = []
    for ji, job in enumerate(jobs):
        n = int(job.target.y.shape[0])
        m = job.bases.m
        if n < 2:
            out[ji] = jnp.full((m + 1,), 1.0 / (m + 1))
            continue
        live.append((ji, job, jax.random.split(job.key, m + 1)))

    if fuse_samples:
        planner = planner if planner is not None else StepPlanner()
        queries = [SampleQuery(job.bases, job.target.x,
                               keys[:job.bases.m], job.n_samples)
                   for _, job, keys in live] + \
                  [LooSampleQuery(job.target, keys[-1], job.n_samples)
                   for _, job, keys in live]
        nested: dict = {}
        executor = (plan_executor if plan_executor is not None
                    else PlanExecutor(impl=impl))
        res = executor.execute(planner.plan(queries), counters=nested,
                               impl=impl)
        s_bases, s_tars = res[:len(live)], res[len(live):]
        flatten_counters(nested, sample_counters, ("sample", "loo"))
    else:
        s_bases = [batched_sample(job.bases, job.target.x,
                                  keys[:job.bases.m], job.n_samples,
                                  impl=impl)
                   for _, job, keys in live]
        s_tars = [gp_loo_samples(job.target, keys[-1], job.n_samples)
                  for _, job, keys in live]

    rows_p, rows_y, rows_nv, spans = [], [], [], []
    for (ji, job, keys), s_base, s_tar in zip(live, s_bases, s_tars):
        y_tar = job.target.y
        n = int(y_tar.shape[0])
        m = job.bases.m
        stacked = jnp.concatenate(
            [s_base.reshape(m * job.n_samples, n), s_tar])  # ((m+1)S, n)
        rows_p.append(stacked)
        rows_y.append(jnp.broadcast_to(y_tar[None], stacked.shape))
        rows_nv.append(jnp.full((stacked.shape[0],), n, jnp.int32))
        spans.append((ji, m, job.n_samples))
    if not rows_p:
        return out

    # planner-policy padding closes the launch's shape vocabulary: the
    # sample axis rounds like an observation axis, the row axis like a
    # fused lane axis (pow2, shard-lifted). Pad rows carry n_valid = 0
    # — zero rankable pairs, score 0 — and per-row independence keeps
    # the real rows bitwise identical to the exact-shape launch.
    planner = planner if planner is not None else StepPlanner()
    n_pad = planner.round_obs(max(p.shape[1] for p in rows_p))
    preds = jnp.concatenate(
        [jnp.pad(p, ((0, 0), (0, n_pad - p.shape[1]))) for p in rows_p])
    ys = jnp.concatenate(
        [jnp.pad(y, ((0, 0), (0, n_pad - y.shape[1]))) for y in rows_y])
    nv = jnp.concatenate(rows_nv)
    r = int(preds.shape[0])
    r_pad = planner.round_models(r)
    if r_pad > r:
        preds = jnp.pad(preds, ((0, r_pad - r), (0, 0)))
        ys = jnp.pad(ys, ((0, r_pad - r), (0, 0)))
        nv = jnp.pad(nv, (0, r_pad - r))
    # every argument is a fresh per-step stack, so the donating twin
    # (pinned by the sharing service's executor when one is passed) is
    # alias-safe
    launch = ranking_loss_launch_fn(
        donate=plan_executor.donate if plan_executor is not None
        else None)
    loss = launch(preds, ys, nv, impl=impl)[:r]
    # one vectorised weight reduction per (m, S) shape group instead of
    # a per-job loop of small eager ops
    offs, off = [], 0
    for ji, m, s in spans:
        offs.append(off)
        off += (m + 1) * s
    wgroups: dict = {}
    for (ji, m, s), o in zip(spans, offs):
        wgroups.setdefault((m, s), []).append((ji, o))
    for (m, s), entries in wgroups.items():
        mats = jnp.stack([loss[o:o + (m + 1) * s].reshape(m + 1, s)
                          for _, o in entries])
        ws = _weights_from_losses_batched(mats, dilution_percentile)
        for (ji, _), w in zip(entries, ws):
            out[ji] = w
    return out


def build_ensemble(base_models: Sequence[GP], target: GP, key: jax.Array,
                   *, n_samples: int = 256, impl: str = "xla") -> Ensemble:
    w = compute_weights(base_models, target, key, n_samples=n_samples,
                        impl=impl)
    return Ensemble(tuple(base_models) + (target,), w)


def ensemble_posterior(ens: Ensemble, xq: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted mixture posterior (standardised scale)."""
    mus, vars_ = [], []
    for gp in ens.models:
        mu, var = gp_posterior(gp, xq)
        mus.append(mu)
        vars_.append(var)
    mus = jnp.stack(mus)            # (m+1, q)
    vars_ = jnp.stack(vars_)
    w = ens.weights[:, None]
    mu = jnp.sum(w * mus, axis=0)
    var = jnp.sum((w ** 2) * vars_, axis=0)
    return mu, jnp.maximum(var, 1e-10)


@dataclasses.dataclass(frozen=True)
class BatchedEnsemble:
    """RGPE ensemble whose base models live in one BatchedGP stack; the
    target keeps its exact (unpadded) representation for LOO sampling."""
    bases: BatchedGP
    target: GP
    weights: jnp.ndarray           # (m + 1,), target last, on the simplex


def build_ensemble_batched(bases: BatchedGP, target: GP, key: jax.Array,
                           *, n_samples: int = 256, impl: str = "xla"
                           ) -> BatchedEnsemble:
    w = compute_weights_batched(bases, target, key, n_samples=n_samples,
                                impl=impl)
    return BatchedEnsemble(bases, target, w)


def mix_weighted(mu_b: jnp.ndarray, var_b: jnp.ndarray,
                 mu_t: jnp.ndarray, var_t: jnp.ndarray,
                 w: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RGPE mixture from stacked base posterior rows ``(m, q)`` plus the
    target row ``(q,)``; ``w`` is ``(m+1,)`` with the target LAST. The
    one mixing rule every path (run_search, run_search_moo, the service)
    applies after its grid posteriors come back from the query plan."""
    wb, wt = w[:-1, None], w[-1]
    mu = jnp.sum(wb * mu_b, axis=0) + wt * mu_t
    var = jnp.sum((wb ** 2) * var_b, axis=0) + (wt ** 2) * var_t
    return mu, jnp.maximum(var, 1e-10)


def ensemble_posterior_batched(ens: BatchedEnsemble, xq: jnp.ndarray, *,
                               impl: str = "xla"
                               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weighted mixture posterior from one batched base query + the
    target query (standardised scale); matches ``ensemble_posterior``."""
    mu_b, var_b = batched_posterior(ens.bases, xq, impl=impl)   # (m, q)
    mu_t, var_t = gp_posterior(ens.target, xq, impl=impl)
    return mix_weighted(mu_b, var_b, mu_t, var_t, ens.weights)


def target_best(ens) -> jnp.ndarray:
    """Best (min) observed target value on the ensemble's output scale;
    works for both Ensemble and BatchedEnsemble (anything with .target).

    The ensemble mean at observed data is dominated by the target model's
    standardised y, so the incumbent for EI is the target's standardised
    minimum scaled by its weight-mixed mean — we use the plain
    standardised min, which is exact when the target carries the weight
    and rank-correct otherwise."""
    return jnp.min(ens.target.y)
