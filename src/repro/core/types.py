"""Core datatypes for Karasu.

Data minimalism (paper §III-B): a shared run record carries ONLY
``(z, c, agg(l), y)`` — an opaque workload id, the resource configuration,
the quantile-compacted metric matrix, and the final performance measures.
Nothing about the workload itself (framework, algorithm, dataset) crosses
the sharing boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RunRecord:
    workload_id: str                 # z_i — opaque id, no workload details
    config: Mapping[str, Any]        # c_j — resource configuration
    metrics: np.ndarray              # agg(l_ij): (n_metrics, n_quantiles)
    measures: Mapping[str, float]    # y_ij: e.g. {"cost", "runtime", ...}

    @property
    def machine_type(self) -> str:
        return str(self.config.get("machine_type", ""))

    @property
    def node_count(self) -> int:
        return int(self.config.get("node_count", 1))

    def metric_vector(self) -> np.ndarray:
        return np.asarray(self.metrics, dtype=np.float64).reshape(-1)


@dataclasses.dataclass(frozen=True)
class Objective:
    name: str                        # key into RunRecord.measures
    minimize: bool = True


@dataclasses.dataclass(frozen=True)
class Constraint:
    name: str
    upper_bound: float               # feasible iff measure <= upper_bound


@dataclasses.dataclass
class Observation:
    config: Mapping[str, Any]
    x: np.ndarray                    # encoded configuration
    measures: Mapping[str, float]
    metrics: Optional[np.ndarray] = None


@dataclasses.dataclass
class BOResult:
    """History of one profiling search."""
    observations: List[Observation]
    best_index_per_iter: List[int]   # index of cheapest-feasible-so-far
    stopped_at: int                  # iteration where early stop triggered
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def measures_array(self, key: str) -> np.ndarray:
        return np.array([o.measures[key] for o in self.observations])
