"""The shared performance-data repository (paper §III-B "Sharing").

Stores only minimal tuples (z, c, agg(l), y). Supports the evaluation's
data-availability filters (Cases A-D) through arbitrary predicates over
*private* workload tags kept OUTSIDE the shared record (the emulation
layer knows each workload's framework/algorithm/dataset; the repository
payload itself never contains them).

Every workload carries a monotonically increasing *version* bumped on
``add_run``; the ``SupportModelStore`` keys its per-(workload, measure)
support GPs on that version, so one shared store serves many concurrent
searches and refits a model only when that workload actually received
new data — instead of every search rebuilding every support model from
scratch.
"""
from __future__ import annotations

import dataclasses
import json
import os
from collections import OrderedDict, defaultdict
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)

import numpy as np

from .types import RunRecord


class Repository:
    def __init__(self) -> None:
        self._runs: Dict[str, List[RunRecord]] = defaultdict(list)
        self._versions: Dict[str, int] = defaultdict(int)

    # -- sharing API -------------------------------------------------------
    def add_run(self, run: RunRecord) -> None:
        self._runs[run.workload_id].append(run)
        self._versions[run.workload_id] += 1

    def add_runs(self, runs: Iterable[RunRecord]) -> None:
        for r in runs:
            self.add_run(r)

    def workloads(self) -> List[str]:
        return list(self._runs.keys())

    def runs(self, workload_id: str) -> List[RunRecord]:
        return list(self._runs.get(workload_id, []))

    def all_runs(self) -> Dict[str, List[RunRecord]]:
        return {z: list(rs) for z, rs in self._runs.items()}

    def version(self, workload_id: str) -> int:
        """Data version of one workload (0 if absent, bumped by add_run)."""
        return self._versions.get(workload_id, 0)

    def global_version(self) -> int:
        """Sum of all workload versions — changes iff any run was added."""
        return sum(self._versions.values())

    def __len__(self) -> int:
        return sum(len(rs) for rs in self._runs.values())

    # -- filtering (evaluation harness) -------------------------------------
    def filtered(self, keep: Callable[[str], bool]) -> "Repository":
        out = Repository()
        for z, rs in self._runs.items():
            if keep(z):
                out.add_runs(rs)
        return out

    def truncated(self, counts: Mapping[str, int]) -> "Repository":
        """Keep only the first counts[z] runs per workload (heterogeneous
        data-amount experiments, paper §IV-D)."""
        out = Repository()
        for z, rs in self._runs.items():
            out.add_runs(rs[:counts.get(z, len(rs))])
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload = []
        for z, rs in self._runs.items():
            for r in rs:
                payload.append({
                    "z": z,
                    "config": dict(r.config),
                    "metrics": np.asarray(r.metrics).tolist(),
                    "measures": {k: float(v) for k, v in r.measures.items()},
                })
        with open(path, "w") as f:
            json.dump(payload, f)

    @classmethod
    def load(cls, path: str) -> "Repository":
        repo = cls()
        with open(path) as f:
            payload = json.load(f)
        for item in payload:
            repo.add_run(RunRecord(
                workload_id=item["z"],
                config=item["config"],
                metrics=np.asarray(item["metrics"]),
                measures=item["measures"]))
        return repo


# ---------------------------------------------------------------------------
# Incremental support-model store
# ---------------------------------------------------------------------------


class SupportModelStore:
    """Version-keyed cache of support GPs, one per (workload, measure).

    Shared across every search hitting the same repository (the
    ``SearchService`` holds one per search space): a support model is
    (re)fit only when its workload's repository version moved since the
    cached fit, i.e. ``add_run`` invalidates exactly the workloads it
    touched. Workloads with fewer than ``min_runs`` usable observations
    (or zero spread in the measure) cache ``None``.

    The stack cache is LRU-bounded at ``max_entries`` (generous by
    default — a steady multi-tenant cohort re-requests a handful of
    support sets per step, but a LONG-lived service whose tenants churn
    through many (support set, measure) combinations must not grow
    memory without bound; each padded stack holds (m, n, n) Cholesky
    factors). Capacity evictions are counted in ``evictions``;
    version-stale entries are dropped separately (and for free) on
    misses.
    """

    def __init__(self, repository: Repository, space, *,
                 noise: float = 0.1, min_runs: int = 3,
                 max_entries: int = 256):
        self._repo = repository
        self._space = space
        self._noise = noise
        self._min_runs = min_runs
        self._max_entries = max_entries
        # (workload, measure) -> (repo version at fit time, GP | None)
        self._cache: Dict[Tuple[str, str], Tuple[int, Optional[object]]] = {}
        # (workload ids, measure) -> (versions at stack time, stack, ids)
        # in LRU order (most recently used last)
        self._stacked: "OrderedDict[Tuple[Tuple[str, ...], str], " \
            "Tuple[Tuple[int, ...], object, list]]" = OrderedDict()
        # (workload ids, measure) -> (versions, SharedMemory, handle) of
        # the stacks this store has exported cross-process
        self._shared: Dict[Tuple[Tuple[str, ...], str],
                           Tuple[Tuple[int, ...], object,
                                 "SharedStackHandle"]] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def repository(self) -> Repository:
        return self._repo

    def get(self, workload_id: str, measure: str):
        """Support GP for (workload, measure), refit iff data changed."""
        from .gp import fit_gp
        v = self._repo.version(workload_id)
        k = (workload_id, measure)
        hit = self._cache.get(k)
        if hit is not None and hit[0] == v:
            self.hits += 1
            return hit[1]
        self.misses += 1
        xs, ys = [], []
        for r in self._repo.runs(workload_id):
            if measure in r.measures:
                xs.append(self._space.encode(r.config))
                ys.append(r.measures[measure])
        if len(ys) >= self._min_runs and np.ptp(ys) > 0:
            gp = fit_gp(np.stack(xs), np.array(ys), noise=self._noise)
        else:
            gp = None
        self._cache[k] = (v, gp)
        return gp

    def get_stacked(self, workload_ids: Sequence[str], measure: str):
        """BatchedGP over the available support models for ``measure``
        (skipping unusable workloads); returns (BatchedGP | None, ids).

        Stacks are version-cached like the per-model fits (a service
        step re-requests the same support stacks every round — without
        the cache each request re-assembles and re-uploads the padded
        arrays) and padded to multiples of 8, so the posterior/sample
        query plans see stable, already-bucketed shapes."""
        from .gp import stack_gps
        from .plan import OBS_ROUND_TO
        key = (tuple(workload_ids), measure)
        vers = tuple(self._repo.version(z) for z in workload_ids)
        hit = self._stacked.get(key)
        if hit is not None and hit[0] == vers:
            self.hits += len(hit[2])
            self._stacked.move_to_end(key)          # LRU touch
            return hit[1], list(hit[2])
        gps, ids = [], []
        for z in workload_ids:
            gp = self.get(z, measure)
            if gp is not None:
                gps.append(gp)
                ids.append(z)
        # stack at the planner's observation bucket so repeated steps
        # re-enter the query plans on already-bucketed shapes
        stack = stack_gps(gps, round_to=OBS_ROUND_TO) if gps else None
        # misses are rare (a repo version moved, or a new support set):
        # use them to evict version-stale entries, so a long-running
        # service's cache tracks the live support sets instead of
        # accumulating dead padded stacks
        stale = [k for k, (v, _, _) in self._stacked.items()
                 if v != tuple(self._repo.version(z) for z in k[0])]
        for k in stale:
            del self._stacked[k]
        self._stacked[key] = (vers, stack, ids)
        # ... and the capacity bound evicts the least recently used
        # live entries beyond it
        while len(self._stacked) > self._max_entries:
            self._stacked.popitem(last=False)
            self.evictions += 1
        return stack, list(ids)

    def invalidate(self, workload_id: Optional[str] = None) -> None:
        """Drop cached fits (one workload, or everything)."""
        if workload_id is None:
            self._cache.clear()
            self._stacked.clear()
        else:
            for k in [k for k in self._cache if k[0] == workload_id]:
                del self._cache[k]
            for k in [k for k in self._stacked if workload_id in k[0]]:
                del self._stacked[k]

    # -- process-shared stacks ----------------------------------------------
    def export_shared(self, workload_ids: Sequence[str],
                      measure: str) -> Optional["SharedStackHandle"]:
        """Pack one support stack into a shared-memory segment and
        return its picklable ``SharedStackHandle`` — the cross-process
        twin of ``get_stacked``, for deployments running one service
        worker per process against a single repository owner: the owner
        exports, the tiny handle crosses the pickle boundary (the same
        boundary ``ProcessPoolProfileExecutor`` already imposes), and
        each worker attaches to the one segment instead of re-fitting
        and re-stacking every support model per process.

        The owner keeps the segment alive (re-exporting the same key at
        the same versions reuses it); ``close_shared()`` unlinks all
        exported segments. Returns ``None`` when no workload of the set
        is usable (the same cases ``get_stacked`` returns ``None``)."""
        stack, ids = self.get_stacked(workload_ids, measure)
        if stack is None:
            return None
        key = (tuple(workload_ids), measure)
        vers = tuple(self._repo.version(z) for z in workload_ids)
        hit = self._shared.get(key)
        if hit is not None and hit[0] == vers:
            return hit[2]
        from multiprocessing import shared_memory
        arrays = [(f, np.asarray(getattr(stack, f)))
                  for f in _SHARED_STACK_FIELDS]
        total = sum(a.nbytes for _, a in arrays)
        seg = shared_memory.SharedMemory(create=True, size=max(total, 1))
        fields, off = [], 0
        for f, a in arrays:
            view = np.ndarray(a.shape, a.dtype, buffer=seg.buf, offset=off)
            view[...] = a
            fields.append((f, a.shape, a.dtype.str, off))
            off += a.nbytes
        handle = SharedStackHandle(seg.name, tuple(fields),
                                   float(stack.noise), tuple(ids), vers)
        if hit is not None:       # versions moved: retire the old segment
            hit[1].close()
            hit[1].unlink()
        self._shared[key] = (vers, seg, handle)
        return handle

    def close_shared(self) -> None:
        """Release every exported segment (owner-side lifecycle end)."""
        for _, seg, _ in self._shared.values():
            seg.close()
            seg.unlink()
        self._shared.clear()


# which BatchedGP fields ride the shared segment, in layout order (the
# full posterior/sample working set: a worker attaching the handle can
# serve every plan-layer query without touching the repository)
_SHARED_STACK_FIELDS = ("x", "y", "mask", "y_mean", "y_std",
                        "log_lengthscales", "log_signal", "chol", "alpha",
                        "counts")


@dataclasses.dataclass(frozen=True)
class SharedStackHandle:
    """Picklable description of one exported support stack: the segment
    name plus each field's (name, shape, dtype, byte offset) — no array
    payload crosses the boundary, only this metadata."""
    shm_name: str
    fields: Tuple[Tuple[str, Tuple[int, ...], str, int], ...]
    noise: float
    ids: Tuple[str, ...]
    versions: Tuple[int, ...]


def load_shared_stack(handle: SharedStackHandle):
    """Attach a ``SharedStackHandle`` and materialise its ``BatchedGP``.

    Arrays are COPIED out of the segment onto the worker's device:
    numpy views into ``shm.buf`` die with the mapping (and jax would
    copy host->device anyway), so attach-copy-close leaves no lifetime
    coupling between the worker's stack and the owner's segment.
    Returns ``(BatchedGP, ids)`` — the ``get_stacked`` result shape."""
    import jax.numpy as jnp
    from multiprocessing import shared_memory

    from .gp import BatchedGP
    seg = shared_memory.SharedMemory(name=handle.shm_name)
    try:
        parts = {}
        for f, shape, dtype, off in handle.fields:
            view = np.ndarray(shape, np.dtype(dtype), buffer=seg.buf,
                              offset=off)
            parts[f] = jnp.asarray(np.array(view, copy=True))
    finally:
        seg.close()
    return (BatchedGP(parts["x"], parts["y"], parts["mask"],
                      parts["y_mean"], parts["y_std"],
                      parts["log_lengthscales"], parts["log_signal"],
                      handle.noise, parts["chol"], parts["alpha"],
                      parts["counts"]),
            list(handle.ids))


class SharedSupportModelStore:
    """Worker-side ``SupportModelStore`` twin serving stacks from
    shared-memory handles instead of fitting models: the owner process
    exports (``SupportModelStore.export_shared``), hands the pickled
    handles over, and workers resolve ``get_stacked`` against them —
    one repository fit, N processes serving it.

    ``get_stacked`` is handle-version-cached like the owner's stack
    cache: re-publishing a handle for the same key with moved versions
    (the owner re-exported after ``add_run``) re-attaches; an identical
    handle serves the already-materialised stack."""

    def __init__(self, handles: Optional[Mapping[Tuple[Tuple[str, ...],
                                                       str],
                                                 SharedStackHandle]] = None):
        self._handles: Dict[Tuple[Tuple[str, ...], str],
                            SharedStackHandle] = dict(handles or {})
        self._attached: Dict[Tuple[Tuple[str, ...], str],
                             Tuple[Tuple[int, ...], object, list]] = {}
        self.hits = 0
        self.misses = 0

    def publish(self, workload_ids: Sequence[str], measure: str,
                handle: Optional[SharedStackHandle]) -> None:
        """Install (or clear, with ``None``) the handle for one key."""
        key = (tuple(workload_ids), measure)
        if handle is None:
            self._handles.pop(key, None)
            self._attached.pop(key, None)
        else:
            self._handles[key] = handle

    def get_stacked(self, workload_ids: Sequence[str], measure: str):
        key = (tuple(workload_ids), measure)
        handle = self._handles.get(key)
        if handle is None:
            return None, []
        hit = self._attached.get(key)
        if hit is not None and hit[0] == handle.versions:
            self.hits += 1
            return hit[1], list(hit[2])
        self.misses += 1
        stack, ids = load_shared_stack(handle)
        self._attached[key] = (handle.versions, stack, ids)
        return stack, list(ids)
