"""The shared performance-data repository (paper §III-B "Sharing").

Stores only minimal tuples (z, c, agg(l), y). Supports the evaluation's
data-availability filters (Cases A-D) through arbitrary predicates over
*private* workload tags kept OUTSIDE the shared record (the emulation
layer knows each workload's framework/algorithm/dataset; the repository
payload itself never contains them).
"""
from __future__ import annotations

import dataclasses
import json
import os
from collections import defaultdict
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence

import numpy as np

from .types import RunRecord


class Repository:
    def __init__(self) -> None:
        self._runs: Dict[str, List[RunRecord]] = defaultdict(list)

    # -- sharing API -------------------------------------------------------
    def add_run(self, run: RunRecord) -> None:
        self._runs[run.workload_id].append(run)

    def add_runs(self, runs: Iterable[RunRecord]) -> None:
        for r in runs:
            self.add_run(r)

    def workloads(self) -> List[str]:
        return list(self._runs.keys())

    def runs(self, workload_id: str) -> List[RunRecord]:
        return list(self._runs.get(workload_id, []))

    def all_runs(self) -> Dict[str, List[RunRecord]]:
        return {z: list(rs) for z, rs in self._runs.items()}

    def __len__(self) -> int:
        return sum(len(rs) for rs in self._runs.values())

    # -- filtering (evaluation harness) -------------------------------------
    def filtered(self, keep: Callable[[str], bool]) -> "Repository":
        out = Repository()
        for z, rs in self._runs.items():
            if keep(z):
                out.add_runs(rs)
        return out

    def truncated(self, counts: Mapping[str, int]) -> "Repository":
        """Keep only the first counts[z] runs per workload (heterogeneous
        data-amount experiments, paper §IV-D)."""
        out = Repository()
        for z, rs in self._runs.items():
            out.add_runs(rs[:counts.get(z, len(rs))])
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload = []
        for z, rs in self._runs.items():
            for r in rs:
                payload.append({
                    "z": z,
                    "config": dict(r.config),
                    "metrics": np.asarray(r.metrics).tolist(),
                    "measures": {k: float(v) for k, v in r.measures.items()},
                })
        with open(path, "w") as f:
            json.dump(payload, f)

    @classmethod
    def load(cls, path: str) -> "Repository":
        repo = cls()
        with open(path) as f:
            payload = json.load(f)
        for item in payload:
            repo.add_run(RunRecord(
                workload_id=item["z"],
                config=item["config"],
                metrics=np.asarray(item["metrics"]),
                measures=item["measures"]))
        return repo
