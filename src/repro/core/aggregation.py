"""The paper's ``agg`` function (§III-B): R^{n x t} -> R^{n x b}, b << t.

Low-level metrics recorded per machine over time are compacted to
per-metric quantiles (10th/50th/90th by default) across time AND
machines, yielding the compact metric vector shared in the repository —
six sar metrics x three quantiles = 18 floats in the paper's setup.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

DEFAULT_QUANTILES: Tuple[float, ...] = (0.1, 0.5, 0.9)

# sar metrics used in the paper's evaluation (§IV-B)
SAR_METRICS = ("cpu_idle_pct", "mem_used_pct", "disk_util_pct",
               "net_ifutil_pct", "swap_used_pct", "paging_vmeff_pct")


def aggregate_metrics(raw: np.ndarray,
                      quantiles: Sequence[float] = DEFAULT_QUANTILES
                      ) -> np.ndarray:
    """raw: (n_metrics, ...) metric samples over (machines x time) or any
    trailing layout -> (n_metrics, len(quantiles)) compact matrix."""
    raw = np.asarray(raw, dtype=np.float64)
    flat = raw.reshape(raw.shape[0], -1)
    return np.quantile(flat, list(quantiles), axis=1).T.copy()
