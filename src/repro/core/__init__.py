"""Karasu core: collaborative resource-configuration profiling.

Public API:
    run_search / run_search_moo   — the BO loops (naive | augmented | karasu)
    Repository, RunRecord         — minimal-data sharing layer
    SearchSpace encoders          — AWS (scout-like) and TPU-mesh spaces
    fit_gp / build_ensemble       — the GP + RGPE machinery
"""
from .aggregation import SAR_METRICS, aggregate_metrics
from .bo import BOConfig, run_search
from .encoding import (SearchSpace, aws_search_space, scout_search_space,
                       tpu_search_space)
from .gp import GP, fit_gp, gp_posterior, gp_posterior_raw
from .moo import pareto_of_result, run_search_moo
from .repository import Repository
from .rgpe import Ensemble, build_ensemble, compute_weights, ensemble_posterior
from .selection import select_similar, select_similar_batched
from .types import BOResult, Constraint, Objective, Observation, RunRecord

__all__ = [
    "SAR_METRICS", "aggregate_metrics", "BOConfig", "run_search",
    "SearchSpace", "aws_search_space", "scout_search_space",
    "tpu_search_space", "GP", "fit_gp", "gp_posterior", "gp_posterior_raw",
    "pareto_of_result", "run_search_moo", "Repository", "Ensemble",
    "build_ensemble", "compute_weights", "ensemble_posterior",
    "select_similar", "select_similar_batched", "BOResult", "Constraint",
    "Objective", "Observation", "RunRecord",
]
