"""Karasu core: collaborative resource-configuration profiling.

Public API:
    run_search / run_search_moo   — the BO loops (naive | augmented | karasu)
    Repository, RunRecord         — minimal-data sharing layer
    SearchSpace encoders          — AWS (scout-like) and TPU-mesh spaces
    fit_gp / build_ensemble       — the GP + RGPE machinery
"""
from .aggregation import SAR_METRICS, aggregate_metrics
from .bo import BOConfig, KarasuContext, run_search
from .encoding import (SearchSpace, aws_search_space, scout_search_space,
                       tpu_search_space)
from .gp import (GP, BatchedGP, batched_posterior, batched_posterior_multi,
                 batched_sample, batched_sample_multi, fit_gp,
                 fit_gp_batched, gp_posterior, gp_posterior_raw, stack_gps)
from .moo import pareto_of_result, run_search_moo
from .plan import (Bucket, EhviQuery, LooSampleQuery, PlanExecutor,
                   PosteriorDrawQuery, PosteriorQuery, SampleQuery,
                   StepPlan, StepPlanner)
from .repository import Repository, SupportModelStore
from .rgpe import (BatchedEnsemble, Ensemble, WeightJob, build_ensemble,
                   build_ensemble_batched, compute_weights,
                   compute_weights_batched, compute_weights_multi,
                   ensemble_posterior, ensemble_posterior_batched,
                   mix_weighted)
from .selection import CandidateIndex, select_similar, select_similar_batched
from .types import BOResult, Constraint, Objective, Observation, RunRecord

__all__ = [
    "SAR_METRICS", "aggregate_metrics", "BOConfig", "KarasuContext",
    "run_search", "SearchSpace", "aws_search_space", "scout_search_space",
    "tpu_search_space", "GP", "BatchedGP", "batched_posterior",
    "batched_posterior_multi", "batched_sample", "batched_sample_multi",
    "fit_gp", "fit_gp_batched",
    "gp_posterior", "gp_posterior_raw", "stack_gps", "pareto_of_result",
    "run_search_moo",
    "Repository", "SupportModelStore", "BatchedEnsemble", "Ensemble",
    "WeightJob", "build_ensemble", "build_ensemble_batched",
    "compute_weights", "compute_weights_batched", "compute_weights_multi",
    "ensemble_posterior",
    "ensemble_posterior_batched",
    "mix_weighted", "CandidateIndex", "select_similar",
    "select_similar_batched", "BOResult", "Constraint", "Objective",
    "Observation", "RunRecord",
    "Bucket", "StepPlan", "StepPlanner", "PlanExecutor",
    "PosteriorQuery", "SampleQuery", "LooSampleQuery",
    "PosteriorDrawQuery", "EhviQuery",
]
