"""Resource-configuration encoders ``h`` (paper §III-B).

The encoder deterministically maps a resource configuration to a
discretised vector; its bounds describe the search space. Two concrete
spaces ship with the framework:

  - ``aws_search_space``  (machine type x node count) — the paper's
    evaluation space on the scout-like dataset.
  - ``tpu_search_space``  (pods x data x model layout, microbatch, remat,
    EP mode) — the TPU-pod adaptation used by launch/karasu_search.py.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Dict, List, Mapping, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Discrete search space + encoder."""
    name: str
    configs: Tuple[Mapping[str, Any], ...]           # all candidates
    encoder: Callable[[Mapping[str, Any]], np.ndarray]

    def encode(self, config: Mapping[str, Any]) -> np.ndarray:
        return np.asarray(self.encoder(config), dtype=np.float64)

    def all_encoded(self) -> np.ndarray:
        return np.stack([self.encode(c) for c in self.configs])

    def __len__(self) -> int:
        return len(self.configs)


# ---------------------------------------------------------------------------
# AWS space (scout-like): machine specs after CherryPick/Arrow
# ---------------------------------------------------------------------------

# family -> (cores, mem_gb, io_scale, net_scale) for the '.large' size
AWS_FAMILIES: Dict[str, Tuple[int, float, float, float]] = {
    "c4": (2, 3.75, 1.0, 1.0),
    "m4": (2, 8.0, 1.0, 1.0),
    "r4": (2, 15.25, 1.0, 2.0),
}
AWS_SIZES = {"large": 1, "xlarge": 2, "2xlarge": 4}


def machine_features(machine_type: str) -> Dict[str, float]:
    family, size = machine_type.split(".")
    cores, mem, io, net = AWS_FAMILIES[family]
    scale = AWS_SIZES[size]
    return {
        "cores": cores * scale,
        "mem_gb": mem * scale,
        "io_scale": io * scale,
        "net_scale": net * scale,
        "mem_per_core": mem / cores,
    }


def _aws_encode(config: Mapping[str, Any]) -> np.ndarray:
    f = machine_features(str(config["machine_type"]))
    n = int(config["node_count"])
    return np.array([
        math.log2(n) / 6.0,                  # node count (<= 64)
        math.log2(f["cores"]) / 5.0,         # per-machine cores
        math.log2(f["mem_gb"]) / 7.0,        # per-machine memory
        f["mem_per_core"] / 8.0,             # family signature
        f["net_scale"] / 8.0,
        math.log2(f["cores"] * n) / 9.0,     # total cores
        math.log2(f["mem_gb"] * n) / 11.0,   # total memory
    ])


def aws_search_space(machine_types: Sequence[str],
                     node_counts: Sequence[int]) -> SearchSpace:
    configs = tuple({"machine_type": mt, "node_count": nc}
                    for mt in machine_types for nc in node_counts)
    return SearchSpace("aws", configs, _aws_encode)


# the 69-config scout-like space: 9 machine types x scaleouts
SCOUT_MACHINE_TYPES = tuple(f"{fam}.{size}" for fam in AWS_FAMILIES
                            for size in AWS_SIZES)
SCOUT_NODE_COUNTS_WIDE = (4, 6, 8, 10, 12, 16, 20, 24)


def scout_search_space() -> SearchSpace:
    """9 machine types x 8 scaleouts = 72, trimmed to 69 as in scout
    (the three largest r4.2xlarge scaleouts are absent)."""
    configs = [
        {"machine_type": mt, "node_count": nc}
        for mt in SCOUT_MACHINE_TYPES for nc in SCOUT_NODE_COUNTS_WIDE
    ]
    configs = [c for c in configs
               if not (c["machine_type"] == "r4.2xlarge"
                       and c["node_count"] >= 20)]
    configs = configs[:69]
    return SearchSpace("scout-aws", tuple(configs), _aws_encode)


# ---------------------------------------------------------------------------
# TPU mesh space: the hardware adaptation
# ---------------------------------------------------------------------------


def _tpu_encode(config: Mapping[str, Any]) -> np.ndarray:
    pods = int(config["pods"])
    dp = int(config["data"])
    mp = int(config["model"])
    mb = int(config["microbatches"])
    remat = 1.0 if config.get("remat", True) else 0.0
    ep = {"none": 0.0, "allgather": 0.5, "a2a": 1.0}[
        config.get("ep_mode", "none")]
    sp = 1.0 if config.get("seq_parallel") else 0.0
    chips = pods * dp * mp
    return np.array([
        math.log2(chips) / 10.0,
        math.log2(mp) / 8.0,
        math.log2(dp) / 8.0,
        math.log2(pods) / 3.0 if pods > 1 else 0.0,
        math.log2(mb) / 6.0 if mb >= 1 else 0.0,
        remat,
        ep,
        sp,
    ])


def tpu_search_space(chips_per_pod: int = 256,
                     pods: Sequence[int] = (1, 2),
                     model_par: Sequence[int] = (4, 8, 16, 32),
                     microbatches: Sequence[int] = (1, 2, 4, 8, 16),
                     ep_modes: Sequence[str] = ("none",),
                     remat_opts: Sequence[bool] = (True,),
                     seq_parallel: Sequence[bool] = (False,)) -> SearchSpace:
    configs = []
    for p, mp, mb, ep, rm, sp in itertools.product(
            pods, model_par, microbatches, ep_modes, remat_opts,
            seq_parallel):
        if chips_per_pod % mp:
            continue
        dp = chips_per_pod // mp
        configs.append({"pods": p, "data": dp, "model": mp,
                        "microbatches": mb, "ep_mode": ep, "remat": rm,
                        "seq_parallel": sp,
                        "machine_type": f"v5e-pod{p}x{mp}",
                        "node_count": p * chips_per_pod // 4})
    return SearchSpace("tpu-mesh", tuple(configs), _tpu_encode)
