"""Acquisition functions: EI, constrained EI (EI x PoF), MC-EHVI.

All minimization convention. CherryPick's NaiveBO uses EI with the
feasibility-weighted form for runtime constraints; Karasu applies the
same acquisitions on the RGPE ensemble posterior; the MOO extension
(paper §III-D) weights expected (hypervolume) improvement of the
objectives by the probability of feasibility under every constraint.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _phi(z):
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)


def _Phi(z):
    return 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))


def expected_improvement(mu: jnp.ndarray, var: jnp.ndarray,
                         best: jnp.ndarray) -> jnp.ndarray:
    """Closed-form EI for minimization."""
    sigma = jnp.sqrt(var)
    z = (best - mu) / sigma
    ei = sigma * (z * _Phi(z) + _phi(z))
    return jnp.maximum(ei, 0.0)


def mc_expected_improvement(samples: jnp.ndarray, best: float
                            ) -> jnp.ndarray:
    """samples: (S, q) posterior draws -> (q,) MC-EI (noisy-EI style)."""
    return jnp.mean(jnp.maximum(best - samples, 0.0), axis=0)


def probability_of_feasibility(mu: jnp.ndarray, var: jnp.ndarray,
                               upper_bound: float) -> jnp.ndarray:
    """P(measure <= upper_bound) under the (Gaussian) constraint model."""
    return _Phi((upper_bound - mu) / jnp.sqrt(var))


def constrained_ei(mu_obj, var_obj, best,
                   constraint_posteriors: Sequence[Tuple[jnp.ndarray,
                                                         jnp.ndarray,
                                                         float]]
                   ) -> jnp.ndarray:
    """EI(objective) x prod_k PoF(constraint_k)."""
    acq = expected_improvement(mu_obj, var_obj, best)
    for mu_c, var_c, ub in constraint_posteriors:
        acq = acq * probability_of_feasibility(mu_c, var_c, ub)
    return acq


# ---------------------------------------------------------------------------
# 2-objective MC expected hypervolume improvement
# ---------------------------------------------------------------------------


def _hv_2d(front: np.ndarray, ref: np.ndarray) -> float:
    """Hypervolume dominated by `front` (minimization) wrt `ref` point.
    front: (k, 2)."""
    pts = front[np.all(front <= ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    pts = pts[np.argsort(pts[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in pts:
        if y < prev_y:
            hv += (ref[0] - x) * (prev_y - y)
            prev_y = y
    return float(hv)


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Non-dominated subset (minimization)."""
    keep = []
    for i, p in enumerate(points):
        dominated = np.any(np.all(points <= p, axis=1)
                           & np.any(points < p, axis=1))
        if not dominated:
            keep.append(i)
    return points[keep]


def mc_ehvi(samples_a: np.ndarray, samples_b: np.ndarray,
            observed: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """MC expected hypervolume improvement for 2 objectives.

    samples_a/b: (S, q) posterior draws per objective; observed: (n, 2)
    current observations; ref: (2,) reference point. Returns (q,)."""
    front = pareto_front(observed)
    hv0 = _hv_2d(front, ref)
    s, q = samples_a.shape
    out = np.zeros(q)
    for j in range(q):
        gain = 0.0
        for i in range(s):
            p = np.array([samples_a[i, j], samples_b[i, j]])
            hv1 = _hv_2d(np.vstack([front, p[None]]), ref)
            gain += max(hv1 - hv0, 0.0)
        out[j] = gain / s
    return out
