"""Acquisition functions: EI, constrained EI (EI x PoF), MC-EHVI.

All minimization convention. CherryPick's NaiveBO uses EI with the
feasibility-weighted form for runtime constraints; Karasu applies the
same acquisitions on the RGPE ensemble posterior; the MOO extension
(paper §III-D) weights expected (hypervolume) improvement of the
objectives by the probability of feasibility under every constraint.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _phi(z):
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)


def _Phi(z):
    return 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))


VAR_FLOOR = 1e-12   # degenerate posteriors (var=0 at an observed point
                    # with tiny noise) must yield 0/finite EI, never NaN


def feasible(obs, constraints: Sequence) -> bool:
    """THE constraint-satisfaction rule (duck-typed over
    ``core.types.Observation``): every acquisition's feasible set and
    every reported Pareto front apply this one predicate."""
    return all(obs.measures[c.name] <= c.upper_bound for c in constraints)


def expected_improvement(mu: jnp.ndarray, var: jnp.ndarray,
                         best: jnp.ndarray) -> jnp.ndarray:
    """Closed-form EI for minimization."""
    sigma = jnp.sqrt(jnp.maximum(var, VAR_FLOOR))
    z = (best - mu) / sigma
    ei = sigma * (z * _Phi(z) + _phi(z))
    return jnp.maximum(ei, 0.0)


def mc_expected_improvement(samples: jnp.ndarray, best: float
                            ) -> jnp.ndarray:
    """samples: (S, q) posterior draws -> (q,) MC-EI (noisy-EI style)."""
    return jnp.mean(jnp.maximum(best - samples, 0.0), axis=0)


def probability_of_feasibility(mu: jnp.ndarray, var: jnp.ndarray,
                               upper_bound: float) -> jnp.ndarray:
    """P(measure <= upper_bound) under the (Gaussian) constraint model."""
    return _Phi((upper_bound - mu) / jnp.sqrt(jnp.maximum(var, VAR_FLOOR)))


def constrained_ei(mu_obj, var_obj, best,
                   constraint_posteriors: Sequence[Tuple[jnp.ndarray,
                                                         jnp.ndarray,
                                                         float]]
                   ) -> jnp.ndarray:
    """EI(objective) x prod_k PoF(constraint_k)."""
    acq = expected_improvement(mu_obj, var_obj, best)
    for mu_c, var_c, ub in constraint_posteriors:
        acq = acq * probability_of_feasibility(mu_c, var_c, ub)
    return acq


# ---------------------------------------------------------------------------
# 2-objective MC expected hypervolume improvement
# ---------------------------------------------------------------------------


def _hv_2d(front: np.ndarray, ref: np.ndarray) -> float:
    """Hypervolume dominated by `front` (minimization) wrt `ref` point.
    front: (k, 2)."""
    pts = front[np.all(front <= ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    pts = pts[np.argsort(pts[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in pts:
        if y < prev_y:
            hv += (ref[0] - x) * (prev_y - y)
            prev_y = y
    return float(hv)


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Non-dominated subset (minimization), first occurrence per
    distinct point. The domination check alone keeps every copy of a
    repeated observation (a point never strictly dominates its twin),
    so the explicit dedup is what stops reported fronts from carrying
    duplicate rows."""
    keep = []
    for i, p in enumerate(points):
        dominated = np.any(np.all(points <= p, axis=1)
                           & np.any(points < p, axis=1))
        duplicate = i > 0 and bool(
            np.any(np.all(points[:i] == p, axis=1)))
        if not dominated and not duplicate:
            keep.append(i)
    return points[keep]


def pareto_of_observations(observations, objectives,
                           constraints: Sequence = ()) -> np.ndarray:
    """Feasible non-dominated (k, n_obj) objective points of a profiling
    history (duck-typed over ``core.types.Observation``). The one
    front-extraction rule shared by ``pareto_of_result`` and the
    serving layer's MOO completions."""
    pts = np.array([[o.measures[obj.name] for obj in objectives]
                    for o in observations if feasible(o, constraints)])
    if len(pts) == 0:
        return np.empty((0, len(objectives)))
    return pareto_front(pts)


def mc_ehvi(samples_a: np.ndarray, samples_b: np.ndarray,
            observed: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """MC expected hypervolume improvement for 2 objectives — reference
    per-candidate loop (one ``_hv_2d`` per sample x candidate). The
    serving path uses ``mc_ehvi_batched``, which this stays the oracle
    for.

    samples_a/b: (S, q) posterior draws per objective; observed: (n, 2)
    current observations; ref: (2,) reference point. Returns (q,)."""
    front = pareto_front(observed)
    hv0 = _hv_2d(front, ref)
    s, q = samples_a.shape
    out = np.zeros(q)
    for j in range(q):
        gain = 0.0
        for i in range(s):
            p = np.array([samples_a[i, j], samples_b[i, j]])
            hv1 = _hv_2d(np.vstack([front, p[None]]), ref)
            gain += max(hv1 - hv0, 0.0)
        out[j] = gain / s
    return out


def _staircase(front: np.ndarray, ref: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The staircase lower envelope of a 2-D front as segments.

    Returns ``(lefts, rights, heights)`` of k+1 x-intervals: left of the
    first vertex nothing is dominated (height +inf); between vertices i
    and i+1 the dominated region starts at y_i; right of the last vertex
    it stays at y_k. Points outside ``ref`` cannot dominate anything in
    the reference box and are dropped; duplicate / tied points collapse
    onto one step."""
    pts = np.asarray(front, dtype=np.float64).reshape(-1, 2)
    pts = pts[np.all(pts <= ref, axis=1)]
    if len(pts):
        pts = pts[np.lexsort((pts[:, 1], pts[:, 0]))]
        env = np.minimum.accumulate(pts[:, 1])
        keep = np.ones(len(pts), dtype=bool)
        keep[1:] = env[1:] < env[:-1]       # strictly lower step only
        pts = np.column_stack([pts[:, 0], env])[keep]
    xs, ys = pts[:, 0], pts[:, 1]
    lefts = np.concatenate([[-np.inf], xs])
    rights = np.concatenate([xs, [np.inf]])
    heights = np.concatenate([[np.inf], ys])
    return lefts, rights, heights


def mc_ehvi_batched(samples_a: np.ndarray, samples_b: np.ndarray,
                    observed: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Vectorised twin of ``mc_ehvi``: every (sample, candidate) point's
    exclusive hypervolume contribution in one broadcast over the
    staircase segments, no Python loop.

    The area a point p adds to the dominated region is, per staircase
    segment, (x-overlap of [p_a, ref_a] with the segment) x (clipped
    height min(seg_y, ref_b) - p_b) — zero automatically when p lies
    outside the reference box or is dominated by the front."""
    lefts, rights, heights = _staircase(pareto_front(observed), ref)
    pa = np.asarray(samples_a, dtype=np.float64)[..., None]   # (S, q, 1)
    pb = np.asarray(samples_b, dtype=np.float64)[..., None]
    w = np.clip(np.minimum(rights, ref[0]) - np.maximum(lefts, pa),
                0.0, None)
    h = np.clip(np.minimum(heights, ref[1]) - pb, 0.0, None)
    return np.sum(w * h, axis=-1).mean(axis=0)


# ---------------------------------------------------------------------------
# n-objective hypervolume (box decomposition + recursive-sweep oracle)
# ---------------------------------------------------------------------------


def hv_nd(points: np.ndarray, ref: np.ndarray) -> float:
    """Hypervolume dominated by ``points`` wrt ``ref`` (minimization),
    any dimension — the recursive dimension-sweep reference: slice along
    the last axis at every distinct coordinate and recurse on the
    projection of the points at or below the slice. Independent of the
    box decomposition below, so it serves as its parity oracle. f64."""
    ref = np.asarray(ref, np.float64)
    d = ref.shape[0]
    pts = np.asarray(points, np.float64).reshape(-1, d)
    pts = pts[np.all(pts <= ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    if d == 1:
        return float(ref[0] - pts.min())
    hv = 0.0
    zs = np.unique(pts[:, -1])
    for i, z in enumerate(zs):
        z_hi = zs[i + 1] if i + 1 < len(zs) else ref[-1]
        if z_hi <= z:
            continue
        hv += (z_hi - z) * hv_nd(pts[pts[:, -1] <= z][:, :-1], ref[:-1])
    return float(hv)


def mc_ehvi_nd(samples: Sequence[np.ndarray], observed: np.ndarray,
               ref: np.ndarray) -> np.ndarray:
    """MC expected hypervolume improvement for n objectives — reference
    per-(sample, candidate) loop over the recursive-sweep ``hv_nd``.
    The f64 parity oracle the fused box-decomposition path is tested
    against (and the ``fuse_samples=False`` serving baseline for n>2).

    ``samples``: one (S, q) raw-scale posterior draw array per
    objective; ``observed``: (n, n_obj); ``ref``: (n_obj,). -> (q,)."""
    ref = np.asarray(ref, np.float64)
    front = pareto_front(np.asarray(observed, np.float64)
                         .reshape(-1, ref.shape[0]))
    hv0 = hv_nd(front, ref)
    s, q = np.asarray(samples[0]).shape
    out = np.zeros(q)
    for j in range(q):
        gain = 0.0
        for i in range(s):
            p = np.array([np.asarray(sm)[i, j] for sm in samples])
            gain += max(hv_nd(np.vstack([front, p[None]]), ref) - hv0, 0.0)
        out[j] = gain / s
    return out


def nondominated_boxes(front: np.ndarray, ref: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Disjoint axis-aligned boxes covering the NON-dominated region
    ``{x <= ref, no front point dominates x}`` — the decomposition MC
    box-EHVI integrates against: the hypervolume a candidate p adds is
    exactly ``sum_b vol([p, ref] ∩ b)`` over these boxes.

    Returns ``(los, his)``, each (K, n_obj); lower bounds may be -inf
    (the region is unbounded below). 2 objectives use the staircase
    envelope (k+1 boxes); n >= 3 use the coordinate grid spanned by the
    front's per-axis values with dominated cells dropped — within one
    grid cell domination by the front is constant, so keeping exactly
    the cells whose lower corner is undominated tiles the region. Cell
    count is O((k+1)^n): fine for profiling-scale fronts (k <= tens),
    the regime Karasu serves."""
    ref = np.asarray(ref, np.float64)
    d = ref.shape[0]
    pts = np.asarray(front, np.float64).reshape(-1, d)
    pts = pts[np.all(pts <= ref, axis=1)]
    if d == 2:
        lefts, rights, heights = _staircase(pts, ref)
        los = np.column_stack([lefts, np.full_like(lefts, -np.inf)])
        his = np.column_stack([rights, heights])
        return los, his
    axes_lo = [np.concatenate([[-np.inf], np.unique(pts[:, k])])
               for k in range(d)]
    axes_hi = [np.concatenate([np.unique(pts[:, k]), [ref[k]]])
               for k in range(d)]
    grids_lo = np.meshgrid(*axes_lo, indexing="ij")
    grids_hi = np.meshgrid(*axes_hi, indexing="ij")
    los = np.stack([g.ravel() for g in grids_lo], axis=1)   # (cells, d)
    his = np.stack([g.ravel() for g in grids_hi], axis=1)
    if len(pts):
        dominated = np.any(np.all(pts[None, :, :] <= los[:, None, :],
                                  axis=2), axis=1)
        los, his = los[~dominated], his[~dominated]
    nonempty = np.all(his > los, axis=1)
    return los[nonempty], his[nonempty]


# ---------------------------------------------------------------------------
# Fused EHVI: MANY sessions' box decompositions in one vmapped launch
# ---------------------------------------------------------------------------


EhviJob = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]
# legacy 2-objective form: (samples_a (S, q), samples_b (S, q),
# observed (n, 2), ref (2,)); the n-objective form is
# ((samples_0, ..., samples_{D-1}), observed (n, D), ref (D,))


EHVI_BOX_CHUNK = 1024
# boxes per fused-EHVI block: the launch materialises (L, S, q, K_blk)
# intermediates, so past this many boxes (deep n>=3 fronts — the grid
# decomposition is O((k+1)^n)) the box axis is processed as a scan of
# fixed-size blocks instead of one broadcast, bounding peak memory while
# keeping one compiled program per (K / chunk) count


def _ehvi_box_block(los, his, refs, ps):
    """Sum over one block of boxes of each (sample, candidate) point's
    overlap volume. -> (L, S, q)."""
    vol = None
    for dim in range(los.shape[-1]):
        lo = los[:, None, None, :, dim]                # (L, 1, 1, K)
        hi = his[:, None, None, :, dim]
        ref = refs[:, dim][:, None, None, None]
        p = ps[:, dim, :, :, None]                     # (L, S, q, 1)
        w = jnp.clip(jnp.minimum(hi, ref) - jnp.maximum(lo, p), 0.0, None)
        vol = w if vol is None else vol * w
    return jnp.sum(vol, axis=-1)


def _ehvi_box_eval(los, his, refs, ps):
    """Per-lane box-decomposition EHVI, any objective count. los/his:
    (L, K, D) box bounds of each lane's non-dominated region (padding
    boxes have lo = hi = +inf, contributing exactly zero volume); refs:
    (L, D); ps: (L, D, S, q) raw-scale draws. -> (L, q). The dominated
    volume a point p adds is, per box, the product over objectives of
    (overlap of [p_d, ref_d] with the box's d-extent) — the staircase
    launch this generalises is the D=2 case (segments are boxes with
    lo_1 = -inf). Past ``EHVI_BOX_CHUNK`` boxes the box axis runs as a
    scan of fixed-size blocks, so peak memory never scales with front
    depth; direct callers may bypass the planner's chunk-multiple
    padding, so a trailing partial block is padded here with zero-volume
    boxes rather than reshaped away."""
    l, k, d = los.shape
    if k <= EHVI_BOX_CHUNK:
        return jnp.mean(_ehvi_box_block(los, his, refs, ps), axis=1)
    pad = (-k) % EHVI_BOX_CHUNK
    if pad:
        los = jnp.pad(los, ((0, 0), (0, pad), (0, 0)),
                      constant_values=jnp.inf)
        his = jnp.pad(his, ((0, 0), (0, pad), (0, 0)),
                      constant_values=jnp.inf)
    nc = (k + pad) // EHVI_BOX_CHUNK
    los_c = jnp.moveaxis(los.reshape(l, nc, EHVI_BOX_CHUNK, d), 1, 0)
    his_c = jnp.moveaxis(his.reshape(l, nc, EHVI_BOX_CHUNK, d), 1, 0)

    def body(acc, blk):
        lo_i, hi_i = blk
        return acc + _ehvi_box_block(lo_i, hi_i, refs, ps), None

    init = jnp.zeros(ps.shape[:1] + ps.shape[2:], ps.dtype)   # (L, S, q)
    acc, _ = jax.lax.scan(body, init, (los_c, his_c))
    return jnp.mean(acc, axis=1)


_ehvi_box_launch = jax.jit(_ehvi_box_eval)
# donated twin for the plan executor: every argument is host-assembled
# per step (np.stack of padded boxes/draws), so nothing aliases a
# session-cached buffer and donation is unconditionally safe here
_ehvi_box_launch_donated = jax.jit(_ehvi_box_eval,
                                   donate_argnums=(0, 1, 2, 3))


def _normalize_ehvi_job(job) -> Tuple[Tuple[np.ndarray, ...], np.ndarray,
                                      np.ndarray]:
    """Accept both the legacy 4-tuple 2-objective job and the
    n-objective ``(samples_tuple, observed, ref)`` form."""
    if len(job) == 4:
        sa, sb, observed, ref = job
        return (sa, sb), observed, ref
    samples, observed, ref = job
    return tuple(samples), observed, ref


def mc_ehvi_multi(jobs: Sequence, *,
                  q_round_to: Optional[int] = None,
                  m_round_pow2: Optional[bool] = None,
                  counters: Optional[dict] = None) -> List[np.ndarray]:
    """MANY sessions' MC-EHVI evaluations as ONE vmapped box launch per
    (n_obj, S, q) bucket — the acquisition-side leg of the sample query
    plan (every MOO session of a service step becomes a lane instead of
    a per-session numpy broadcast). Thin wrapper over the query-plan
    layer (``core.plan``): builds one ``EhviQuery`` per job and lets the
    ``StepPlanner`` / ``PlanExecutor`` own all bucketing and padding
    (fronts pad to power-of-two box counts with zero-volume boxes, the
    candidate axis to a ``q_round_to`` bucket with +inf sample points,
    the lane axis to a power of two).

    Each job is ``(samples_a, samples_b, observed, ref)`` exactly as
    ``mc_ehvi_batched`` takes them, or ``(samples_tuple, observed,
    ref)`` for n objectives. Returns one ``(q,)`` array per job, in
    input order, matching ``mc_ehvi_batched`` / ``mc_ehvi_nd`` to
    float32 roundoff (the fused kernel computes in f32; the numpy twins
    stay the f64 parity oracles)."""
    from .plan import (EhviQuery, PlanExecutor, StepPlanner,
                       flatten_counters)
    planner = StepPlanner(q_round_to=q_round_to, m_round_pow2=m_round_pow2)
    queries = [EhviQuery(*_normalize_ehvi_job(job)) for job in jobs]
    nested: dict = {}
    results = PlanExecutor().execute(planner.plan(queries), counters=nested)
    flatten_counters(nested, counters, ("ehvi",))
    return results
