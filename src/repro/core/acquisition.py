"""Acquisition functions: EI, constrained EI (EI x PoF), MC-EHVI.

All minimization convention. CherryPick's NaiveBO uses EI with the
feasibility-weighted form for runtime constraints; Karasu applies the
same acquisitions on the RGPE ensemble posterior; the MOO extension
(paper §III-D) weights expected (hypervolume) improvement of the
objectives by the probability of feasibility under every constraint.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _phi(z):
    return jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)


def _Phi(z):
    return 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))


VAR_FLOOR = 1e-12   # degenerate posteriors (var=0 at an observed point
                    # with tiny noise) must yield 0/finite EI, never NaN


def feasible(obs, constraints: Sequence) -> bool:
    """THE constraint-satisfaction rule (duck-typed over
    ``core.types.Observation``): every acquisition's feasible set and
    every reported Pareto front apply this one predicate."""
    return all(obs.measures[c.name] <= c.upper_bound for c in constraints)


def expected_improvement(mu: jnp.ndarray, var: jnp.ndarray,
                         best: jnp.ndarray) -> jnp.ndarray:
    """Closed-form EI for minimization."""
    sigma = jnp.sqrt(jnp.maximum(var, VAR_FLOOR))
    z = (best - mu) / sigma
    ei = sigma * (z * _Phi(z) + _phi(z))
    return jnp.maximum(ei, 0.0)


def mc_expected_improvement(samples: jnp.ndarray, best: float
                            ) -> jnp.ndarray:
    """samples: (S, q) posterior draws -> (q,) MC-EI (noisy-EI style)."""
    return jnp.mean(jnp.maximum(best - samples, 0.0), axis=0)


def probability_of_feasibility(mu: jnp.ndarray, var: jnp.ndarray,
                               upper_bound: float) -> jnp.ndarray:
    """P(measure <= upper_bound) under the (Gaussian) constraint model."""
    return _Phi((upper_bound - mu) / jnp.sqrt(jnp.maximum(var, VAR_FLOOR)))


def constrained_ei(mu_obj, var_obj, best,
                   constraint_posteriors: Sequence[Tuple[jnp.ndarray,
                                                         jnp.ndarray,
                                                         float]]
                   ) -> jnp.ndarray:
    """EI(objective) x prod_k PoF(constraint_k)."""
    acq = expected_improvement(mu_obj, var_obj, best)
    for mu_c, var_c, ub in constraint_posteriors:
        acq = acq * probability_of_feasibility(mu_c, var_c, ub)
    return acq


# ---------------------------------------------------------------------------
# 2-objective MC expected hypervolume improvement
# ---------------------------------------------------------------------------


def _hv_2d(front: np.ndarray, ref: np.ndarray) -> float:
    """Hypervolume dominated by `front` (minimization) wrt `ref` point.
    front: (k, 2)."""
    pts = front[np.all(front <= ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    pts = pts[np.argsort(pts[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in pts:
        if y < prev_y:
            hv += (ref[0] - x) * (prev_y - y)
            prev_y = y
    return float(hv)


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Non-dominated subset (minimization)."""
    keep = []
    for i, p in enumerate(points):
        dominated = np.any(np.all(points <= p, axis=1)
                           & np.any(points < p, axis=1))
        if not dominated:
            keep.append(i)
    return points[keep]


def pareto_of_observations(observations, objectives,
                           constraints: Sequence = ()) -> np.ndarray:
    """Feasible non-dominated (k, 2) objective points of a profiling
    history (duck-typed over ``core.types.Observation``). The one
    front-extraction rule shared by ``pareto_of_result`` and the
    serving layer's MOO completions."""
    pts = np.array([[o.measures[objectives[0].name],
                     o.measures[objectives[1].name]]
                    for o in observations if feasible(o, constraints)])
    if len(pts) == 0:
        return np.empty((0, 2))
    return pareto_front(pts)


def mc_ehvi(samples_a: np.ndarray, samples_b: np.ndarray,
            observed: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """MC expected hypervolume improvement for 2 objectives — reference
    per-candidate loop (one ``_hv_2d`` per sample x candidate). The
    serving path uses ``mc_ehvi_batched``, which this stays the oracle
    for.

    samples_a/b: (S, q) posterior draws per objective; observed: (n, 2)
    current observations; ref: (2,) reference point. Returns (q,)."""
    front = pareto_front(observed)
    hv0 = _hv_2d(front, ref)
    s, q = samples_a.shape
    out = np.zeros(q)
    for j in range(q):
        gain = 0.0
        for i in range(s):
            p = np.array([samples_a[i, j], samples_b[i, j]])
            hv1 = _hv_2d(np.vstack([front, p[None]]), ref)
            gain += max(hv1 - hv0, 0.0)
        out[j] = gain / s
    return out


def _staircase(front: np.ndarray, ref: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The staircase lower envelope of a 2-D front as segments.

    Returns ``(lefts, rights, heights)`` of k+1 x-intervals: left of the
    first vertex nothing is dominated (height +inf); between vertices i
    and i+1 the dominated region starts at y_i; right of the last vertex
    it stays at y_k. Points outside ``ref`` cannot dominate anything in
    the reference box and are dropped; duplicate / tied points collapse
    onto one step."""
    pts = np.asarray(front, dtype=np.float64).reshape(-1, 2)
    pts = pts[np.all(pts <= ref, axis=1)]
    if len(pts):
        pts = pts[np.lexsort((pts[:, 1], pts[:, 0]))]
        env = np.minimum.accumulate(pts[:, 1])
        keep = np.ones(len(pts), dtype=bool)
        keep[1:] = env[1:] < env[:-1]       # strictly lower step only
        pts = np.column_stack([pts[:, 0], env])[keep]
    xs, ys = pts[:, 0], pts[:, 1]
    lefts = np.concatenate([[-np.inf], xs])
    rights = np.concatenate([xs, [np.inf]])
    heights = np.concatenate([[np.inf], ys])
    return lefts, rights, heights


def mc_ehvi_batched(samples_a: np.ndarray, samples_b: np.ndarray,
                    observed: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Vectorised twin of ``mc_ehvi``: every (sample, candidate) point's
    exclusive hypervolume contribution in one broadcast over the
    staircase segments, no Python loop.

    The area a point p adds to the dominated region is, per staircase
    segment, (x-overlap of [p_a, ref_a] with the segment) x (clipped
    height min(seg_y, ref_b) - p_b) — zero automatically when p lies
    outside the reference box or is dominated by the front."""
    lefts, rights, heights = _staircase(pareto_front(observed), ref)
    pa = np.asarray(samples_a, dtype=np.float64)[..., None]   # (S, q, 1)
    pb = np.asarray(samples_b, dtype=np.float64)[..., None]
    w = np.clip(np.minimum(rights, ref[0]) - np.maximum(lefts, pa),
                0.0, None)
    h = np.clip(np.minimum(heights, ref[1]) - pb, 0.0, None)
    return np.sum(w * h, axis=-1).mean(axis=0)


# ---------------------------------------------------------------------------
# Fused EHVI: MANY sessions' staircases in one vmapped launch
# ---------------------------------------------------------------------------


EhviJob = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]
# (samples_a (S, q), samples_b (S, q), observed (n, 2), ref (2,))


@jax.jit
def _ehvi_staircase_launch(lefts, rights, heights, refs, pa, pb):
    """Per-lane staircase EHVI. lefts/rights/heights: (L, K) segment
    bounds (padding segments have left = right = +inf, contributing
    exactly zero width); refs: (L, 2); pa/pb: (L, S, q). -> (L, q)."""
    ref_a = refs[:, 0][:, None, None, None]
    ref_b = refs[:, 1][:, None, None, None]
    seg_l = lefts[:, None, None, :]
    seg_r = rights[:, None, None, :]
    seg_h = heights[:, None, None, :]
    w = jnp.clip(jnp.minimum(seg_r, ref_a)
                 - jnp.maximum(seg_l, pa[..., None]), 0.0, None)
    h = jnp.clip(jnp.minimum(seg_h, ref_b) - pb[..., None], 0.0, None)
    return jnp.mean(jnp.sum(w * h, axis=-1), axis=1)


def mc_ehvi_multi(jobs: Sequence[EhviJob], *,
                  q_round_to: int = 8, m_round_pow2: bool = True,
                  counters: Optional[dict] = None) -> List[np.ndarray]:
    """MANY sessions' MC-EHVI evaluations as ONE vmapped staircase
    launch per (S, q) bucket — the acquisition-side leg of the sample
    query plan (every MOO session of a service step becomes a lane
    instead of a per-session numpy broadcast).

    Each job is ``(samples_a, samples_b, observed, ref)`` exactly as
    ``mc_ehvi_batched`` takes them. For jit-shape stability while
    candidate sets shrink and fronts grow step to step, fronts pad to a
    power-of-two segment count with zero-width (+inf) segments, the
    candidate axis to a ``q_round_to`` bucket with +inf sample points
    (zero hypervolume gain, sliced off), and the lane axis to a power of
    two — mirroring the posterior/sample plans' shape discipline.
    Returns one ``(q,)`` array per job, in input order, matching
    ``mc_ehvi_batched`` to float32 roundoff (the fused kernel computes
    in f32; the numpy twin stays the f64 parity oracle).
    """
    results: List[Optional[np.ndarray]] = [None] * len(jobs)
    stairs = [_staircase(pareto_front(np.asarray(obs)), np.asarray(ref))
              for _, _, obs, ref in jobs]
    groups: dict = {}
    for i, (sa, _, _, _) in enumerate(jobs):
        sa = np.asarray(sa)
        groups.setdefault((int(sa.shape[0]), int(sa.shape[1])),
                          []).append(i)

    for (_s, q), idxs in groups.items():
        k_max = max(stairs[i][0].shape[0] for i in idxs)
        k_pad = 1 << (k_max - 1).bit_length()
        q_pad = q
        if q_round_to > 1:
            q_pad = ((q + q_round_to - 1) // q_round_to) * q_round_to
        ls, rs, hs, refs, pas, pbs = [], [], [], [], [], []
        for i in idxs:
            lefts, rights, heights = stairs[i]
            p = k_pad - lefts.shape[0]
            # zero-width padding: left = right = +inf clips to w = 0
            ls.append(np.pad(lefts, (0, p), constant_values=np.inf))
            rs.append(np.pad(rights, (0, p), constant_values=np.inf))
            hs.append(np.pad(heights, (0, p), constant_values=0.0))
            refs.append(np.asarray(jobs[i][3], np.float32))
            # +inf candidates gain nothing and are sliced off below
            pas.append(np.pad(np.asarray(jobs[i][0], np.float32),
                              ((0, 0), (0, q_pad - q)),
                              constant_values=np.inf))
            pbs.append(np.pad(np.asarray(jobs[i][1], np.float32),
                              ((0, 0), (0, q_pad - q)),
                              constant_values=np.inf))
        parts = [jnp.asarray(np.stack(a).astype(np.float32))
                 for a in (ls, rs, hs, refs, pas, pbs)]
        l_total = len(idxs)
        if m_round_pow2:
            l_pad = 1 << (l_total - 1).bit_length()
            if l_pad > l_total:
                parts = [jnp.concatenate(
                    [a, jnp.broadcast_to(a[:1],
                                         (l_pad - l_total,) + a.shape[1:])])
                    for a in parts]
        out = _ehvi_staircase_launch(*parts)
        for j, i in enumerate(idxs):
            results[i] = np.asarray(out[j])[:q]
        if counters is not None:
            counters["launches"] = counters.get("launches", 0) + 1
            counters["queries"] = counters.get("queries", 0) + len(idxs)
    return results
