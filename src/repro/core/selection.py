"""Similarity-based data selection — the paper's Algorithm 1 (§III-C).

For a target workload z_i and candidate workloads z_j in the repository:
for every pair of runs (r_n of z_i, r_m of z_j) on the SAME machine type,
    weight = |log2 nodes(r_n) - log2 nodes(r_m)|
    DIST   -> (1 / 2^weight,  (pearsonr(metrics) + 1) / 2)
The candidate score is the scaling-factor-weighted average of the
similarity scores; candidates sorted descending, best k returned.

Two paths: the faithful pure-python loop (exactly Algorithm 1, used at
search-time sizes) and a vectorised batch path over the whole repository
using the ``pairwise_pearson`` kernel (the "proper distance operator" a
real deployment needs, §IV-E).
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.pairwise_pearson import pairwise_pearson
from .types import RunRecord


def dist(r_n: RunRecord, r_m: RunRecord) -> Tuple[float, float]:
    """The paper's DIST: (scaling factor, similarity score in [0,1])."""
    weight = abs(math.log2(max(r_n.node_count, 1))
                 - math.log2(max(r_m.node_count, 1)))
    a, b = r_n.metric_vector(), r_m.metric_vector()
    sa, sb = np.std(a), np.std(b)
    if sa < 1e-12 or sb < 1e-12:
        score = 0.0
    else:
        score = float(np.corrcoef(a, b)[0, 1])
    return 1.0 / (2.0 ** weight), (score + 1.0) / 2.0


def select_similar(
    target_runs: Sequence[RunRecord],
    candidates: Dict[str, Sequence[RunRecord]],
    k: int,
    *,
    default_score: float = 0.5,
) -> List[Tuple[str, float]]:
    """Algorithm 1, faithful loop. Returns the k best (workload_id, score)."""
    results: List[Tuple[str, float]] = []
    for z_j, runs_j in candidates.items():
        num, den = 0.0, 0.0
        for r_n in target_runs:
            for r_m in runs_j:
                if r_n.machine_type == r_m.machine_type:
                    w, s = dist(r_n, r_m)
                else:
                    w, s = 0.0, default_score  # default for unmatched types
                num += w * s
                den += w
        score = num / den if den > 0 else default_score
        results.append((z_j, score))
    results.sort(key=lambda t: -t[1])
    return results[:k]


class CandidateIndex:
    """Precomputed candidate-side arrays for repeated Algorithm-1 queries.

    Stacking every candidate run's metric vector / machine type / node
    count is O(repository) work; a multi-tenant ``SearchService`` runs
    Algorithm 1 once per tenant per iteration against the *same*
    repository snapshot, so the index is built once (and rebuilt only
    when the repository version moves) and each query pays only the
    pairwise-Pearson kernel plus a vectorised segment reduction."""

    def __init__(self, candidates: Dict[str, Sequence[RunRecord]]):
        cand_ids: List[str] = []
        cand_runs: List[RunRecord] = []
        for z_j, runs_j in candidates.items():
            for r in runs_j:
                if r.metrics is None:    # unusable without agg(l)
                    continue
                cand_ids.append(z_j)
                cand_runs.append(r)
        self.workload_ids: List[str] = list(candidates.keys())
        self.empty = not cand_runs
        if self.empty:
            return
        self._zindex = {z: i for i, z in enumerate(self.workload_ids)}
        self._seg = np.array([self._zindex[z] for z in cand_ids])
        self._metrics = jnp.asarray(
            np.stack([r.metric_vector() for r in cand_runs]))
        self._types = np.array([r.machine_type for r in cand_runs])
        self._log_nodes = np.log2(
            np.array([max(r.node_count, 1) for r in cand_runs]))

    def query(self, target_runs: Sequence[RunRecord], k: int, *,
              impl: str = "xla", default_score: float = 0.5,
              exclude: Optional[Sequence[str]] = None
              ) -> List[Tuple[str, float]]:
        """Top-k candidates; ``exclude`` drops workload ids before the
        cut (e.g. a tenant's own published runs — which would otherwise
        score ~1.0 against themselves and defeat the LOO safeguard)."""
        if self.empty or not target_runs:
            return []
        a = np.stack([r.metric_vector() for r in target_runs])
        corr = np.asarray(pairwise_pearson(jnp.asarray(a), self._metrics,
                                           impl=impl))
        sim = (corr + 1.0) / 2.0

        t_types = np.array([r.machine_type for r in target_runs])
        t_nodes = np.log2(np.array([max(r.node_count, 1)
                                    for r in target_runs]))
        w = np.exp2(-np.abs(t_nodes[:, None] - self._log_nodes[None, :]))
        same = t_types[:, None] == self._types[None, :]
        w = np.where(same, w, 0.0)
        sim = np.where(same, sim, default_score)

        nz = len(self.workload_ids)
        num = np.bincount(self._seg, weights=(w * sim).sum(0), minlength=nz)
        den = np.bincount(self._seg, weights=w.sum(0), minlength=nz)
        score = np.where(den > 0, num / np.maximum(den, 1e-300),
                         default_score)
        out = list(zip(self.workload_ids, score.tolist()))
        if exclude:
            banned = set(exclude)
            out = [t for t in out if t[0] not in banned]
        out.sort(key=lambda t: -t[1])
        return out[:k]


def select_similar_batched(
    target_runs: Sequence[RunRecord],
    candidates: Dict[str, Sequence[RunRecord]],
    k: int,
    *,
    impl: str = "xla",
    default_score: float = 0.5,
    index: Optional[CandidateIndex] = None,
) -> List[Tuple[str, float]]:
    """Vectorised Algorithm 1: one pairwise-Pearson kernel call between
    the target's runs and ALL candidate runs, then a weighted reduction.
    Semantics identical to select_similar. Pass a prebuilt
    ``CandidateIndex`` to amortise candidate stacking across queries."""
    if not target_runs or (index is None and not candidates):
        return []
    if index is None:
        index = CandidateIndex(candidates)
    return index.query(target_runs, k, impl=impl,
                       default_score=default_score)
