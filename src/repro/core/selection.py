"""Similarity-based data selection — the paper's Algorithm 1 (§III-C).

For a target workload z_i and candidate workloads z_j in the repository:
for every pair of runs (r_n of z_i, r_m of z_j) on the SAME machine type,
    weight = |log2 nodes(r_n) - log2 nodes(r_m)|
    DIST   -> (1 / 2^weight,  (pearsonr(metrics) + 1) / 2)
The candidate score is the scaling-factor-weighted average of the
similarity scores; candidates sorted descending, best k returned.

Two paths: the faithful pure-python loop (exactly Algorithm 1, used at
search-time sizes) and a vectorised batch path over the whole repository
using the ``pairwise_pearson`` kernel (the "proper distance operator" a
real deployment needs, §IV-E).
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.pairwise_pearson import pairwise_pearson
from .types import RunRecord


def dist(r_n: RunRecord, r_m: RunRecord) -> Tuple[float, float]:
    """The paper's DIST: (scaling factor, similarity score in [0,1])."""
    weight = abs(math.log2(max(r_n.node_count, 1))
                 - math.log2(max(r_m.node_count, 1)))
    a, b = r_n.metric_vector(), r_m.metric_vector()
    sa, sb = np.std(a), np.std(b)
    if sa < 1e-12 or sb < 1e-12:
        score = 0.0
    else:
        score = float(np.corrcoef(a, b)[0, 1])
    return 1.0 / (2.0 ** weight), (score + 1.0) / 2.0


def select_similar(
    target_runs: Sequence[RunRecord],
    candidates: Dict[str, Sequence[RunRecord]],
    k: int,
    *,
    default_score: float = 0.5,
) -> List[Tuple[str, float]]:
    """Algorithm 1, faithful loop. Returns the k best (workload_id, score)."""
    results: List[Tuple[str, float]] = []
    for z_j, runs_j in candidates.items():
        num, den = 0.0, 0.0
        for r_n in target_runs:
            for r_m in runs_j:
                if r_n.machine_type == r_m.machine_type:
                    w, s = dist(r_n, r_m)
                else:
                    w, s = 0.0, default_score  # default for unmatched types
                num += w * s
                den += w
        score = num / den if den > 0 else default_score
        results.append((z_j, score))
    results.sort(key=lambda t: -t[1])
    return results[:k]


def select_similar_batched(
    target_runs: Sequence[RunRecord],
    candidates: Dict[str, Sequence[RunRecord]],
    k: int,
    *,
    impl: str = "xla",
    default_score: float = 0.5,
) -> List[Tuple[str, float]]:
    """Vectorised Algorithm 1: one pairwise-Pearson kernel call between
    the target's runs and ALL candidate runs, then a weighted reduction.
    Semantics identical to select_similar."""
    if not target_runs or not candidates:
        return []
    cand_ids, cand_runs = [], []
    for z_j, runs_j in candidates.items():
        for r in runs_j:
            cand_ids.append(z_j)
            cand_runs.append(r)
    a = np.stack([r.metric_vector() for r in target_runs])
    b = np.stack([r.metric_vector() for r in cand_runs])
    corr = np.asarray(pairwise_pearson(jnp.asarray(a), jnp.asarray(b),
                                       impl=impl))
    sim = (corr + 1.0) / 2.0

    t_types = [r.machine_type for r in target_runs]
    c_types = [r.machine_type for r in cand_runs]
    t_nodes = np.array([max(r.node_count, 1) for r in target_runs])
    c_nodes = np.array([max(r.node_count, 1) for r in cand_runs])
    wexp = np.abs(np.log2(t_nodes)[:, None] - np.log2(c_nodes)[None, :])
    w = 1.0 / np.exp2(wexp)
    same = np.array([[tt == ct for ct in c_types] for tt in t_types])
    w = np.where(same, w, 0.0)
    sim = np.where(same, sim, default_score)

    scores: Dict[str, Tuple[float, float]] = defaultdict(lambda: (0.0, 0.0))
    for j, z_j in enumerate(cand_ids):
        num, den = scores[z_j]
        num += float(np.sum(w[:, j] * sim[:, j]))
        den += float(np.sum(w[:, j]))
        scores[z_j] = (num, den)
    out = [(z, (num / den if den > 0 else default_score))
           for z, (num, den) in scores.items()]
    out.sort(key=lambda t: -t[1])
    return out[:k]
