"""Extra-Trees regressor (numpy) — the prior function of the AugmentedBO
baseline (Arrow, Hsu et al. 2018).

Extremely-randomised trees: at each node, K candidate features each get
ONE uniformly-random split point; the best by variance reduction is kept.
Mean prediction per tree; the across-tree variance serves as the
uncertainty estimate for EI (Arrow under-specifies its acquisition — the
paper notes the original authors did not respond — so Karasu's authors,
and we, use EI on this mean/variance).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


def _build_tree(x: np.ndarray, y: np.ndarray, rng: np.random.Generator,
                k_features: int, min_samples: int, max_depth: int
                ) -> List[_Node]:
    nodes: List[_Node] = []

    def rec(idx: np.ndarray, depth: int) -> int:
        node_id = len(nodes)
        nodes.append(_Node(value=float(np.mean(y[idx]))))
        if (len(idx) < min_samples or depth >= max_depth
                or np.ptp(y[idx]) < 1e-12):
            return node_id
        feats = rng.choice(x.shape[1], size=min(k_features, x.shape[1]),
                           replace=False)
        best = None
        parent_var = np.var(y[idx]) * len(idx)
        for f in feats:
            lo, hi = x[idx, f].min(), x[idx, f].max()
            if hi <= lo:
                continue
            thr = rng.uniform(lo, hi)
            mask = x[idx, f] <= thr
            nl, nr = mask.sum(), (~mask).sum()
            if nl == 0 or nr == 0:
                continue
            score = parent_var - (np.var(y[idx[mask]]) * nl
                                  + np.var(y[idx[~mask]]) * nr)
            if best is None or score > best[0]:
                best = (score, f, thr, mask)
        if best is None:
            return node_id
        _, f, thr, mask = best
        left = rec(idx[mask], depth + 1)
        right = rec(idx[~mask], depth + 1)
        nodes[node_id].feature = int(f)
        nodes[node_id].threshold = float(thr)
        nodes[node_id].left = left
        nodes[node_id].right = right
        return node_id

    rec(np.arange(len(y)), 0)
    return nodes


def _predict_tree(nodes: List[_Node], x: np.ndarray) -> np.ndarray:
    out = np.empty(len(x))
    for i, row in enumerate(x):
        n = 0
        while nodes[n].feature >= 0:
            n = nodes[n].left if row[nodes[n].feature] <= nodes[n].threshold \
                else nodes[n].right
        out[i] = nodes[n].value
    return out


@dataclasses.dataclass
class ExtraTrees:
    trees: List[List[_Node]]
    y_mean: float
    y_std: float

    def posterior(self, x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        preds = np.stack([_predict_tree(t, x) for t in self.trees])
        mu = preds.mean(0)
        var = preds.var(0) + 1e-6
        return mu, var


def fit_extra_trees(x: np.ndarray, y: np.ndarray, *, n_trees: int = 50,
                    k_features: Optional[int] = None, min_samples: int = 2,
                    max_depth: int = 12, seed: int = 0) -> ExtraTrees:
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    y_mean, y_std = float(np.mean(y)), float(max(np.std(y), 1e-9))
    ys = (y - y_mean) / y_std
    k = k_features or max(1, x.shape[1])
    rng = np.random.default_rng(seed)
    trees = [_build_tree(x, ys, rng, k, min_samples, max_depth)
             for _ in range(n_trees)]
    return ExtraTrees(trees, y_mean, y_std)
