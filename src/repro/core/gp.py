"""Gaussian-process regression in pure JAX (Matern-5/2 + ARD).

The building block of both baselines and Karasu: CherryPick's NaiveBO is
exactly this GP + EI; Karasu fits one per workload per objective and
ensembles them with RGPE.

Targets are standardised internally (zero mean / unit variance over the
model's own observations) — the property RGPE relies on: predictions from
different workloads become comparable in *rank* without sharing scales.
Observation noise defaults to sigma^2 = 0.1 on the standardised scale, as
assumed in the paper's evaluation (§IV-B); kernel hyperparameters are fit
by Adam on the exact negative log marginal likelihood.

Hot spot at repository scale: the kernel matrix. ``repro.kernels.matern``
provides the Pallas-tiled pairwise Matern-5/2 kernel; this module calls
through ``matern52`` which dispatches on size/impl.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.matern import matern52

JITTER = 1e-6


@dataclasses.dataclass(frozen=True)
class GPParams:
    log_lengthscales: jnp.ndarray  # (d,)
    log_signal: jnp.ndarray        # ()
    noise: float                   # fixed observation noise variance


@dataclasses.dataclass(frozen=True)
class GP:
    x: jnp.ndarray                 # (n, d) encoded configs
    y_raw: jnp.ndarray             # (n,) original-scale targets
    y: jnp.ndarray                 # (n,) standardised targets
    y_mean: jnp.ndarray
    y_std: jnp.ndarray
    params: GPParams
    chol: jnp.ndarray              # (n, n) cholesky of K + noise I
    alpha: jnp.ndarray             # (n,) K^{-1} y

    @property
    def n(self) -> int:
        return int(self.x.shape[0])


def _kernel(params: GPParams, a: jnp.ndarray, b: jnp.ndarray,
            impl: str = "xla") -> jnp.ndarray:
    ls = jnp.exp(params.log_lengthscales)
    sf = jnp.exp(params.log_signal)
    return sf * matern52(a / ls, b / ls, impl=impl)


def _nlml(params: GPParams, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    n = x.shape[0]
    k = _kernel(params, x, x) + (params.noise + JITTER) * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (0.5 * y @ alpha
            + jnp.sum(jnp.log(jnp.diagonal(chol)))
            + 0.5 * n * jnp.log(2.0 * jnp.pi))


@partial(jax.jit, static_argnames=("steps", "noise"))
def _fit(x, y, key, steps: int = 120, noise: float = 0.1,
         lr: float = 0.05):
    d = x.shape[1]
    p0 = {"ls": jnp.zeros((d,)), "sf": jnp.zeros(())}

    def loss(p):
        return _nlml(GPParams(p["ls"], p["sf"], noise), x, y)

    grad = jax.grad(loss)
    # Adam
    mu0 = jax.tree.map(jnp.zeros_like, p0)
    nu0 = jax.tree.map(jnp.zeros_like, p0)

    def body(carry, i):
        p, mu, nu = carry
        g = grad(p)
        mu = jax.tree.map(lambda m, gg: 0.9 * m + 0.1 * gg, mu, g)
        nu = jax.tree.map(lambda v, gg: 0.999 * v + 0.001 * gg * gg, nu, g)
        t = i.astype(jnp.float32) + 1.0
        def upd(pp, m, v):
            mh = m / (1 - 0.9 ** t)
            vh = v / (1 - 0.999 ** t)
            return pp - lr * mh / (jnp.sqrt(vh) + 1e-8)
        p = jax.tree.map(upd, p, mu, nu)
        p = {"ls": jnp.clip(p["ls"], -3.0, 3.0),
             "sf": jnp.clip(p["sf"], -3.0, 3.0)}
        return (p, mu, nu), None

    (p, _, _), _ = jax.lax.scan(body, (p0, mu0, nu0), jnp.arange(steps))
    return p


def fit_gp(x: np.ndarray, y: np.ndarray, *, noise: float = 0.1,
           steps: int = 120, key: Optional[jax.Array] = None) -> GP:
    x = jnp.asarray(x, jnp.float32)
    y_raw = jnp.asarray(y, jnp.float32)
    y_mean = jnp.mean(y_raw)
    y_std = jnp.maximum(jnp.std(y_raw), 1e-8)
    ys = (y_raw - y_mean) / y_std
    key = key if key is not None else jax.random.PRNGKey(0)
    p = _fit(x, ys, key, steps=steps, noise=noise)
    params = GPParams(p["ls"], p["sf"], noise)
    n = x.shape[0]
    k = _kernel(params, x, x) + (noise + JITTER) * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), ys)
    return GP(x, y_raw, ys, y_mean, y_std, params, chol, alpha)


def gp_posterior(gp: GP, xq: jnp.ndarray,
                 impl: str = "xla") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior mean/variance on the standardised scale. xq: (m, d)."""
    xq = jnp.asarray(xq, jnp.float32)
    ks = _kernel(gp.params, xq, gp.x, impl=impl)        # (m, n)
    mu = ks @ gp.alpha
    v = jax.scipy.linalg.solve_triangular(gp.chol, ks.T, lower=True)
    kss = jnp.exp(gp.params.log_signal)                  # diag of k(x,x)
    var = jnp.maximum(kss - jnp.sum(v * v, axis=0), 1e-10)
    return mu, var


def gp_posterior_raw(gp: GP, xq) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior on the original target scale."""
    mu, var = gp_posterior(gp, xq)
    return mu * gp.y_std + gp.y_mean, var * gp.y_std ** 2


def gp_sample(gp: GP, xq: jnp.ndarray, key: jax.Array,
              n_samples: int) -> jnp.ndarray:
    """Draw (n_samples, m) from the marginal posterior (independent per
    point, as used by RGPE's ranking-loss sampling)."""
    mu, var = gp_posterior(gp, xq)
    eps = jax.random.normal(key, (n_samples, mu.shape[0]))
    return mu[None] + eps * jnp.sqrt(var)[None]


def gp_loo_samples(gp: GP, key: jax.Array, n_samples: int) -> jnp.ndarray:
    """Leave-one-out posterior samples at the GP's own inputs — used for
    the target model inside RGPE so it does not trivially win on its own
    training points. Closed-form LOO from the full Cholesky."""
    n = gp.n
    kinv = jax.scipy.linalg.cho_solve((gp.chol, True), jnp.eye(n))
    kinv_diag = jnp.diagonal(kinv)
    mu_loo = gp.y - gp.alpha / kinv_diag
    var_loo = jnp.maximum(1.0 / kinv_diag, 1e-10)
    eps = jax.random.normal(key, (n_samples, n))
    return mu_loo[None] + eps * jnp.sqrt(var_loo)[None]
