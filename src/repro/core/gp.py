"""Gaussian-process regression in pure JAX (Matern-5/2 + ARD).

The building block of both baselines and Karasu: CherryPick's NaiveBO is
exactly this GP + EI; Karasu fits one per workload per objective and
ensembles them with RGPE.

Targets are standardised internally (zero mean / unit variance over the
model's own observations) — the property RGPE relies on: predictions from
different workloads become comparable in *rank* without sharing scales.
Observation noise defaults to sigma^2 = 0.1 on the standardised scale, as
assumed in the paper's evaluation (§IV-B); kernel hyperparameters are fit
by Adam on the exact negative log marginal likelihood.

Hot spot at repository scale: the kernel matrix. ``repro.kernels.matern``
provides the Pallas-tiled pairwise Matern-5/2 kernel; this module calls
through ``matern52`` which dispatches on size/impl.

Two representations live here:

  - ``GP``        — one model, exact shapes. The reference implementation.
  - ``BatchedGP`` — m models stacked into padded ``(m, n_max, d)`` arrays
    with a validity mask, fit and queried through ``vmap`` so that all
    measures of one search, all support models of one ensemble, and all
    tenants of a ``SearchService`` round share a single batched Cholesky
    instead of a Python loop. Padding is exact: padded rows/columns are
    masked out of the kernel and carry unit diagonal entries, so the
    valid block of every factorisation equals the unbatched one.

On top of ``BatchedGP`` sits the posterior **query plan**
(``batched_posterior_multi``): many stacks' grid queries — target GPs,
RGPE support stacks, MOO models, across tenants — fused into one padded
launch per (grid, dim) bucket, with ``impl="auto"`` routing the pairwise
Matern to the Pallas kernel when the fused batch justifies it.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.matern import matern52
from repro.kernels.routing import resolve_impl

JITTER = 1e-6


@dataclasses.dataclass(frozen=True)
class GPParams:
    log_lengthscales: jnp.ndarray  # (d,)
    log_signal: jnp.ndarray        # ()
    noise: float                   # fixed observation noise variance


@dataclasses.dataclass(frozen=True)
class GP:
    x: jnp.ndarray                 # (n, d) encoded configs
    y_raw: jnp.ndarray             # (n,) original-scale targets
    y: jnp.ndarray                 # (n,) standardised targets
    y_mean: jnp.ndarray
    y_std: jnp.ndarray
    params: GPParams
    chol: jnp.ndarray              # (n, n) cholesky of K + noise I
    alpha: jnp.ndarray             # (n,) K^{-1} y

    @property
    def n(self) -> int:
        return int(self.x.shape[0])


def _kernel(params: GPParams, a: jnp.ndarray, b: jnp.ndarray,
            impl: str = "xla") -> jnp.ndarray:
    ls = jnp.exp(params.log_lengthscales)
    sf = jnp.exp(params.log_signal)
    return sf * matern52(a / ls, b / ls, impl=impl)


def _nlml(params: GPParams, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    n = x.shape[0]
    k = _kernel(params, x, x) + (params.noise + JITTER) * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (0.5 * y @ alpha
            + jnp.sum(jnp.log(jnp.diagonal(chol)))
            + 0.5 * n * jnp.log(2.0 * jnp.pi))


def _adam_nlml(loss, d: int, steps: int, lr: float):
    """Shared Adam-on-NLML driver for both the single and batched fits —
    identical update rule so batched fits reproduce unbatched ones."""
    p0 = {"ls": jnp.zeros((d,)), "sf": jnp.zeros(())}
    grad = jax.grad(loss)
    mu0 = jax.tree.map(jnp.zeros_like, p0)
    nu0 = jax.tree.map(jnp.zeros_like, p0)

    def body(carry, i):
        p, mu, nu = carry
        g = grad(p)
        mu = jax.tree.map(lambda m, gg: 0.9 * m + 0.1 * gg, mu, g)
        nu = jax.tree.map(lambda v, gg: 0.999 * v + 0.001 * gg * gg, nu, g)
        t = i.astype(jnp.float32) + 1.0
        def upd(pp, m, v):
            mh = m / (1 - 0.9 ** t)
            vh = v / (1 - 0.999 ** t)
            return pp - lr * mh / (jnp.sqrt(vh) + 1e-8)
        p = jax.tree.map(upd, p, mu, nu)
        p = {"ls": jnp.clip(p["ls"], -3.0, 3.0),
             "sf": jnp.clip(p["sf"], -3.0, 3.0)}
        return (p, mu, nu), None

    (p, _, _), _ = jax.lax.scan(body, (p0, mu0, nu0), jnp.arange(steps))
    return p


@partial(jax.jit, static_argnames=("steps", "noise"))
def _fit(x, y, key, steps: int = 120, noise: float = 0.1,
         lr: float = 0.05):
    d = x.shape[1]

    def loss(p):
        return _nlml(GPParams(p["ls"], p["sf"], noise), x, y)

    return _adam_nlml(loss, d, steps, lr)


def fit_gp(x: np.ndarray, y: np.ndarray, *, noise: float = 0.1,
           steps: int = 120, key: Optional[jax.Array] = None) -> GP:
    x = jnp.asarray(x, jnp.float32)
    y_raw = jnp.asarray(y, jnp.float32)
    y_mean = jnp.mean(y_raw)
    y_std = jnp.maximum(jnp.std(y_raw), 1e-8)
    ys = (y_raw - y_mean) / y_std
    key = key if key is not None else jax.random.PRNGKey(0)
    p = _fit(x, ys, key, steps=steps, noise=noise)
    params = GPParams(p["ls"], p["sf"], noise)
    n = x.shape[0]
    k = _kernel(params, x, x) + (noise + JITTER) * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), ys)
    return GP(x, y_raw, ys, y_mean, y_std, params, chol, alpha)


def gp_posterior(gp: GP, xq: jnp.ndarray,
                 impl: str = "xla") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior mean/variance on the standardised scale. xq: (m, d)."""
    xq = jnp.asarray(xq, jnp.float32)
    ks = _kernel(gp.params, xq, gp.x, impl=impl)        # (m, n)
    mu = ks @ gp.alpha
    v = jax.scipy.linalg.solve_triangular(gp.chol, ks.T, lower=True)
    kss = jnp.exp(gp.params.log_signal)                  # diag of k(x,x)
    var = jnp.maximum(kss - jnp.sum(v * v, axis=0), 1e-10)
    return mu, var


def gp_posterior_raw(gp: GP, xq) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior on the original target scale."""
    mu, var = gp_posterior(gp, xq)
    return mu * gp.y_std + gp.y_mean, var * gp.y_std ** 2


def gp_sample(gp: GP, xq: jnp.ndarray, key: jax.Array,
              n_samples: int) -> jnp.ndarray:
    """Draw (n_samples, m) from the marginal posterior (independent per
    point, as used by RGPE's ranking-loss sampling)."""
    mu, var = gp_posterior(gp, xq)
    eps = jax.random.normal(key, (n_samples, mu.shape[0]))
    return mu[None] + eps * jnp.sqrt(var)[None]


def gp_loo_samples(gp: GP, key: jax.Array, n_samples: int) -> jnp.ndarray:
    """Leave-one-out posterior samples at the GP's own inputs — used for
    the target model inside RGPE so it does not trivially win on its own
    training points. Closed-form LOO from the full Cholesky."""
    n = gp.n
    kinv = jax.scipy.linalg.cho_solve((gp.chol, True), jnp.eye(n))
    kinv_diag = jnp.diagonal(kinv)
    mu_loo = gp.y - gp.alpha / kinv_diag
    var_loo = jnp.maximum(1.0 / kinv_diag, 1e-10)
    eps = jax.random.normal(key, (n_samples, n))
    return mu_loo[None] + eps * jnp.sqrt(var_loo)[None]


# ---------------------------------------------------------------------------
# BatchedGP: m models in padded (m, n_max, d) arrays, vmapped throughout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BatchedGP:
    """m stacked GPs. Padded entries are masked out of every kernel and
    carry a unit diagonal, so each model's valid block matches its
    unbatched counterpart exactly."""
    x: jnp.ndarray                 # (m, n_max, d), zero-padded
    y: jnp.ndarray                 # (m, n_max) standardised, zero-padded
    mask: jnp.ndarray              # (m, n_max) 1.0 valid / 0.0 pad
    y_mean: jnp.ndarray            # (m,)
    y_std: jnp.ndarray             # (m,)
    log_lengthscales: jnp.ndarray  # (m, d)
    log_signal: jnp.ndarray        # (m,)
    noise: float
    chol: jnp.ndarray              # (m, n_max, n_max)
    alpha: jnp.ndarray             # (m, n_max)
    counts: jnp.ndarray            # (m,) int32 valid observations

    @property
    def m(self) -> int:
        return int(self.x.shape[0])

    @property
    def n_max(self) -> int:
        return int(self.x.shape[1])

    def extract(self, i: int) -> GP:
        """Materialise model i as an unbatched GP (exact un-padding)."""
        n = int(self.counts[i])
        params = GPParams(self.log_lengthscales[i], self.log_signal[i],
                          self.noise)
        ys = self.y[i, :n]
        return GP(self.x[i, :n], ys * self.y_std[i] + self.y_mean[i], ys,
                  self.y_mean[i], self.y_std[i], params,
                  self.chol[i, :n, :n], self.alpha[i, :n])


def _masked_nlml(params: GPParams, x: jnp.ndarray, y: jnp.ndarray,
                 mask: jnp.ndarray) -> jnp.ndarray:
    """NLML over the valid block only. Padded rows/cols contribute a
    parameter-independent constant, so gradients equal the unmasked
    ``_nlml`` on the valid data."""
    n_max = x.shape[0]
    k = _kernel(params, x, x) * (mask[:, None] * mask[None, :])
    k = k + (params.noise + JITTER) * jnp.eye(n_max) + jnp.diag(1.0 - mask)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    n = jnp.sum(mask)
    return (0.5 * y @ alpha
            + jnp.sum(jnp.log(jnp.diagonal(chol)) * mask)
            + 0.5 * n * jnp.log(2.0 * jnp.pi))


@partial(jax.jit, static_argnames=("steps", "noise"))
def _fit_batched(x, y, mask, steps: int = 120, noise: float = 0.1,
                 lr: float = 0.05):
    d = x.shape[-1]

    def one(xi, yi, mi):
        def loss(p):
            return _masked_nlml(GPParams(p["ls"], p["sf"], noise),
                                xi, yi, mi)
        return _adam_nlml(loss, d, steps, lr)

    return jax.vmap(one)(x, y, mask)


@partial(jax.jit, static_argnames=("noise",))
def _batched_chol_alpha(log_ls, log_sf, x, y, mask, noise: float):
    def one(ls, sf, xi, yi, mi):
        n_max = xi.shape[0]
        params = GPParams(ls, sf, noise)
        k = _kernel(params, xi, xi) * (mi[:, None] * mi[None, :])
        k = k + (noise + JITTER) * jnp.eye(n_max) + jnp.diag(1.0 - mi)
        chol = jnp.linalg.cholesky(k)
        alpha = jax.scipy.linalg.cho_solve((chol, True), yi)
        return chol, alpha

    return jax.vmap(one)(log_ls, log_sf, x, y, mask)


def _pack_fit_lanes(xs, ys, ns, nm: int):
    """Host-side lane packing + vectorised target standardisation.

    Packs ragged ``(x_i, y_i)`` models into padded ``(m, nm, d)`` /
    ``(m, nm)`` float32 arrays with a validity mask and standardises
    every lane's targets in one shot: per-lane mean/std are accumulated
    in float64 over the masked rows (padding is exact — pad entries are
    zero and excluded by count), then cast to float32 for the same
    ``(y - mu) / sd`` the per-lane path applied. This replaces the old
    per-model ``jnp.mean``/``jnp.std`` loop, which paid m blocking
    device round-trips per fit call; values shift by at most ~1 ulp
    (f64 vs f32 accumulation order), within every consumer's tolerance.
    Shared by ``fit_gp_batched`` and the plan executor's fit leg, so
    both launches see bitwise-identical packing."""
    m = len(xs)
    d = int(np.shape(xs[0])[1])
    x = np.zeros((m, nm, d), np.float32)
    yr = np.zeros((m, nm), np.float32)
    mask = np.zeros((m, nm), np.float32)
    for i, (xi, yi) in enumerate(zip(xs, ys)):
        n = ns[i]
        x[i, :n] = np.asarray(xi, np.float32)
        yr[i, :n] = np.asarray(yi, np.float32)
        mask[i, :n] = 1.0
    cnt = np.asarray(ns, np.float64)
    mu = yr.sum(axis=1, dtype=np.float64) / cnt
    sq = ((yr - mu[:, None]) * mask) ** 2
    sd = np.maximum(np.sqrt(sq.sum(axis=1, dtype=np.float64) / cnt), 1e-8)
    y_mean = mu.astype(np.float32)
    y_std = sd.astype(np.float32)
    ysd = ((yr - y_mean[:, None]) / y_std[:, None]) * mask
    return x, ysd, mask, y_mean, y_std


def fit_gp_batched(xs: Sequence[np.ndarray], ys: Sequence[np.ndarray], *,
                   noise: float = 0.1, steps: int = 120,
                   n_max: Optional[int] = None, round_to: int = 1,
                   m_round_pow2: bool = False, lane_round_to: int = 1,
                   launches=None) -> BatchedGP:
    """Fit m GPs in one vmapped Adam/Cholesky pass.

    ``xs[i]``: (n_i, d), ``ys[i]``: (n_i,). All models must share d (and
    the fixed noise); n_i may differ — shorter models are zero-padded to
    ``n_max``. ``round_to`` rounds the pad length up to a multiple so jit
    shapes stay stable while a search's observation count grows (padding
    never changes results — masked rows carry unit diagonals).

    ``m_round_pow2`` pads the MODEL dimension to the next power of two by
    repeating model 0; models ``>= m`` are throwaway lanes. Callers whose
    cohort size varies step to step (an async ``SearchService``, where
    whichever sessions' profiling runs landed form the batch) use this so
    the vmapped fit compiles once per bucket instead of once per cohort
    size. Real models' results are unaffected: vmap lanes are
    independent.

    ``lane_round_to`` additionally rounds the model dimension up to a
    multiple (applied after the pow2 rounding) so the lane axis divides a
    ``shard_map`` mesh evenly; ``launches`` optionally substitutes a
    ``(fit, chol_alpha)`` pair of launch twins for the default jitted
    ones — ``sharded_fit_launches`` builds the shard-mapped pair."""
    m = len(xs)
    if m == 0 or m != len(ys):
        raise ValueError("fit_gp_batched needs >=1 model and len(xs)==len(ys)")
    if m_round_pow2:
        target = 1 << (m - 1).bit_length()
        xs = list(xs) + [xs[0]] * (target - m)
        ys = list(ys) + [ys[0]] * (target - m)
        m = target
    if lane_round_to > 1 and m % lane_round_to:
        target = ((m + lane_round_to - 1) // lane_round_to) * lane_round_to
        xs = list(xs) + [xs[0]] * (target - m)
        ys = list(ys) + [ys[0]] * (target - m)
        m = target
    d = int(np.shape(xs[0])[1])
    ns = [int(np.shape(y)[0]) for y in ys]
    nm = max(ns) if n_max is None else int(n_max)
    if nm < max(ns):
        raise ValueError(f"n_max={nm} < largest model ({max(ns)})")
    if round_to > 1:
        nm = ((nm + round_to - 1) // round_to) * round_to

    x, ysd, mask, y_mean, y_std = _pack_fit_lanes(xs, ys, ns, nm)

    xj = jnp.asarray(x)
    yj = jnp.asarray(ysd)
    mj = jnp.asarray(mask)
    fit_fn, ca_fn = ((_fit_batched, _batched_chol_alpha)
                     if launches is None else launches)
    p = fit_fn(xj, yj, mj, steps=steps, noise=noise)
    chol, alpha = ca_fn(p["ls"], p["sf"], xj, yj, mj, noise)
    return BatchedGP(xj, yj, mj, jnp.asarray(y_mean), jnp.asarray(y_std),
                     p["ls"], p["sf"], noise, chol, alpha,
                     jnp.asarray(ns, jnp.int32))


# ---------------------------------------------------------------------------
# Shard-mapped fit twins: the vmapped Adam fit + Cholesky refresh split
# over a mesh's data axis (lanes are independent models, so data-parallel
# splitting is exact). Minted once per (mesh, axis) and registered with
# ``launch.compile_stats`` so the compile-once accounting covers them.
# ---------------------------------------------------------------------------

_SHARDED_FIT: dict = {}


def sharded_fit_launches(mesh, axis: str = "data"):
    """``(fit, chol_alpha)`` launch twins of ``_fit_batched`` /
    ``_batched_chol_alpha`` running under ``shard_map`` over ``axis``.

    Per-lane math is untouched — each device fits its slice of the model
    stack with the same vmapped program, so results match the unsharded
    launch up to float roundoff (XLA fuses the per-shard batch size
    differently, nothing more). ``lr`` is lifted to a static argname:
    ``shard_map`` bodies cannot close over tracers, and the fit's
    learning rate is a config constant, never a traced value."""
    key = (mesh, axis)
    hit = _SHARDED_FIT.get(key)
    if hit is not None:
        return hit
    from jax.sharding import PartitionSpec

    from repro.distributed import mesh_axis_size, shard_map
    from repro.launch.compile_stats import register_launch
    spec = PartitionSpec(axis)

    @partial(jax.jit, static_argnames=("steps", "noise", "lr"))
    def fit(x, y, mask, steps: int = 120, noise: float = 0.1,
            lr: float = 0.05):
        body = partial(_fit_batched.__wrapped__, steps=steps, noise=noise,
                       lr=lr)
        return shard_map(body, mesh, in_specs=(spec,) * 3, out_specs=spec,
                         check_vma=False)(x, y, mask)

    @partial(jax.jit, static_argnames=("noise",))
    def chol_alpha(log_ls, log_sf, x, y, mask, noise: float):
        body = partial(_batched_chol_alpha.__wrapped__, noise=noise)
        return shard_map(body, mesh, in_specs=(spec,) * 5, out_specs=spec,
                         check_vma=False)(log_ls, log_sf, x, y, mask)

    size = mesh_axis_size(mesh, axis)
    register_launch(f"fit_sharded_x{size}_{len(_SHARDED_FIT)}", fit)
    register_launch(f"chol_alpha_sharded_x{size}_{len(_SHARDED_FIT)}",
                    chol_alpha)
    pair = (fit, chol_alpha)
    _SHARDED_FIT[key] = pair
    return pair


def stack_gps(gps: Sequence[GP], n_max: Optional[int] = None, *,
              round_to: int = 1) -> BatchedGP:
    """Stack already-fitted GPs into a BatchedGP without refitting — the
    padded Cholesky is assembled block-diagonally from each model's own
    factor, so posteriors are bit-identical to the unbatched ones.
    ``round_to`` rounds the padded length up to a multiple (same
    jit-shape bucketing as ``fit_gp_batched``), so stacks built at
    different data sizes land on shared query-plan pad shapes."""
    if not gps:
        raise ValueError("stack_gps needs >=1 model")
    d = int(gps[0].x.shape[1])
    noise = float(gps[0].params.noise)
    ns = [g.n for g in gps]
    nm = max(ns) if n_max is None else int(n_max)
    if round_to > 1:
        nm = ((nm + round_to - 1) // round_to) * round_to
    m = len(gps)

    x = np.zeros((m, nm, d), np.float32)
    y = np.zeros((m, nm), np.float32)
    mask = np.zeros((m, nm), np.float32)
    chol = np.zeros((m, nm, nm), np.float32)
    alpha = np.zeros((m, nm), np.float32)
    ls = np.zeros((m, d), np.float32)
    sf = np.zeros((m,), np.float32)
    y_mean = np.zeros((m,), np.float32)
    y_std = np.zeros((m,), np.float32)
    pad_diag = float(np.sqrt(1.0 + noise + JITTER))
    for i, g in enumerate(gps):
        n = ns[i]
        x[i, :n] = np.asarray(g.x)
        y[i, :n] = np.asarray(g.y)
        mask[i, :n] = 1.0
        chol[i, :n, :n] = np.asarray(g.chol)
        for j in range(n, nm):
            chol[i, j, j] = pad_diag
        alpha[i, :n] = np.asarray(g.alpha)
        ls[i] = np.asarray(g.params.log_lengthscales)
        sf[i] = np.asarray(g.params.log_signal)
        y_mean[i] = float(g.y_mean)
        y_std[i] = float(g.y_std)
    return BatchedGP(jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask),
                     jnp.asarray(y_mean), jnp.asarray(y_std),
                     jnp.asarray(ls), jnp.asarray(sf), noise,
                     jnp.asarray(chol), jnp.asarray(alpha),
                     jnp.asarray(ns, jnp.int32))


@partial(jax.jit, static_argnames=("impl",))
def _batched_posterior(log_ls, log_sf, x, mask, chol, alpha, xq,
                       impl: str = "xla"):
    def one(ls, sf, xi, mi, ci, ai, xqi):
        params = GPParams(ls, sf, 0.0)
        ks = _kernel(params, xqi, xi, impl=impl) * mi[None, :]  # (q, n_max)
        mu = ks @ ai
        v = jax.scipy.linalg.solve_triangular(ci, ks.T, lower=True)
        var = jnp.maximum(jnp.exp(sf) - jnp.sum(v * v, axis=0), 1e-10)
        return mu, var

    return jax.vmap(one)(log_ls, log_sf, x, mask, chol, alpha, xq)


# Donating twin: the plan executor rebuilds the stacked observation-
# cache buffers (x, mask, chol, alpha, grid) every step, so on backends
# where the executor pins donation they are handed back to XLA for the
# solve intermediates. Hyperparameter rows stay un-donated (tiny, and
# shared with the watcher's bucket accounting).
_batched_posterior_donated = jax.jit(
    _batched_posterior.__wrapped__, static_argnames=("impl",),
    donate_argnums=(2, 3, 4, 5, 6))


def batched_posterior(bgp: BatchedGP, xq: jnp.ndarray, *, impl: str = "xla"
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior mean/variance of every model, standardised scale.

    xq: (q, d) shared across models, or (m, q, d) per-model. Returns
    ((m, q), (m, q)). One vmapped triangular solve instead of m calls;
    ``impl`` dispatches the pairwise Matern to Pallas where it wins
    (``"auto"`` resolves on the fused models x grid x obs cell count)."""
    xq = jnp.asarray(xq, jnp.float32)
    if xq.ndim == 2:
        xq = jnp.broadcast_to(xq[None], (bgp.m,) + xq.shape)
    impl = resolve_impl(impl, cells=bgp.m * xq.shape[1] * bgp.n_max)
    return _batched_posterior(bgp.log_lengthscales, bgp.log_signal, bgp.x,
                              bgp.mask, bgp.chol, bgp.alpha, xq, impl=impl)


# ---------------------------------------------------------------------------
# Posterior query plan: MANY stacks' grid posteriors in one padded launch
# ---------------------------------------------------------------------------


def _pad_stack_obs(st: BatchedGP, n_pad: int):
    """Pad one stack's observation axis to ``n_pad``: zero rows masked
    out of the kernel, unit diagonal on the padded Cholesky block — the
    same exactness contract ``fit_gp_batched``/``stack_gps`` already
    guarantee, so fused results match per-stack ones."""
    p = n_pad - st.n_max
    if p == 0:
        return st.x, st.mask, st.chol, st.alpha
    x = jnp.pad(st.x, ((0, 0), (0, p), (0, 0)))
    mask = jnp.pad(st.mask, ((0, 0), (0, p)))
    chol = jnp.pad(st.chol, ((0, 0), (0, p), (0, p)))
    bump = jnp.concatenate([jnp.zeros((st.n_max,), jnp.float32),
                            jnp.ones((p,), jnp.float32)])
    chol = chol + jnp.diag(bump)[None]
    alpha = jnp.pad(st.alpha, ((0, 0), (0, p)))
    return x, mask, chol, alpha


def batched_posterior_multi(
    queries, *,
    impl: str = "auto", round_to: Optional[int] = None,
    m_round_pow2: Optional[bool] = None,
    counters: Optional[dict] = None,
) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Execute MANY ``(stack, grid)`` posterior queries as ONE padded
    ``_batched_posterior`` launch per (q, d) bucket.

    Thin wrapper over the query-plan layer (``core.plan``): each tuple
    becomes a ``PosteriorQuery`` node and the ``StepPlanner`` /
    ``PlanExecutor`` own all bucketing and padding — target GPs, every
    RGPE ensemble's support stack, and MOO objective/constraint models
    become lanes of the same vmapped triangular solve instead of
    separate Python-loop launches. ``round_to`` / ``m_round_pow2``
    default to the planner's policy (observation axis to multiples of
    8, fused model axis to a power of two).

    Returns one ``(mu, var)`` pair per query, shapes ``(m_i, q)``, in
    input order. ``counters`` (optional dict) is incremented with
    ``launches`` / ``queries`` / ``lanes`` for callers tracking fusion.
    """
    from .plan import (PlanExecutor, PosteriorQuery, StepPlanner,
                       flatten_counters)
    planner = StepPlanner(obs_round_to=round_to, m_round_pow2=m_round_pow2)
    nested: dict = {}
    results = PlanExecutor(impl=impl).execute(
        planner.plan([PosteriorQuery(st, xq) for st, xq in queries]),
        counters=nested)
    flatten_counters(nested, counters, ("posterior",))
    return results


def batched_sample(bgp: BatchedGP, xq: jnp.ndarray, keys: jax.Array,
                   n_samples: int, *, impl: str = "xla") -> jnp.ndarray:
    """(m, n_samples, q) marginal-posterior draws; ``keys`` is one PRNG
    key per model (so draws match per-model ``gp_sample`` exactly)."""
    mu, var = batched_posterior(bgp, xq, impl=impl)
    q = mu.shape[1]
    eps = jax.vmap(lambda k: jax.random.normal(k, (n_samples, q)))(keys)
    return mu[:, None, :] + eps * jnp.sqrt(var)[:, None, :]


# ---------------------------------------------------------------------------
# Sample query plan: MANY stacks' posterior draws in one padded launch
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("impl",))
def _batched_sample_launch(log_ls, log_sf, x, mask, chol, alpha, xq, eps,
                           impl: str = "xla"):
    """Posterior + affine draw combine for all fused lanes in one
    launch. ``eps`` carries the per-lane N(0,1) draws, generated OUTSIDE
    at each query's exact (S, q) shape and zero-padded on the grid axis
    here — so the grid padding that keeps this program's jit shapes
    stable across steps can never perturb a lane's PRNG stream."""
    mu, var = _batched_posterior(log_ls, log_sf, x, mask, chol, alpha, xq,
                                 impl=impl)
    return mu[:, None, :] + eps * jnp.sqrt(var)[:, None, :]


# Donating twin of the sample launch: same buffers as the posterior
# twin plus the per-step eps tensor (drawn fresh each round, never
# session-cached, so always safe to hand back).
_batched_sample_launch_donated = jax.jit(
    _batched_sample_launch.__wrapped__, static_argnames=("impl",),
    donate_argnums=(2, 3, 4, 5, 6, 7))


def batched_sample_multi(
    queries, *,
    impl: str = "auto", round_to: Optional[int] = None,
    q_round_to: Optional[int] = None,
    m_round_pow2: Optional[bool] = None,
    counters: Optional[dict] = None,
) -> List[jnp.ndarray]:
    """Execute MANY ``(stack, grid, keys, n_samples)`` posterior-sample
    draws as ONE padded ``_batched_sample_launch`` per (S, q, d) bucket.

    The sample-side twin of ``batched_posterior_multi`` and likewise a
    thin wrapper over the query-plan layer (each tuple becomes a
    ``SampleQuery`` node; all bucketing/padding policy lives in
    ``core.plan.StepPlanner``). Exact-padding contract: the observation
    axis pads to a ``round_to`` bucket (masked rows, unit Cholesky
    diagonal), the GRID axis to a ``q_round_to`` bucket (edge-repeated
    rows whose draws are sliced off — posterior columns are
    independent, so real columns are untouched), and the fused model
    axis to a power of two by repeating lane 0 (throwaway lanes). Draw
    streams are untouched by fusion OR padding: lane i consumes
    ``normal(keys[i], (S, q))`` at the exact query shape, just as
    ``batched_sample`` does.

    Returns one ``(m_i, n_samples, q)`` array per query, in input order.
    ``counters`` (optional dict) is incremented with ``launches`` /
    ``queries`` / ``lanes`` for callers tracking fusion.
    """
    from .plan import (PlanExecutor, SampleQuery, StepPlanner,
                       flatten_counters)
    planner = StepPlanner(obs_round_to=round_to, q_round_to=q_round_to,
                          m_round_pow2=m_round_pow2)
    nested: dict = {}
    results = PlanExecutor(impl=impl).execute(
        planner.plan([SampleQuery(st, xq, keys, ns)
                      for st, xq, keys, ns in queries]),
        counters=nested)
    flatten_counters(nested, counters, ("sample",))
    return results


@jax.jit
def _batched_loo_launch(chol, alpha, y, eps):
    """Closed-form LOO posterior + draws for stacked targets. chol:
    (J, n_pad, n_pad) block-diagonally padded (unit diagonal on the pad
    block, so the valid block's inverse is exact); alpha/y: (J, n_pad)
    zero-padded; eps: (J, S, n_pad), exact-shape draws zero-padded."""
    n_pad = chol.shape[1]

    def one(ci, ai, yi):
        kinv = jax.scipy.linalg.cho_solve((ci, True), jnp.eye(n_pad))
        kd = jnp.diagonal(kinv)
        return yi - ai / kd, jnp.maximum(1.0 / kd, 1e-10)

    mu, var = jax.vmap(one)(chol, alpha, y)
    return mu[:, None, :] + eps * jnp.sqrt(var)[:, None, :]


# Donating twin: every LOO argument is stacked fresh per scoring round
# (jnp.stack always copies), so all four may be donated.
_batched_loo_launch_donated = jax.jit(
    _batched_loo_launch.__wrapped__, donate_argnums=(0, 1, 2, 3))


def loo_sample_multi(
    queries, *,
    round_to: Optional[int] = None, counters: Optional[dict] = None,
) -> List[jnp.ndarray]:
    """MANY targets' leave-one-out posterior draws (``gp_loo_samples``)
    as ONE ``_batched_loo_launch`` per (S, n) bucket — the last
    per-ensemble draw of an RGPE scoring round joins the sample query
    plan (each ``(target, key, n_samples)`` tuple becomes a
    ``LooSampleQuery`` node; bucketing/padding policy lives in
    ``core.plan.StepPlanner``). The observation axis pads to a
    ``round_to`` bucket (unit Cholesky diagonal, so the valid block's
    LOO moments are exact); eps is drawn OUTSIDE at each target's exact
    (S, n) shape, so streams match the per-target path bit for bit.
    Returns one ``(S, n_i)`` array per query, in input order."""
    from .plan import (LooSampleQuery, PlanExecutor, StepPlanner,
                       flatten_counters)
    planner = StepPlanner(obs_round_to=round_to)
    nested: dict = {}
    results = PlanExecutor().execute(
        planner.plan([LooSampleQuery(gp, key, ns)
                      for gp, key, ns in queries]),
        counters=nested)
    flatten_counters(nested, counters, ("loo",))
    return results
