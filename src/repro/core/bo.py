"""Search-based resource-configuration profiling loops.

  - NaiveBO    (CherryPick): Matern-5/2 GP prior + EI, constraints via
               probability of feasibility.
  - AugmentedBO (Arrow): Extra-Trees prior fed low-level metric averages,
               EI acquisition.
  - Karasu     : NaiveBO extended with the RGPE ensemble over support
               models chosen by Algorithm 1 from the shared repository.

All methods share the same protocol (paper §IV-C): 3 random initial
samples, <= 20 profiling runs, optional CherryPick stopping rule (stop
when max EI <= 10% of the incumbent and >= 6 runs done).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .acquisition import (constrained_ei, expected_improvement, feasible,
                          probability_of_feasibility)
from .encoding import SearchSpace
from .extra_trees import fit_extra_trees
from .gp import batched_posterior
from .plan import PlanExecutor, PosteriorQuery, StepPlanner
from .repository import Repository, SupportModelStore
from .rgpe import WeightJob, compute_weights_multi, mix_weighted
from .selection import CandidateIndex
from .types import BOResult, Constraint, Objective, Observation, RunRecord

ProfileFn = Callable[[Mapping], Tuple[Dict[str, float], np.ndarray]]
# profile_fn(config) -> (measures, compact metric matrix)

# the single-tenant drivers share one planner/executor pair with default
# policy — the same query-plan layer a SearchService step uses, so the
# serving path and the reference loop literally share one plan
# implementation (m_round_pow2=False on fits: a fixed-size measure
# cohort never varies step to step, so lane padding buys nothing)
_PLANNER = StepPlanner()
_EXECUTOR = PlanExecutor()


# PRNG purpose tags. Every per-iteration key consumer derives its keys
# as nested fold_ins of (purpose, iteration, index) — distinct purposes
# give disjoint subtrees, so no arithmetic on tag integers can make two
# consumers collide (the old ``1000 + it * 10 + oi`` MOO tag shared the
# integer space with every other single-fold tag).
KEY_PURPOSE_RGPE = 0          # RGPE support-sample draws (index: measure)
KEY_PURPOSE_MOO_EHVI = 1      # MC-EHVI posterior draws (index: objective)

# the purpose registry: ``repro.analysis.prng_audit`` proves the tags
# distinct and the enumerated (purpose, iteration, index) tree
# collision-free — add new purposes HERE so the audit covers them
KEY_PURPOSES: Dict[str, int] = {
    "rgpe": KEY_PURPOSE_RGPE,
    "moo_ehvi": KEY_PURPOSE_MOO_EHVI,
}


def derive_key(base: jax.Array, purpose: int, it: int,
               index: int) -> jax.Array:
    """Collision-free per-(purpose, iteration, index) PRNG key."""
    k = jax.random.fold_in(base, purpose)
    k = jax.random.fold_in(k, it)
    return jax.random.fold_in(k, index)


@dataclasses.dataclass(frozen=True)
class BOConfig:
    n_init: int = 3
    max_iters: int = 20
    noise: float = 0.1
    early_stop: bool = False
    ei_threshold: float = 0.1     # CherryPick: stop when EI <= 10% incumbent
    min_iters: int = 6
    n_support: int = 3            # Karasu support models
    rgpe_samples: int = 256
    kernel_impl: str = "xla"      # xla | pallas | pallas_interpret


# the one feasibility rule, shared with pareto_of_observations and the
# serving layer (historical private name kept for existing importers)
_feasible = feasible


def _best_feasible_value(observations, objective, constraints):
    vals = [o.measures[objective.name] for o in observations
            if _feasible(o, constraints)]
    return min(vals) if vals else None


def _best_index_so_far(observations, objective, constraints) -> int:
    best_i, best_v = -1, np.inf
    for i, o in enumerate(observations):
        if _feasible(o, constraints) and o.measures[objective.name] < best_v:
            best_i, best_v = i, o.measures[objective.name]
    return best_i


def _profile_into(space, xq_all, profile_fn, objective, constraints,
                  observations, best_idx, profiled, ci: int) -> Observation:
    """Execute one profiling run and record it — the bookkeeping shared
    verbatim by run_search and SearchService sessions."""
    config = space.configs[ci]
    measures_out, metrics = profile_fn(config)
    obs = Observation(config=config, x=xq_all[ci], measures=measures_out,
                      metrics=metrics)
    observations.append(obs)
    profiled.add(ci)
    best_idx.append(_best_index_so_far(observations, objective, constraints))
    return obs


def _acquisition(post, observations, objective, constraints):
    """Constrained EI over whatever grid ``post`` was evaluated on.
    Shared by run_search and SearchService so the acquisition and its
    incumbent handling cannot diverge. Returns (acq, best_raw, obj_post)."""
    obj_post = post[objective.name]
    best_raw = _best_feasible_value(observations, objective, constraints)
    if best_raw is None:
        best_raw = min(o.measures[objective.name] for o in observations)
    best_std = (best_raw - obj_post["y_mean"]) / obj_post["y_std"]
    cons_posts = []
    for c in constraints:
        cp = post[c.name]
        ub_std = (c.upper_bound - cp["y_mean"]) / cp["y_std"]
        cons_posts.append((cp["mu"], cp["var"], ub_std))
    acq = np.asarray(constrained_ei(obj_post["mu"], obj_post["var"],
                                    best_std, cons_posts))
    return acq, best_raw, obj_post


def _should_stop_early(cfg, n_obs: int, acq, obj_post, best_raw) -> bool:
    """CherryPick stopping rule: max EI <= 10% of the incumbent, after at
    least min_iters profiling runs."""
    if not cfg.early_stop or n_obs < cfg.min_iters:
        return False
    ei_raw = float(np.max(acq)) * float(obj_post["y_std"])
    return ei_raw <= cfg.ei_threshold * abs(best_raw)


class KarasuContext:
    """Per-search (or per-service, shared across tenants) Karasu state:
    the incremental support-model store plus a repository-version-keyed
    Algorithm-1 candidate index. Everything in here is derived purely
    from repository contents, so N concurrent searches against the same
    repository can (and should) share one context."""

    def __init__(self, repository: Repository, space: SearchSpace, *,
                 noise: float = 0.1,
                 store: Optional[SupportModelStore] = None):
        self.repo = repository
        self.store = store or SupportModelStore(repository, space,
                                                noise=noise)
        self._index: Optional[CandidateIndex] = None
        self._index_version = -1

    def candidate_index(self) -> CandidateIndex:
        v = self.repo.global_version()
        if self._index is None or v != self._index_version:
            self._index = CandidateIndex(self.repo.all_runs())
            self._index_version = v
        return self._index

    @staticmethod
    def score_ensembles(jobs: Sequence[WeightJob], *,
                        impl: str = "xla", fuse_samples: bool = True,
                        sample_counters: Optional[dict] = None,
                        planner: Optional[StepPlanner] = None,
                        plan_executor: Optional[PlanExecutor] = None
                        ) -> List:
        """RGPE weights for every queued (tenant, measure) ensemble of a
        scheduling round in ONE padded ranking-loss launch, with every
        job's support-sample draw emitted as ``SampleQuery`` /
        ``LooSampleQuery`` nodes into the query plan
        (``fuse_samples=False`` restores the per-job draw loop, the
        parity/benchmark baseline; ``planner`` shares the caller's
        bucketing policy). Static — the weighting depends only on the
        jobs, never on context state, so a service may score jobs
        spanning several contexts in one call. Single-tenant
        ``run_search`` batches its measures through the same entry
        point, so the serving path and the reference loop cannot
        diverge."""
        return compute_weights_multi(jobs, impl=impl,
                                     fuse_samples=fuse_samples,
                                     sample_counters=sample_counters,
                                     planner=planner,
                                     plan_executor=plan_executor)


def _target_runs(observations) -> List[RunRecord]:
    return [RunRecord("__target__", o.config, o.metrics, o.measures)
            for o in observations if o.metrics is not None]


def _model_posteriors_karasu(observations, measures, cfg,
                             ctx: KarasuContext, key, xq):
    """RGPE ensemble posterior per measure + target scalers.

    All target GPs (one per measure) are fit in ONE vmapped batch under
    the planner's shape policy, and every grid posterior the iteration
    needs — the target stack AND all measures' RGPE support stacks —
    is emitted as ``PosteriorQuery`` nodes and executed by the SAME
    collect → plan → execute → scatter layer a ``SearchService`` step
    uses, preceded by one padded ranking-loss launch for the weights.
    The old per-ensemble posterior loop lives on only in
    ``ensemble_posterior_batched``, the parity oracle."""
    selected = ctx.candidate_index().query(
        _target_runs(observations), cfg.n_support, impl=cfg.kernel_impl)

    x = np.stack([o.x for o in observations])
    ys = [np.array([o.measures[m] for o in observations])
          for m in measures]
    tgts = _PLANNER.fit_targets([x] * len(measures), ys, noise=cfg.noise,
                                m_round_pow2=False)
    jobs, job_meta = [], []
    for mi, m in enumerate(measures):
        bases, _ids = ctx.store.get_stacked([z for z, _ in selected], m)
        if bases is not None:
            jobs.append(WeightJob(bases, tgts.extract(mi),
                                  jax.random.fold_in(key, mi),
                                  cfg.rgpe_samples))
            job_meta.append((mi, m, bases))
    # all measures' ensembles scored in one padded ranking-loss launch
    ws = ctx.score_ensembles(jobs, impl=cfg.kernel_impl, planner=_PLANNER)
    # ... and ALL grid posteriors (targets + ensemble members) planned
    # into fused launches
    res = _EXECUTOR.execute(
        _PLANNER.plan([PosteriorQuery(tgts, xq)]
                      + [PosteriorQuery(bases, xq)
                         for _, _, bases in job_meta]),
        impl=cfg.kernel_impl)
    mu_t, var_t = res[0]
    out = {}
    for mi, m in enumerate(measures):
        out[m] = {"mu": mu_t[mi], "var": var_t[mi],
                  "y_mean": tgts.y_mean[mi], "y_std": tgts.y_std[mi],
                  "weights": np.array([1.0])}
    for (mi, m, bases), w, (mu_b, var_b) in zip(job_meta, ws, res[1:]):
        mu, var = mix_weighted(mu_b, var_b, out[m]["mu"], out[m]["var"], w)
        out[m] = {"mu": mu, "var": var, "y_mean": tgts.y_mean[mi],
                  "y_std": tgts.y_std[mi], "weights": np.asarray(w)}
    return out, selected


def _model_posteriors_naive(observations, measures, cfg, xq):
    """All measures' GPs share the observed x, so they fit and query as
    one BatchedGP — a single vmapped Cholesky instead of a measure loop."""
    x = np.stack([o.x for o in observations])
    ys = [np.array([o.measures[m] for o in observations])
          for m in measures]
    b = _PLANNER.fit_targets([x] * len(measures), ys, noise=cfg.noise,
                             m_round_pow2=False)
    mu, var = batched_posterior(b, xq)
    return {m: {"mu": mu[i], "var": var[i], "y_mean": b.y_mean[i],
                "y_std": b.y_std[i]}
            for i, m in enumerate(measures)}


def _model_posteriors_augmented(observations, measures, cfg, xq, seed):
    """Arrow: Extra-Trees on [encoded config ++ low-level metric means];
    candidate metrics imputed with the observed means."""
    out = {}
    metr = np.stack([
        np.mean(o.metrics, axis=1) if o.metrics is not None
        else np.zeros(6) for o in observations])
    x = np.stack([o.x for o in observations])
    x_aug = np.concatenate([x, metr], axis=1)
    imput = np.tile(metr.mean(0), (xq.shape[0], 1))
    xq_aug = np.concatenate([np.asarray(xq), imput], axis=1)
    for m in measures:
        y = np.array([o.measures[m] for o in observations])
        et = fit_extra_trees(x_aug, y, seed=seed)
        mu, var = et.posterior(xq_aug)
        out[m] = {"mu": jnp.asarray(mu), "var": jnp.asarray(var),
                  "y_mean": jnp.asarray(et.y_mean),
                  "y_std": jnp.asarray(et.y_std)}
    return out


def run_search(
    space: SearchSpace,
    profile_fn: ProfileFn,
    objective: Objective,
    constraints: Sequence[Constraint] = (),
    *,
    method: str = "naive",            # naive | augmented | karasu
    repository: Optional[Repository] = None,
    bo_config: BOConfig = BOConfig(),
    seed: int = 0,
) -> BOResult:
    cfg = bo_config
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    measures = [objective.name] + [c.name for c in constraints]
    xq_all = space.all_encoded()
    ctx = (KarasuContext(repository, space, noise=cfg.noise)
           if method == "karasu" and repository is not None else None)

    observations: List[Observation] = []
    best_idx: List[int] = []
    profiled: set = set()
    stopped_at = cfg.max_iters
    meta: Dict = {"method": method, "selected": []}

    def profile(ci: int):
        _profile_into(space, xq_all, profile_fn, objective, constraints,
                      observations, best_idx, profiled, ci)

    # --- random initialisation (3 samples, paper §IV-B) -------------------
    init = rng.choice(len(space), size=min(cfg.n_init, len(space)),
                      replace=False)
    for ci in init:
        profile(int(ci))

    for it in range(len(observations), cfg.max_iters):
        remaining = [i for i in range(len(space)) if i not in profiled]
        if not remaining:
            stopped_at = it
            break
        xq = xq_all[remaining]

        if method == "karasu" and repository is not None:
            # per-measure jobs fold_in(mi) below this root, completing
            # the derive_key(key, RGPE, it, mi) schedule the service's
            # _rgpe_jobs derives identically
            rgpe_root = jax.random.fold_in(
                jax.random.fold_in(key, KEY_PURPOSE_RGPE), it)
            post, selected = _model_posteriors_karasu(
                observations, measures, cfg, ctx, rgpe_root, xq)
            meta["selected"].append([z for z, _ in selected])
        elif method == "augmented":
            post = _model_posteriors_augmented(observations, measures, cfg,
                                               xq, seed)
        else:
            post = _model_posteriors_naive(observations, measures, cfg, xq)

        # objective EI on the model's standardised scale
        acq, best_raw, obj_post = _acquisition(post, observations,
                                               objective, constraints)
        if _should_stop_early(cfg, len(observations), acq, obj_post,
                              best_raw):
            stopped_at = it
            break

        profile(remaining[int(np.argmax(acq))])

    meta["n_profiled"] = len(observations)
    return BOResult(observations=observations, best_index_per_iter=best_idx,
                    stopped_at=stopped_at, meta=meta)
