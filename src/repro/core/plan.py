"""The declarative query-plan layer: collect -> plan -> execute -> scatter.

PRs 1-4 fused every posterior, sample draw, and EHVI evaluation of a
multi-tenant service step into padded batched launches, but the "plan"
was implicit: the bucketing and padding policy (observation axis rounded
to multiples of 8, fused model axis to a power of two, (q, d) / (S, q,
d) bucket keys) was restated in ``core/gp.py``, ``core/acquisition.py``
and ``serve/search_service.py``. This module makes the plan an explicit,
testable IR:

  - **Query nodes** — one dataclass per kind of launch a scheduling
    round needs, each carrying an opaque ``owner`` tag for scatter:

    ==================== ================================== =============
    node                 one logical request                bucket key
    ==================== ================================== =============
    ``PosteriorQuery``   grid posterior of a BatchedGP      (q, d)
    ``SampleQuery``      marginal posterior draws of a      (S, q, d)
                         BatchedGP at a grid
    ``LooSampleQuery``   closed-form leave-one-out draws    (S, n)
                         of a single target GP
    ``PosteriorDrawQuery`` affine draws from precomputed    (S, q)
                         posterior rows (MOO EHVI sampling)
    ``EhviQuery``        MC-EHVI of raw-scale draws against (n_obj, S, q)
                         a session's front (any n_obj >= 2)
    ``FitQuery``         warm-startable GP fit of one       (d, steps,
                         model's observations               noise)
    ==================== ================================== =============

  - ``StepPlanner`` — owns ALL bucketing/padding policy in one place.
    ``plan(queries)`` groups queries into ``Bucket``\\ s (one fused
    launch each) and records every pad decision on the bucket, so tests
    can assert the exact launch shapes a query set produces without
    running anything.

  - ``PlanExecutor`` — runs one launch per bucket (the jitted kernels
    live with their model math in ``core/gp.py`` /
    ``core/acquisition.py``) and scatters results back to owners:
    results come back in query order, and any query whose ``owner`` is
    callable has it invoked with the result.

``SearchService.step`` collects query nodes from every ready session,
plans, executes, and scatters; ``run_search`` / ``run_search_moo`` /
``KarasuContext.score_ensembles`` route through the same planner, and
the historical entry points (``batched_posterior_multi``,
``batched_sample_multi``, ``loo_sample_multi``, ``mc_ehvi_multi``) are
thin wrappers over it — so the serving path and the driver path share
one plan implementation, and new workload kinds (e.g. the n>=3-objective
EHVI) are plan-node additions instead of another fused-step rewrite.

Exact-padding contract (inherited from the fused launches this layer
absorbs): padded observations are masked out of the kernel and carry
unit Cholesky diagonals, padded grid points are edge-repeats or +inf
points whose rows are sliced off, padded model lanes repeat lane 0 and
are thrown away, padded EHVI boxes have lo = hi = +inf (zero volume) —
fusing or padding a query NEVER changes its result beyond float
roundoff, and PRNG draws always happen at each query's exact shape
before any padding, so draw streams are plan-invariant.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.distributed import mesh_axis_size, shard_map
from repro.kernels.routing import resolve_impl

from .acquisition import (EHVI_BOX_CHUNK, _ehvi_box_launch,
                          _ehvi_box_launch_donated, expected_improvement,
                          nondominated_boxes, pareto_front)
from .gp import (GP, BatchedGP, _batched_loo_launch,
                 _batched_loo_launch_donated, _batched_posterior,
                 _batched_posterior_donated, _batched_sample_launch,
                 _batched_sample_launch_donated, _pack_fit_lanes,
                 _pad_stack_obs, fit_gp_batched, sharded_fit_launches)

# -- the one home of the shape policy ---------------------------------------
OBS_ROUND_TO = 8        # observation axis pads to multiples of this
GRID_ROUND_TO = 8       # sample/EHVI candidate axis pads to multiples
M_ROUND_POW2 = True     # fused model/lane axis pads to a power of two


# ---------------------------------------------------------------------------
# Query nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PosteriorQuery:
    """Posterior mean/variance of one ``BatchedGP`` stack on a grid.
    ``grid``: (q, d) shared across the stack's models or (m, q, d)
    per-model. Result: ``(mu, var)``, each (m, q) — or ``(mu, var, ei)``
    when ``best`` (the standardised-scale incumbent for the closed-form
    minimisation-EI head) is set, letting the fused bucket kernel finish
    the acquisition in the same launch."""
    stack: BatchedGP
    grid: Any
    owner: Any = None
    best: Any = None


@dataclasses.dataclass(frozen=True)
class SampleQuery:
    """Marginal-posterior draws of one stack at a grid: ``keys`` is one
    PRNG key per model. Result: (m, n_samples, q)."""
    stack: BatchedGP
    grid: Any
    keys: Any
    n_samples: int
    owner: Any = None


@dataclasses.dataclass(frozen=True)
class LooSampleQuery:
    """Closed-form leave-one-out posterior draws of a single target GP
    at its own inputs (RGPE's target honesty device). Result: (S, n)."""
    gp: GP
    key: Any
    n_samples: int
    owner: Any = None


@dataclasses.dataclass(frozen=True)
class PosteriorDrawQuery:
    """Raw-scale affine draws from precomputed posterior rows — the MOO
    EHVI sampling leg, where the grid posterior already ran and only
    ``mu + eps * sqrt(var)`` (rescaled) remains. ``mu``/``var``: (q,)
    standardised rows at the remaining candidates. Result: (n_mc, q)."""
    mu: Any
    var: Any
    y_mean: Any
    y_std: Any
    key: Any
    n_mc: int
    owner: Any = None


@dataclasses.dataclass(frozen=True)
class EhviQuery:
    """MC expected hypervolume improvement against a session's observed
    front, in one of two equivalent forms sharing a bucket:

    **Sample form** (``samples`` set): one (S, q) raw-scale draw array
    per objective (any count >= 2) — the draws already ran (e.g. as a
    ``PosteriorDrawQuery`` round).

    **Posterior form** (``samples=None``): the draw is deferred into the
    EHVI launch itself. ``mu``/``var``: one (q,) standardised posterior
    row per objective; ``y_mean``/``y_std``: per-objective scalars;
    ``keys``: one PRNG key per objective; ``n_mc``: draw count. The
    launch consumes ``normal(keys[i], (n_mc, q))`` and the exact
    ``(mu + eps * sqrt(var)) * y_std + y_mean`` affine of
    ``_draw_launch``, so both forms produce bit-identical streams — the
    fused executor skips the separate draw round (and its (S, q) HBM
    round-trip per objective) without perturbing results.

    ``observed``: (n, n_obj); ``ref``: (n_obj,). Result: (q,) numpy."""
    samples: Optional[Tuple[Any, ...]]
    observed: Any
    ref: Any
    owner: Any = None
    mu: Optional[Tuple[Any, ...]] = None
    var: Optional[Tuple[Any, ...]] = None
    y_mean: Optional[Tuple[float, ...]] = None
    y_std: Optional[Tuple[float, ...]] = None
    keys: Optional[Tuple[Any, ...]] = None
    n_mc: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class FitQuery:
    """Fit one GP model's hyperparameters from its raw observations —
    the fit leg as a first-class plan node. ``x``: (n, d) raw inputs,
    ``y``: (n,) raw objective values (standardisation happens at
    packing, exactly as in ``fit_gp_batched``). ``steps`` is the Adam
    schedule length and part of the bucket key: warm lanes carry their
    previous hyperparameters in ``init_ls``/``init_sf`` and ask for the
    short refine rung (``CohortLimits.fit_warm_steps``), cold lanes
    leave them ``None`` (zero init) on the full rung
    (``CohortLimits.fit_steps``). Result: ``(stack, lane)`` — the
    bucket's fitted ``BatchedGP`` plus this query's lane index in it
    (``stack.extract(lane)`` recovers the unbatched model)."""
    x: Any
    y: Any
    noise: float
    steps: int
    init_ls: Any = None
    init_sf: Any = None
    owner: Any = None


# ---------------------------------------------------------------------------
# The plan IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One fused launch: the queries at ``indices`` share ``key`` and
    execute together under the pad decisions in ``pads`` (every padded
    axis length the launch will use, for golden-shape tests)."""
    kind: str
    key: Tuple
    indices: Tuple[int, ...]
    pads: Dict[str, int]


@dataclasses.dataclass(frozen=True)
class CohortLimits:
    """Bounds that CLOSE a service's bucket vocabulary, so the full set
    of launch shapes it can ever ask for is enumerable up front
    (``StepPlanner.enumerate_buckets``) and precompilable at startup
    (``SearchService.precompile``).

    ``d``/``q_grid`` come from the search space (encoded dimension and
    candidate count); ``max_obs`` bounds any single model's observation
    count (for targets: initial runs + max_iters; support models are
    bounded by the repository's deepest (workload, measure) history);
    ``max_lanes`` bounds how many model lanes one fused launch can carry
    (targets and support stacks summed across the cohort). The optional
    tuples pin the discrete knob values in play — RGPE sample counts,
    MOO Monte-Carlo draw counts, objective counts — and ``noises`` the
    fixed noise levels the (jit-static) fit launches will see.
    ``max_ehvi_boxes`` bounds the box-decomposition size of any front
    (2-objective fronts decompose into at most ``front+1`` staircase
    boxes; n>=3 fronts grow faster and dominate the vocabulary)."""
    d: int
    q_grid: int
    max_obs: int
    max_lanes: int = 1
    n_samples: Tuple[int, ...] = ()
    n_mc: Tuple[int, ...] = ()
    n_objectives: Tuple[int, ...] = ()
    max_ehvi_boxes: int = 1
    noises: Tuple[float, ...] = (0.1,)
    fit_steps: int = 120
    fit_warm_steps: int = 16


@dataclasses.dataclass
class StepPlan:
    """The planned step: ``queries`` in emission order, ``buckets`` one
    per fused launch, ``prep`` per-query planner precomputation (the
    EHVI box decompositions). ``stats()`` reports the fusion shape."""
    queries: List[Any]
    buckets: List[Bucket]
    prep: Dict[int, Any] = dataclasses.field(default_factory=dict)

    def stats(self) -> Dict[str, int]:
        return {"batches": len(self.buckets), "queries": len(self.queries)}


def _round_up(n: int, mult: int) -> int:
    return n if mult <= 1 else ((n + mult - 1) // mult) * mult


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class StepPlanner:
    """Owns ALL bucketing/padding policy: which queries fuse (the bucket
    keys) and what every launch's padded shapes are. The historical
    contracts — observation axis to multiples of ``obs_round_to``,
    sample/EHVI candidate axis to ``q_round_to``, fused model/lane axis
    to a power of two, EHVI box count to a power of two — live here and
    nowhere else."""

    def __init__(self, *, obs_round_to: Optional[int] = None,
                 q_round_to: Optional[int] = None,
                 m_round_pow2: Optional[bool] = None,
                 mesh=None, data_axis: str = "data",
                 lane_shards: Optional[int] = None):
        self.obs_round_to = (OBS_ROUND_TO if obs_round_to is None
                             else obs_round_to)
        self.q_round_to = (GRID_ROUND_TO if q_round_to is None
                           else q_round_to)
        self.m_round_pow2 = (M_ROUND_POW2 if m_round_pow2 is None
                             else m_round_pow2)
        # data-parallel execution: with a mesh installed, every fused
        # lane axis additionally rounds up to a multiple of the mesh's
        # data-axis size, so shard_map splits each launch evenly across
        # devices. ``lane_shards`` overrides the divisor directly (for
        # policy tests on single-device hosts).
        self.mesh = mesh
        self.data_axis = data_axis
        self.lane_shards = (mesh_axis_size(mesh, data_axis)
                            if lane_shards is None else int(lane_shards))

    # -- shared shape policy -------------------------------------------------
    def round_obs(self, n: int) -> int:
        return _round_up(n, self.obs_round_to)

    def round_grid(self, q: int) -> int:
        return _round_up(q, self.q_round_to)

    def round_models(self, m: int) -> int:
        m = _pow2(m) if self.m_round_pow2 else m
        return _round_up(m, self.lane_shards)

    def round_boxes(self, k: int) -> int:
        """Box-axis pad of a live ``k``-box front: small fronts pad to
        a power of two; past one launch block the axis pads to a chunk
        multiple instead (the launch scans fixed-size blocks there,
        bounding peak memory). The policy twin of ``_box_pads`` — the
        static closure analysis holds the two together."""
        return (_pow2(k) if k <= EHVI_BOX_CHUNK
                else _round_up(k, EHVI_BOX_CHUNK))

    def fit_targets(self, xs, ys, *, noise: float, steps: int = 120,
                    m_round_pow2: Optional[bool] = None) -> BatchedGP:
        """Fit a cohort of target GPs under the planner's jit-shape
        policy (the fused-fit twin of ``plan``: same observation-axis
        bucketing, same model-axis rule). ``m_round_pow2=False`` opts a
        fixed-size cohort (e.g. single-tenant ``run_search``) out of the
        power-of-two lane padding that only pays off when cohort size
        varies step to step. With a mesh installed the fit runs through
        the shard-mapped launch twins (lane axis split over the data
        axis), and the lane count rounds to a shard multiple either
        way."""
        launches = (sharded_fit_launches(self.mesh, self.data_axis)
                    if self.mesh is not None and self.lane_shards > 1
                    else None)
        return fit_gp_batched(
            xs, ys, noise=noise, steps=steps, round_to=self.obs_round_to,
            m_round_pow2=(self.m_round_pow2 if m_round_pow2 is None
                          else m_round_pow2),
            lane_round_to=self.lane_shards, launches=launches)

    # -- bucketing -----------------------------------------------------------
    def bucket_key(self, query) -> Tuple[str, Tuple]:
        """(kind, key): queries fuse into one launch iff both match.
        Shapes are read via ``np.shape`` — no materialisation, so
        device-resident grids/rows never sync to host just to plan."""
        if isinstance(query, PosteriorQuery):
            return "posterior", (int(np.shape(query.grid)[-2]),
                                 int(query.stack.x.shape[-1]))
        if isinstance(query, SampleQuery):
            return "sample", (int(query.n_samples),
                              int(np.shape(query.grid)[-2]),
                              int(query.stack.x.shape[-1]))
        if isinstance(query, LooSampleQuery):
            return "loo", (int(query.n_samples), query.gp.n)
        if isinstance(query, PosteriorDrawQuery):
            return "draw", (int(query.n_mc),
                            int(np.shape(query.mu)[0]))
        if isinstance(query, EhviQuery):
            if query.samples is None:   # posterior form: draw deferred
                return "ehvi", (len(query.mu), int(query.n_mc),
                                int(np.shape(query.mu[0])[0]))
            s_shape = np.shape(query.samples[0])
            return "ehvi", (len(query.samples), int(s_shape[0]),
                            int(s_shape[1]))
        if isinstance(query, FitQuery):
            # steps and noise are jit-static on the fit launch, so warm
            # and cold lanes land in DIFFERENT buckets by construction
            return "fit", (int(np.shape(query.x)[1]), int(query.steps),
                           float(query.noise))
        raise TypeError(f"not a query node: {query!r}")

    def plan(self, queries: Sequence) -> StepPlan:
        """Group queries into one ``Bucket`` per fused launch and fix
        every pad decision. No launches execute here; the one
        non-trivial planning cost is the EHVI box decomposition
        (``_pads_ehvi`` must know each front's box count to fix
        ``k_pad``), which is computed once per query on the host and
        carried to the executor via ``StepPlan.prep``."""
        groups: Dict[Tuple[str, Tuple], List[int]] = {}
        for i, query in enumerate(queries):
            groups.setdefault(self.bucket_key(query), []).append(i)
        prep: Dict[int, Any] = {}
        buckets = []
        for (kind, key), idxs in groups.items():
            pads = getattr(self, f"_pads_{kind}")(
                key, [queries[i] for i in idxs], idxs, prep)
            buckets.append(Bucket(kind, key, tuple(idxs), pads))
        return StepPlan(list(queries), buckets, prep)

    def _pads_posterior(self, key, queries, idxs, prep) -> Dict[str, int]:
        lanes = sum(q.stack.m for q in queries)
        return {"n_pad": self.round_obs(max(q.stack.n_max for q in queries)),
                "m_pad": self.round_models(lanes), "lanes": lanes}

    def _pads_sample(self, key, queries, idxs, prep) -> Dict[str, int]:
        lanes = sum(q.stack.m for q in queries)
        return {"n_pad": self.round_obs(max(q.stack.n_max for q in queries)),
                "q_pad": self.round_grid(key[1]),
                "m_pad": self.round_models(lanes), "lanes": lanes}

    def _pads_loo(self, key, queries, idxs, prep) -> Dict[str, int]:
        # the lane axis pads to a power of two like every other fused
        # launch: without it the LOO launch recompiles per cohort size
        # and the bucket vocabulary is open-ended
        lanes = len(queries)
        return {"n_pad": self.round_obs(key[1]),
                "l_pad": self.round_models(lanes), "lanes": lanes}

    def _pads_draw(self, key, queries, idxs, prep) -> Dict[str, int]:
        # deliberately exact: the draw combine is not jitted (q shrinks
        # every iteration and the arithmetic is trivially cheap), so
        # padding would buy nothing and only perturb memory traffic
        return {"lanes": len(queries)}

    def _pads_ehvi(self, key, queries, idxs, prep) -> Dict[str, int]:
        n_obj = key[0]
        k_max = 1
        for i, query in zip(idxs, queries):
            observed = np.asarray(query.observed, np.float64)
            if observed.size and (observed.ndim != 2
                                  or observed.shape[1] != n_obj):
                raise ValueError(
                    f"EhviQuery observed has shape {observed.shape} but "
                    f"carries {n_obj} objective sample arrays")
            los, his = nondominated_boxes(
                pareto_front(observed.reshape(-1, n_obj)),
                np.asarray(query.ref, np.float64))
            prep[i] = (los, his)
            k_max = max(k_max, los.shape[0])
        return {"k_pad": self.round_boxes(k_max),
                "q_pad": self.round_grid(key[2]),
                "l_pad": self.round_models(len(queries)),
                "lanes": len(queries)}

    def _pads_fit(self, key, queries, idxs, prep) -> Dict[str, int]:
        lanes = len(queries)
        n_max = max(int(np.shape(q.y)[0]) for q in queries)
        return {"n_pad": self.round_obs(n_max),
                "m_pad": self.round_models(lanes), "lanes": lanes}

    # -- the closed bucket vocabulary ----------------------------------------
    def _obs_pads(self, max_obs: int) -> List[int]:
        step = max(1, self.obs_round_to)
        return list(range(step, self.round_obs(max_obs) + 1, step))

    def _grid_pads(self, max_q: int) -> List[int]:
        step = max(1, self.q_round_to)
        return list(range(step, self.round_grid(max_q) + 1, step))

    def _lane_pads(self, max_lanes: int) -> List[int]:
        # every fixed point of round_models up to the bound: the pow2
        # ladder, each rung lifted to a shard multiple when a mesh is
        # installed (so the enumerated vocabulary IS the sharded one)
        return sorted({self.round_models(m)
                       for m in range(1, max_lanes + 1)})

    def _box_pads(self, max_boxes: int) -> List[int]:
        out, p = [], 1
        while p < min(_pow2(max_boxes), EHVI_BOX_CHUNK):
            out.append(p)
            p <<= 1
        out.append(p)
        k = 2 * EHVI_BOX_CHUNK
        while k <= _round_up(max_boxes, EHVI_BOX_CHUNK):
            out.append(k)
            k += EHVI_BOX_CHUNK
        return out

    def fit_step_rungs(self, limits: CohortLimits) -> List[int]:
        """The fit leg's schedule-length vocabulary: the warm (short
        refine) rung and the cold (full) rung — deduplicated, since a
        service may disable warm starts by equating the two. A mutant
        that drops the warm rung here opens a vocabulary hole the
        closure analysis must catch (``repro.analysis.mutants``)."""
        rungs = {int(limits.fit_steps)}
        if limits.fit_warm_steps:
            rungs.add(int(limits.fit_warm_steps))
        return sorted(rungs)

    def enumerate_buckets(self, limits: CohortLimits) -> List[Bucket]:
        """Walk the CLOSED launch-shape vocabulary a cohort bounded by
        ``limits`` can produce — one ``Bucket`` (empty ``indices``) per
        distinct jitted launch shape, keys stated at their padded values
        (every padded value is its own fixed point under the rounding
        policy, so a dummy query AT the key shape lands exactly on the
        enumerated launch). ``draw`` buckets are deliberately absent:
        the draw combine is not jitted, so it has no compile vocabulary.

        Per kind: posterior launches vary (n_pad, m_pad) at the fixed
        (q_grid, d); sample launches add the grid axis (RGPE scores at
        the target's own inputs, so q ranges over the observation
        buckets) and the sample count; LOO launches vary (n_pad, l_pad)
        per sample count; EHVI launches vary the candidate bucket (the
        remaining-candidate set shrinks every iteration), the box-axis
        pad, and the MOO lane pad per (n_obj, n_mc); fit launches vary
        (n_pad, m_pad) per (noise, steps-rung) — the warm and cold
        schedule lengths from ``fit_step_rungs``."""
        out: List[Bucket] = []
        obs = self._obs_pads(limits.max_obs)
        lanes = self._lane_pads(limits.max_lanes)
        for n_pad in obs:
            for m_pad in lanes:
                out.append(Bucket("posterior", (limits.q_grid, limits.d),
                                  (), {"n_pad": n_pad, "m_pad": m_pad,
                                       "lanes": m_pad}))
        for s in limits.n_samples:
            for q_pad in self._grid_pads(limits.max_obs):
                for n_pad in obs:
                    for m_pad in lanes:
                        out.append(Bucket(
                            "sample", (s, q_pad, limits.d), (),
                            {"n_pad": n_pad, "q_pad": q_pad,
                             "m_pad": m_pad, "lanes": m_pad}))
            for n_pad in obs:
                for l_pad in lanes:
                    out.append(Bucket("loo", (s, n_pad), (),
                                      {"n_pad": n_pad, "l_pad": l_pad,
                                       "lanes": l_pad}))
        for n_obj in limits.n_objectives:
            for s in limits.n_mc:
                for q_pad in self._grid_pads(limits.q_grid):
                    for k_pad in self._box_pads(limits.max_ehvi_boxes):
                        for l_pad in lanes:
                            out.append(Bucket(
                                "ehvi", (n_obj, s, q_pad), (),
                                {"k_pad": k_pad, "q_pad": q_pad,
                                 "l_pad": l_pad, "lanes": l_pad}))
        for noise in limits.noises:
            for steps in self.fit_step_rungs(limits):
                for n_pad in obs:
                    for m_pad in lanes:
                        out.append(Bucket(
                            "fit", (limits.d, steps, float(noise)), (),
                            {"n_pad": n_pad, "m_pad": m_pad,
                             "lanes": m_pad}))
        return out

    def launch_signature(self, bucket: Bucket) -> Tuple:
        """The jit-cache identity of a bucket's launch: kind plus every
        axis length the compiled program sees (exact key dims that the
        executor pads away are normalised to their padded value, so a
        live bucket compares equal to its enumerated twin). Under a
        mesh the shard count joins the signature — the shard-mapped
        twin of a shape is a DIFFERENT compiled program than the
        single-device one, and the precompiled vocabulary must say
        which family it warmed."""
        k, key, p = bucket.kind, bucket.key, bucket.pads
        if k == "posterior":
            sig = ("posterior", key[0], key[1], p["n_pad"], p["m_pad"])
        elif k == "sample":
            sig = ("sample", key[0], p["q_pad"], key[2],
                   p["n_pad"], p["m_pad"])
        elif k == "loo":
            sig = ("loo", key[0], p["n_pad"], p["l_pad"])
        elif k == "draw":   # unjitted: exact shapes, no compile identity
            sig = ("draw", key[0], key[1], p["lanes"])
        elif k == "ehvi":
            sig = ("ehvi", key[0], key[1], p["q_pad"], p["k_pad"],
                   p["l_pad"])
        elif k == "fit":
            # the schedule length is a jit-static rung of the closed
            # vocabulary, not an axis — named so golden-signature tests
            # can't confuse it with the obs pad
            sig = ("fit", key[0], p["n_pad"], p["m_pad"],
                   ("steps", key[1]), ("noise", key[2]))
        else:
            raise ValueError(f"unknown bucket kind {k!r}")
        if self.lane_shards > 1 and k != "draw":
            sig = sig + (("shards", self.lane_shards),)
        return sig


# ---------------------------------------------------------------------------
# Execution: one launch per bucket, scatter to owners
# ---------------------------------------------------------------------------


def _count(counters: Optional[dict], kind: str, queries: int,
           lanes: int, wall_s: float = 0.0) -> None:
    if counters is None:
        return
    c = counters.setdefault(kind, {})
    c["launches"] = c.get("launches", 0) + 1
    c["queries"] = c.get("queries", 0) + queries
    c["lanes"] = c.get("lanes", 0) + lanes
    c["wall_s"] = c.get("wall_s", 0.0) + wall_s


def flatten_counters(nested: dict, counters: Optional[dict],
                     kinds: Sequence[str]) -> None:
    """Merge ``execute``'s per-kind counters into the historical flat
    ``launches``/``queries``/``lanes`` dict the single-kind wrappers
    (``batched_posterior_multi`` & co.) expose."""
    if counters is None:
        return
    for kind in kinds:
        for k, v in nested.get(kind, {}).items():
            counters[k] = counters.get(k, 0) + v


# -- shard-mapped launch twins ----------------------------------------------
# One jitted twin per (mesh, kind, donate): the base (unjitted) bucket
# launch body runs under shard_map with every argument — and every
# output — split on its leading lane axis over the mesh's data axis.
# Lane axes are multiples of the shard count by planner policy
# (``StepPlanner.round_models``), so shapes always divide evenly. Each
# twin is registered with ``launch.compile_stats`` at construction, so
# the compile-once accounting (``plan_compile_misses``) covers the
# sharded vocabulary exactly like the single-device one.
_SHARDED_LAUNCHES: Dict[Tuple, Any] = {}


def _shard_base(kind: str):
    """(base fn, takes-static-impl, donate_argnums) for one launch kind.
    Bases are the UNJITTED bodies — the sharded twin re-jits them under
    its own shard_map wrapper (donating the same per-step-rebuilt
    buffers as the single-device donating twins)."""
    if kind == "posterior":
        return _batched_posterior.__wrapped__, True, (2, 3, 4, 5, 6)
    if kind == "sample":
        return _batched_sample_launch.__wrapped__, True, (2, 3, 4, 5, 6, 7)
    if kind == "loo":
        return _batched_loo_launch.__wrapped__, False, (0, 1, 2, 3)
    if kind == "ehvi":
        from .acquisition import _ehvi_box_eval
        return _ehvi_box_eval, False, (0, 1, 2, 3)
    if kind == "fused_posterior":
        from repro.kernels.fused_posterior.ops import fused_posterior_ei
        return fused_posterior_ei, True, (2, 3, 4, 5, 6)
    if kind == "fused_ehvi":
        from repro.kernels.fused_ehvi.ops import fused_ehvi
        return fused_ehvi, True, (0, 1, 2, 3, 4, 5, 6, 7)
    if kind == "fused_fit":
        from repro.kernels.fused_fit.ops import fused_fit
        return fused_fit, True, (3, 4)
    raise ValueError(f"no sharded twin for launch kind {kind!r}")


def sharded_fused_fit_launch(mesh, axis: str, donate: bool):
    """Shard-mapped twin of the fused fit launch. The generic wrapper
    below only threads ``impl`` statically, but the fit leg's schedule
    length and noise are jit-static rungs of the vocabulary too — so it
    gets its own wrapper binding all three before shard_map. One jitted
    entry covers every (steps, noise) rung (jit caches per static), and
    only the per-step-rebuilt warm-start rows are donated."""
    cache_key = (mesh, axis, "fused_fit", donate)
    hit = _SHARDED_LAUNCHES.get(cache_key)
    if hit is not None:
        return hit
    from repro.kernels.fused_fit.ops import fused_fit
    from repro.launch.compile_stats import register_launch
    spec = PartitionSpec(axis)

    def run(x, y, mask, init_ls, init_sf, *, steps: int = 120,
            noise: float = 0.1, lr: float = 0.05, impl: str = "xla"):
        body = functools.partial(fused_fit, steps=steps, noise=noise,
                                 lr=lr, impl=impl)
        return shard_map(body, mesh, in_specs=(spec,) * 5,
                         out_specs=spec, check_vma=False)(
            x, y, mask, init_ls, init_sf)

    kw: Dict[str, Any] = {"static_argnames": ("steps", "noise", "lr",
                                              "impl")}
    if donate:
        kw["donate_argnums"] = (3, 4)
    launch = jax.jit(run, **kw)
    register_launch(
        f"fused_fit_sharded{'_donated' if donate else ''}"
        f"_x{mesh_axis_size(mesh, axis)}_{len(_SHARDED_LAUNCHES)}",
        launch)
    sharding = NamedSharding(mesh, spec)

    def placed(*args, **kwargs):
        return launch(*(jax.device_put(a, sharding) for a in args),
                      **kwargs)

    _SHARDED_LAUNCHES[cache_key] = placed
    return placed


def sharded_bucket_launch(mesh, axis: str, kind: str, donate: bool):
    """The jitted shard-mapped twin of one bucket launch kind, cached
    per (mesh, axis, kind, donate) so repeated steps re-enter one jit
    cache (and ``CompileWatcher`` sees one stable tracked entry)."""
    if kind == "fused_fit":   # extra statics: steps/noise/lr rungs
        return sharded_fused_fit_launch(mesh, axis, donate)
    cache_key = (mesh, axis, kind, donate)
    hit = _SHARDED_LAUNCHES.get(cache_key)
    if hit is not None:
        return hit
    from repro.launch.compile_stats import register_launch
    base, has_impl, donate_nums = _shard_base(kind)
    spec = PartitionSpec(axis)

    if has_impl:
        def run(*args, impl: str = "xla"):
            body = functools.partial(base, impl=impl)
            return shard_map(body, mesh, in_specs=(spec,) * len(args),
                             out_specs=spec, check_vma=False)(*args)
        kw: Dict[str, Any] = {"static_argnames": ("impl",)}
    else:
        def run(*args):
            return shard_map(base, mesh, in_specs=(spec,) * len(args),
                             out_specs=spec, check_vma=False)(*args)
        kw = {}
    if donate:
        kw["donate_argnums"] = donate_nums
    launch = jax.jit(run, **kw)
    register_launch(
        f"{kind}_sharded{'_donated' if donate else ''}"
        f"_x{mesh_axis_size(mesh, axis)}_{len(_SHARDED_LAUNCHES)}",
        launch)
    sharding = NamedSharding(mesh, spec)

    def placed(*args, **kwargs):
        # one argument placement for every caller: a step's bucket args
        # mix host-built stacks (uncommitted) with outputs of earlier
        # sharded launches (committed to the mesh), and precompile's
        # dummies are all uncommitted — jit caches per argument
        # sharding, so without normalisation a "warmed" shape compiles
        # AGAIN the first time it arrives mesh-committed mid-serve.
        # device_put is a no-op for arrays already carrying this
        # sharding, so the steady state pays nothing.
        return launch(*(jax.device_put(a, sharding) for a in args),
                      **kwargs)

    _SHARDED_LAUNCHES[cache_key] = placed
    return placed


def _draw_launch(keys, mu, var, y_std, y_mean, n_mc: int):
    """All draw lanes of one bucket in one stacked batch. Per-lane eps
    is ``normal(key, (n_mc, q))`` — the identical stream the per-session
    loop consumes, so fusion never changes draws."""
    q = mu.shape[1]
    eps = jax.vmap(lambda k: jax.random.normal(k, (n_mc, q)))(keys)
    sm = mu[:, None, :] + eps * jnp.sqrt(var)[:, None, :]
    return sm * y_std[:, None, None] + y_mean[:, None, None]


def _materialise_ehvi_draws(query, s: int, q: int):
    """Raw-scale draws of a posterior-form ``EhviQuery`` on the vmapped
    (non-fused) path: one ``_draw_launch`` over the query's objectives,
    consuming the same per-objective keys the fused kernel would — so
    the two executors' EHVI rows agree to float roundoff."""
    keys = jnp.stack([jnp.asarray(k) for k in query.keys])
    parts = [jnp.stack([jnp.asarray(a, jnp.float32) for a in t])
             for t in (query.mu, query.var)]
    scal = [jnp.asarray(np.asarray(t, np.float32)) for t in
            (query.y_std, query.y_mean)]
    draws = _draw_launch(keys, parts[0], parts[1], scal[0], scal[1],
                         n_mc=s)
    return [draws[d] for d in range(draws.shape[0])]


class PlanExecutor:
    """Executes a ``StepPlan``: one fused launch per bucket, results
    returned in query order. Scatter: any query whose ``owner`` is
    callable has ``owner(result)`` invoked (in query order, so owners
    that overlay earlier owners' state — e.g. RGPE mixes over target
    posteriors — see a deterministic sequence). ``counters`` (optional
    dict) collects ``{kind: {launches, queries, lanes}}``.

    ``fused_posterior=True`` dispatches posterior buckets to the fused
    ``kernels.fused_posterior`` launch (masked Cholesky-solve ->
    posterior -> EI in one kernel) instead of the vmapped-XLA
    ``_batched_posterior`` chain; ``fused_ehvi=True`` likewise
    dispatches EHVI buckets to ``kernels.fused_ehvi`` (per-lane draw
    affine + box reduction in one kernel) instead of the vmapped
    ``_ehvi_box_launch``. The defaults stay the vmapped paths, which
    double as the fused kernels' parity baselines. Results are
    identical up to float roundoff either way; queries carrying
    ``best`` additionally get the EI row.

    ``donate`` picks the donating jitted twins for every bucket launch
    (fused or vmapped): the per-step-rebuilt buffers — stacked
    observation caches, padded grids, box decompositions, draws — are
    handed back to XLA for the launch intermediates. It is resolved
    ONCE at construction (default: donate on a TPU backend), so
    ``SearchService.precompile`` warms exactly the jit entry serving
    dispatches — the two can never disagree via a per-call backend
    probe. Single-query buckets guard against aliasing: with no
    lane-padding to force a copy, the "stacked" buffers can BE a
    session's cached stack arrays, which donation would delete.

    ``mesh`` turns on data-parallel execution: every jitted bucket
    launch is replaced by its shard-mapped twin splitting the lane axis
    over the mesh's ``data_axis`` (lanes are independent models, so
    per-lane results match the single-device path up to float roundoff
    — XLA fuses the per-shard batch size differently, nothing more; the
    DISCRETE trajectory, which configs a search selects, is unchanged).
    The paired ``StepPlanner(mesh=...)`` rounds lane pads to shard
    multiples so shapes always divide; ``resolve_impl`` sees the
    per-shard cell volume, so ``"auto"`` routes each DEVICE's slice.
    The unjitted ``draw`` combine stays unsharded — exact shapes, no
    compile identity, trivial arithmetic."""

    def __init__(self, *, impl: str = "auto",
                 fused_posterior: bool = False,
                 fused_ehvi: bool = False,
                 donate: Optional[bool] = None,
                 mesh=None, data_axis: str = "data"):
        self.impl = impl
        self.fused_posterior = fused_posterior
        self.fused_ehvi = fused_ehvi
        self.donate = (jax.default_backend() == "tpu" if donate is None
                       else bool(donate))
        self.mesh = mesh
        self.data_axis = data_axis
        self.lane_shards = mesh_axis_size(mesh, data_axis)

    def _launch(self, kind: str, plain, donated):
        """The launch for one bucket kind under this executor's config:
        the shard-mapped twin when a mesh is installed, else the donating
        or plain single-device jit."""
        if self.mesh is not None and self.lane_shards > 1:
            return sharded_bucket_launch(self.mesh, self.data_axis, kind,
                                         self.donate)
        return donated if self.donate else plain

    def execute(self, plan: StepPlan, *, counters: Optional[dict] = None,
                impl: Optional[str] = None) -> List[Any]:
        impl = self.impl if impl is None else impl
        results: List[Any] = [None] * len(plan.queries)
        for bucket in plan.buckets:
            queries = [plan.queries[i] for i in bucket.indices]
            # host-side dispatch wall per bucket kind: includes lane
            # assembly + launch dispatch but NOT device completion (jax
            # dispatch is async) — a relative hotness signal across
            # kinds, not a device-time profile
            t0 = time.perf_counter()
            out = getattr(self, f"_exec_{bucket.kind}")(
                bucket, queries, plan, impl)
            wall = time.perf_counter() - t0
            for i, r in zip(bucket.indices, out):
                results[i] = r
            _count(counters, bucket.kind, len(queries),
                   bucket.pads.get("m_pad",
                                   bucket.pads.get("l_pad",
                                                   bucket.pads["lanes"])),
                   wall)
        for query, result in zip(plan.queries, results):
            if callable(query.owner):
                query.owner(result)
        return results

    # -- per-kind launches ---------------------------------------------------
    @staticmethod
    def _stack_parts(queries, n_pad: int, q: int, d: int,
                     q_pad: Optional[int] = None):
        """Assemble the padded (ls, sf, x, mask, chol, alpha, xq) lanes
        shared by the posterior and sample launches."""
        xs, masks, chols, alphas, lss, sfs, xqs = [], [], [], [], [], [], []
        for query in queries:
            st = query.stack
            x, mask, chol, alpha = _pad_stack_obs(st, n_pad)
            xs.append(x)
            masks.append(mask)
            chols.append(chol)
            alphas.append(alpha)
            lss.append(st.log_lengthscales)
            sfs.append(st.log_signal)
            xq = jnp.asarray(query.grid, jnp.float32)
            if xq.ndim == 2:
                xq = jnp.broadcast_to(xq[None], (st.m, q, d))
            if q_pad is not None and q_pad > q:
                xq = jnp.pad(xq, ((0, 0), (0, q_pad - q), (0, 0)),
                             mode="edge")
            xqs.append(xq)
        return [jnp.concatenate(a) for a in
                (lss, sfs, xs, masks, chols, alphas, xqs)]

    @staticmethod
    def _pad_lanes(parts, m_pad: int):
        m_total = int(parts[0].shape[0])
        if m_pad > m_total:
            parts = [jnp.concatenate(
                [a, jnp.broadcast_to(a[:1],
                                     (m_pad - m_total,) + a.shape[1:])])
                for a in parts]
        return parts

    def _fresh_parts(self, queries, parts):
        """Aliasing guard for donated launches: a single-query bucket's
        "stacked" parts come out of ``jnp.concatenate([x])`` /
        ``jnp.asarray``, which RETURN the input when shapes already
        match — i.e. the session's cached stack buffers themselves.
        Donating those would delete live cache state, so copy them
        first. Multi-query buckets always concatenate (a real copy)."""
        if self.donate and len(queries) == 1:
            parts = [jnp.array(p, copy=True) for p in parts]
        return parts

    def _exec_posterior(self, bucket, queries, plan, impl):
        q, d = bucket.key
        n_pad, m_pad = bucket.pads["n_pad"], bucket.pads["m_pad"]
        parts = self._fresh_parts(
            queries, self._stack_parts(queries, n_pad, q, d))
        r_impl = resolve_impl(impl, cells=m_pad * q * n_pad,
                              shards=self.lane_shards)
        if self.fused_posterior:
            from repro.kernels.fused_posterior import fused_launch_fn
            # per-lane incumbents; lanes without an EI head get 0.0 (the
            # EI row is computed either way — shape stability — and
            # simply not returned for those queries)
            best = jnp.concatenate([
                jnp.full((query.stack.m,),
                         0.0 if query.best is None else float(query.best),
                         jnp.float32) for query in queries])
            parts = self._pad_lanes(parts + [best], m_pad)
            launch = self._launch("fused_posterior",
                                  fused_launch_fn(donate=False),
                                  fused_launch_fn(donate=True))
            mu, var, ei = launch(*parts, impl=r_impl)
        else:
            parts = self._pad_lanes(parts, m_pad)
            launch = self._launch("posterior", _batched_posterior,
                                  _batched_posterior_donated)
            mu, var = launch(*parts, impl=r_impl)
            ei = None
        out, off = [], 0
        for query in queries:
            rows = slice(off, off + query.stack.m)
            if query.best is None:
                out.append((mu[rows], var[rows]))
            elif ei is not None:
                out.append((mu[rows], var[rows], ei[rows]))
            else:
                out.append((mu[rows], var[rows], expected_improvement(
                    mu[rows], var[rows], float(query.best))))
            off += query.stack.m
        return out

    def _exec_sample(self, bucket, queries, plan, impl):
        n_samples, q, d = bucket.key
        n_pad, q_pad, m_pad = (bucket.pads["n_pad"], bucket.pads["q_pad"],
                               bucket.pads["m_pad"])
        parts = self._fresh_parts(
            queries, self._stack_parts(queries, n_pad, q, d, q_pad=q_pad))
        keys_cat = jnp.concatenate(
            [jnp.asarray(query.keys) for query in queries])
        # exact-shape draws (one dispatch for the bucket), THEN pad: the
        # grid padding that keeps jit shapes stable across steps must
        # never perturb a lane's PRNG stream
        eps = jax.vmap(
            lambda k: jax.random.normal(k, (n_samples, q)))(keys_cat)
        if q_pad > q:
            eps = jnp.pad(eps, ((0, 0), (0, 0), (0, q_pad - q)))
        parts = self._pad_lanes(parts + [eps], m_pad)
        r_impl = resolve_impl(impl, cells=m_pad * q_pad * n_pad,
                              shards=self.lane_shards)
        launch = self._launch("sample", _batched_sample_launch,
                              _batched_sample_launch_donated)
        s = launch(*parts, impl=r_impl)
        out, off = [], 0
        for query in queries:
            out.append(s[off:off + query.stack.m, :, :q])
            off += query.stack.m
        return out

    def _exec_loo(self, bucket, queries, plan, impl):
        n_samples, n = bucket.key
        n_pad = bucket.pads["n_pad"]
        p = n_pad - n
        chols, alphas, ys = [], [], []
        for query in queries:
            gp = query.gp
            chol = jnp.pad(gp.chol, ((0, p), (0, p)))
            if p:
                bump = jnp.concatenate([jnp.zeros((n,), jnp.float32),
                                        jnp.ones((p,), jnp.float32)])
                chol = chol + jnp.diag(bump)
            chols.append(chol)
            alphas.append(jnp.pad(gp.alpha, (0, p)))
            ys.append(jnp.pad(gp.y, (0, p)))
        keys = jnp.stack([jnp.asarray(query.key) for query in queries])
        eps = jax.vmap(
            lambda k: jax.random.normal(k, (n_samples, n)))(keys)
        if p:
            eps = jnp.pad(eps, ((0, 0), (0, 0), (0, p)))
        parts = self._pad_lanes(
            [jnp.stack(chols), jnp.stack(alphas), jnp.stack(ys), eps],
            bucket.pads["l_pad"])
        # every LOO part is stacked fresh above (jnp.stack always
        # copies), so donation needs no single-query guard here
        launch = self._launch("loo", _batched_loo_launch,
                              _batched_loo_launch_donated)
        s = launch(*parts)
        return [s[j, :, :n] for j in range(len(queries))]

    def _exec_draw(self, bucket, queries, plan, impl):
        n_mc, _q = bucket.key
        parts = [jnp.stack([jnp.asarray(getattr(query, f))
                            for query in queries])
                 for f in ("key", "mu", "var", "y_std", "y_mean")]
        draws = _draw_launch(*parts, n_mc=n_mc)
        return [draws[j] for j in range(len(queries))]

    def _exec_ehvi(self, bucket, queries, plan, impl):
        n_obj, s, q = bucket.key
        k_pad, q_pad, l_pad = (bucket.pads["k_pad"], bucket.pads["q_pad"],
                               bucket.pads["l_pad"])
        los, his, refs = [], [], []
        for i, query in zip(bucket.indices, queries):
            lo, hi = plan.prep[i]
            pad = k_pad - lo.shape[0]
            # zero-volume padding: lo = hi = +inf clips every overlap to 0
            los.append(np.pad(lo, ((0, pad), (0, 0)),
                              constant_values=np.inf))
            his.append(np.pad(hi, ((0, pad), (0, 0)),
                              constant_values=np.inf))
            refs.append(np.asarray(query.ref, np.float32))
        if self.fused_ehvi:
            return self._exec_ehvi_fused(bucket, queries, los, his, refs,
                                         impl)
        ps = []
        for query in queries:
            samples = (query.samples if query.samples is not None
                       else _materialise_ehvi_draws(query, s, q))
            # +inf candidates gain nothing and are sliced off below
            ps.append(np.stack(
                [np.pad(np.asarray(sm, np.float32),
                        ((0, 0), (0, q_pad - q)), constant_values=np.inf)
                 for sm in samples]))
        parts = [jnp.asarray(np.stack(a).astype(np.float32))
                 for a in (los, his, refs, ps)]
        parts = self._pad_lanes(parts, l_pad)
        # all four parts are host-assembled fresh every step (np.stack ->
        # device transfer), so donation is unconditionally alias-safe
        launch = self._launch("ehvi", _ehvi_box_launch,
                              _ehvi_box_launch_donated)
        out = launch(*parts)
        return [np.asarray(out[j])[:q] for j in range(len(queries))]

    def _exec_ehvi_fused(self, bucket, queries, los, his, refs, impl):
        """One ``kernels.fused_ehvi`` launch for the bucket: the draw
        affine runs inside the kernel, so the (L, D, S, q) raw-scale
        draw tensor never round-trips through HBM. Sample-form queries
        still fuse via the identity affine (mu = 0, var = 1, y = eps):
        the kernel then reproduces their precomputed draws exactly."""
        from repro.kernels.fused_ehvi import fused_ehvi_launch_fn
        n_obj, s, q = bucket.key
        k_pad, q_pad, l_pad = (bucket.pads["k_pad"], bucket.pads["q_pad"],
                               bucket.pads["l_pad"])
        pq = q_pad - q
        # exact-shape draws for every posterior-form lane of the bucket
        # in ONE dispatch — normal(key, (n_mc, q)) per objective, the
        # identical stream _draw_launch and the per-session loop consume
        key_rows = [jnp.asarray(k) for query in queries
                    if query.samples is None for k in query.keys]
        eps_all = (jax.vmap(lambda k: jax.random.normal(k, (s, q)))(
            jnp.stack(key_rows)) if key_rows else None)
        mus, vars_, yms, yss, epss = [], [], [], [], []
        off = 0
        for query in queries:
            if query.samples is None:
                # padded candidates carry mu = +inf / var = 0: their
                # draws land at +inf and gain nothing
                mus.append(np.pad(
                    np.stack([np.asarray(m, np.float32)
                              for m in query.mu]),
                    ((0, 0), (0, pq)), constant_values=np.inf))
                vars_.append(np.pad(
                    np.stack([np.asarray(v, np.float32)
                              for v in query.var]), ((0, 0), (0, pq))))
                yms.append(np.asarray(query.y_mean, np.float32))
                yss.append(np.asarray(query.y_std, np.float32))
                eps = eps_all[off:off + n_obj]
                off += n_obj
                if pq:
                    eps = jnp.pad(eps, ((0, 0), (0, 0), (0, pq)))
                epss.append(eps)
            else:
                # identity affine; the +inf pad rides on the samples
                mus.append(np.zeros((n_obj, q_pad), np.float32))
                vars_.append(np.ones((n_obj, q_pad), np.float32))
                yms.append(np.zeros((n_obj,), np.float32))
                yss.append(np.ones((n_obj,), np.float32))
                epss.append(jnp.asarray(np.stack(
                    [np.pad(np.asarray(sm, np.float32),
                            ((0, 0), (0, pq)), constant_values=np.inf)
                     for sm in query.samples])))
        parts = [jnp.asarray(np.stack(a).astype(np.float32))
                 for a in (los, his, refs, mus, vars_, yms, yss)]
        parts.append(jnp.stack(epss))
        parts = self._pad_lanes(parts, l_pad)
        r_impl = resolve_impl(impl, cells=l_pad * s * q_pad * k_pad,
                              shards=self.lane_shards)
        # every argument is rebuilt per step (host-assembled stacks,
        # fresh draws), so the donating twin is alias-safe here too
        launch = self._launch("fused_ehvi",
                              fused_ehvi_launch_fn(donate=False),
                              fused_ehvi_launch_fn(donate=True))
        out = launch(*parts, impl=r_impl)
        return [np.asarray(out[j])[:q] for j in range(len(queries))]

    def _exec_fit(self, bucket, queries, plan, impl):
        """One ``kernels.fused_fit`` launch for the bucket: pack the raw
        observations host-side (vectorised standardisation, zero-padded
        lanes), overlay warm-start rows, fit every lane in one launch,
        and hand each query ``(stack, lane)`` into the bucket's fitted
        ``BatchedGP``. Only the warm-start rows are donated — the
        packed x/y/mask become the stack the posterior legs query, so
        they must outlive the launch."""
        from repro.kernels.fused_fit import fused_fit_launch_fn
        d, steps, noise = bucket.key
        n_pad, m_pad = bucket.pads["n_pad"], bucket.pads["m_pad"]
        xs = [np.asarray(query.x, np.float32) for query in queries]
        ys = [np.asarray(query.y, np.float32) for query in queries]
        ns = [int(yi.shape[0]) for yi in ys]
        if m_pad > len(queries):   # padded lanes repeat lane 0, thrown away
            extra = m_pad - len(queries)
            xs += [xs[0]] * extra
            ys += [ys[0]] * extra
            ns += [ns[0]] * extra
        x_np, ysd, mask_np, y_mean, y_std = _pack_fit_lanes(
            xs, ys, ns, n_pad)
        ils = np.zeros((m_pad, d), np.float32)
        isf = np.zeros((m_pad,), np.float32)
        for j, query in enumerate(queries):
            if query.init_ls is not None:
                ils[j] = np.asarray(query.init_ls, np.float32)
                isf[j] = np.float32(query.init_sf)
        gx = jnp.asarray(x_np)
        gy = jnp.asarray(ysd)
        gmask = jnp.asarray(mask_np)
        # all five launch args are host-built fresh above (device
        # transfers of new numpy buffers), so donation is alias-safe
        # without the single-query guard; only gils/gisf (the donated
        # positions) die at launch — x/y/mask stay live to seed the
        # returned BatchedGP
        gils = jnp.asarray(ils)
        gisf = jnp.asarray(isf)
        r_impl = resolve_impl(impl, cells=m_pad * n_pad * n_pad * steps,
                              shards=self.lane_shards)
        launch = self._launch("fused_fit",
                              fused_fit_launch_fn(donate=False),
                              fused_fit_launch_fn(donate=True))
        log_ls, log_sf, chol, alpha = launch(
            gx, gy, gmask, gils, gisf, steps=steps, noise=noise,
            impl=r_impl)
        stack = BatchedGP(gx, gy, gmask, jnp.asarray(y_mean),
                          jnp.asarray(y_std), log_ls, log_sf, noise,
                          chol, alpha, jnp.asarray(ns, jnp.int32))
        return [(stack, j) for j in range(len(queries))]
