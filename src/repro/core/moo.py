"""Multi-objective optimisation (paper §III-D).

Each objective and each constraint gets its OWN model (GP or RGPE
ensemble) — treated as independent, so the approach applies without
correlation priors and workloads optimised under different objective
sets can still share models. Acquisition: MC expected hypervolume
improvement over the (2-objective) posterior, weighted by the
probability of feasibility under every constraint.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .acquisition import mc_ehvi, pareto_front, probability_of_feasibility
from .bo import (BOConfig, KarasuContext, ProfileFn,
                 _model_posteriors_karasu, _model_posteriors_naive,
                 _feasible)
from .encoding import SearchSpace
from .repository import Repository
from .types import BOResult, Constraint, Objective, Observation


def run_search_moo(
    space: SearchSpace,
    profile_fn: ProfileFn,
    objectives: Sequence[Objective],
    constraints: Sequence[Constraint] = (),
    *,
    method: str = "naive",            # naive | karasu
    repository: Optional[Repository] = None,
    bo_config: BOConfig = BOConfig(),
    seed: int = 0,
    n_mc: int = 64,
) -> BOResult:
    assert len(objectives) == 2, "MC-EHVI path implemented for 2 objectives"
    cfg = bo_config
    key = jax.random.PRNGKey(seed)
    rng = np.random.default_rng(seed)
    measures = [o.name for o in objectives] + [c.name for c in constraints]
    xq_all = space.all_encoded()
    ctx = (KarasuContext(repository, space, noise=cfg.noise)
           if method == "karasu" and repository is not None else None)

    observations: List[Observation] = []
    profiled: set = set()
    best_idx: List[int] = []
    stopped_at = cfg.max_iters

    def profile(ci: int):
        config = space.configs[ci]
        m, metr = profile_fn(config)
        observations.append(Observation(config=config, x=xq_all[ci],
                                        measures=m, metrics=metr))
        profiled.add(ci)
        best_idx.append(len(observations) - 1)

    for ci in rng.choice(len(space), size=min(cfg.n_init, len(space)),
                         replace=False):
        profile(int(ci))

    for it in range(len(observations), cfg.max_iters):
        remaining = [i for i in range(len(space)) if i not in profiled]
        if not remaining:
            stopped_at = it
            break
        xq = xq_all[remaining]

        if method == "karasu" and repository is not None:
            post, _sel = _model_posteriors_karasu(
                observations, measures, cfg, ctx,
                jax.random.fold_in(key, it), xq)
        else:
            post = _model_posteriors_naive(observations, measures, cfg, xq)

        # raw-scale posterior samples per objective
        samples = []
        for oi, obj in enumerate(objectives):
            p = post[obj.name]
            k = jax.random.fold_in(key, 1000 + it * 10 + oi)
            eps = jax.random.normal(k, (n_mc, xq.shape[0]))
            s = (p["mu"][None] + eps * jnp.sqrt(p["var"])[None])
            samples.append(np.asarray(s * p["y_std"] + p["y_mean"]))

        feas_obs = [o for o in observations if _feasible(o, constraints)] \
            or observations
        observed = np.array([[o.measures[objectives[0].name],
                              o.measures[objectives[1].name]]
                             for o in feas_obs])
        ref = observed.max(axis=0) * 1.1 + 1e-9
        acq = mc_ehvi(samples[0], samples[1], observed, ref)

        for c in constraints:
            cp = post[c.name]
            ub_std = (c.upper_bound - cp["y_mean"]) / cp["y_std"]
            pof = np.asarray(probability_of_feasibility(
                cp["mu"], cp["var"], float(ub_std)))
            acq = acq * pof

        profile(remaining[int(np.argmax(acq))])

    return BOResult(observations=observations, best_index_per_iter=best_idx,
                    stopped_at=stopped_at,
                    meta={"method": method, "moo": True,
                          "objectives": [o.name for o in objectives]})


def pareto_of_result(result: BOResult, objectives: Sequence[Objective],
                     constraints: Sequence[Constraint] = ()) -> np.ndarray:
    pts = np.array([[o.measures[objectives[0].name],
                     o.measures[objectives[1].name]]
                    for o in result.observations
                    if _feasible(o, constraints)])
    if len(pts) == 0:
        return np.empty((0, 2))
    return pareto_front(pts)
