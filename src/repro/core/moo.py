"""Multi-objective optimisation (paper §III-D).

Each objective and each constraint gets its OWN model (GP or RGPE
ensemble) — treated as independent, so the approach applies without
correlation priors and workloads optimised under different objective
sets can still share models. Acquisition: MC expected hypervolume
improvement over the posterior (2 objectives via the staircase
envelope, n >= 3 via the non-dominated box decomposition in
``core/acquisition.py``), weighted by the probability of feasibility
under every constraint.

``run_search_moo`` is a thin driver over the multi-tenant
``SearchService`` (one slot, synchronous executor): MOO tenants use the
same fused fit / RGPE-weight / grid-posterior launches as
single-objective ones, so single- and multi-objective searches mix in
one serving step instead of living on separate code paths.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .acquisition import pareto_of_observations
from .bo import BOConfig, ProfileFn
from .encoding import SearchSpace
from .repository import Repository
from .types import BOResult, Constraint, Objective


def run_search_moo(
    space: SearchSpace,
    profile_fn: ProfileFn,
    objectives: Sequence[Objective],
    constraints: Sequence[Constraint] = (),
    *,
    method: str = "naive",            # naive | karasu
    repository: Optional[Repository] = None,
    bo_config: BOConfig = BOConfig(),
    seed: int = 0,
    n_mc: int = 64,
    fuse_posteriors: bool = True,
    fuse_samples: bool = True,
) -> BOResult:
    assert len(objectives) >= 2, "MOO needs at least 2 objectives"
    # imported here: serve sits above core in the layering, and the
    # driver is the one place core reaches back up into it
    from repro.serve.search_service import SearchRequest, SearchService

    svc = SearchService(repository, slots=1,
                        fuse_posteriors=fuse_posteriors,
                        fuse_samples=fuse_samples)
    svc.submit(SearchRequest(space, profile_fn, None, constraints,
                             method=method, bo_config=bo_config, seed=seed,
                             objectives=tuple(objectives), n_mc=n_mc))
    completion, = svc.run()
    return completion.result


def pareto_of_result(result: BOResult, objectives: Sequence[Objective],
                     constraints: Sequence[Constraint] = ()) -> np.ndarray:
    return pareto_of_observations(result.observations, objectives,
                                  constraints)
