"""Ambient distribution context.

Model code is mesh-agnostic; the launcher installs a ``DistContext`` that
tells distribution-aware layers (MoE expert parallelism, sequence-parallel
attention) which mesh/axes to use. When no context is installed (unit
tests, single-host CPU), layers fall back to purely local math.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def shard_map(f, mesh: Mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across jax versions: newer releases expose it at
    the top level (keyword ``check_vma``); older ones only under
    ``jax.experimental.shard_map`` where the same flag is ``check_rep``.
    Every shard-mapped launch in this package routes through here so the
    plan executor and the model layers agree on one resolution."""
    top = getattr(jax, "shard_map", None)
    if top is not None:
        return top(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def mesh_axis_size(mesh: Optional[Mesh], axis: str) -> int:
    """Size of one named mesh axis; 1 when the mesh is absent OR simply
    does not carry the axis (a data-only ``("data",)`` mesh has no
    model axis — that is a size-1 degree of parallelism, not an
    error)."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(axis, 1))


@dataclasses.dataclass
class DistContext:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)  # batch shards over these
    model_axis: str = "model"
    # expert parallelism mode: none | allgather | a2a
    ep_mode: str = "none"
    # FSDP axis for expert weights (huge MoE archs); None = no FSDP
    fsdp_axis: Optional[str] = None
    # sequence axis used for context parallelism in long-prefill shapes
    seq_axis: Optional[str] = None

    @property
    def model_size(self) -> int:
        # absent axes are size 1, NOT a KeyError: a data-only mesh is a
        # perfectly valid context for layers that never shard weights
        return mesh_axis_size(self.mesh, self.model_axis)

    @property
    def data_size(self) -> int:
        """Product of the batch-axis sizes present on the mesh."""
        out = 1
        for a in self.batch_axes:
            out *= mesh_axis_size(self.mesh, a)
        return out

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.batch_axes) + (self.model_axis,)


_CURRENT = DistContext()


def get_context() -> DistContext:
    return _CURRENT


def set_context(ctx: DistContext) -> None:
    global _CURRENT
    _CURRENT = ctx


@contextlib.contextmanager
def use_context(ctx: DistContext):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = ctx
    try:
        yield ctx
    finally:
        _CURRENT = prev
