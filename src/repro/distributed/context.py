"""Ambient distribution context.

Model code is mesh-agnostic; the launcher installs a ``DistContext`` that
tells distribution-aware layers (MoE expert parallelism, sequence-parallel
attention) which mesh/axes to use. When no context is installed (unit
tests, single-host CPU), layers fall back to purely local math.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

from jax.sharding import Mesh


@dataclasses.dataclass
class DistContext:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)  # batch shards over these
    model_axis: str = "model"
    # expert parallelism mode: none | allgather | a2a
    ep_mode: str = "none"
    # FSDP axis for expert weights (huge MoE archs); None = no FSDP
    fsdp_axis: Optional[str] = None
    # sequence axis used for context parallelism in long-prefill shapes
    seq_axis: Optional[str] = None

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.batch_axes) + (self.model_axis,)


_CURRENT = DistContext()


def get_context() -> DistContext:
    return _CURRENT


def set_context(ctx: DistContext) -> None:
    global _CURRENT
    _CURRENT = ctx


@contextlib.contextmanager
def use_context(ctx: DistContext):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = ctx
    try:
        yield ctx
    finally:
        _CURRENT = prev
