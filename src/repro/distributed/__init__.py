from .context import (DistContext, get_context, mesh_axis_size, set_context,
                      shard_map, use_context)

__all__ = ["DistContext", "get_context", "mesh_axis_size", "set_context",
           "shard_map", "use_context"]
