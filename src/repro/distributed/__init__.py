from .context import DistContext, get_context, set_context, use_context

__all__ = ["DistContext", "get_context", "set_context", "use_context"]
