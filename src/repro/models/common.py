"""Common building blocks for the pure-JAX model zoo.

No flax/haiku dependency: parameters are nested dicts of jnp arrays,
initialised by explicit ``init_*`` functions and consumed by pure
``apply``-style functions. Layer stacks are built by vmapping the unit
initialiser over a leading ``layer`` axis and scanning the unit body, so
the lowered HLO contains a single unit body regardless of depth.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of jnp arrays


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config to describe every architecture in the assigned pool.

    Block kinds (``block_pattern`` entries, repeated cyclically over
    ``n_layers``):
      - ``attn``         full-attention transformer block
      - ``local_attn``   sliding-window attention block
      - ``mamba2``       Mamba2 SSD block
      - ``mlstm``        xLSTM matrix-LSTM block
      - ``slstm``        xLSTM scalar-LSTM block
      - ``shared_attn``  weight-tied global attention block (zamba-style)
    """

    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab: int = 512
    block_pattern: Tuple[str, ...] = ("attn",)
    # attention
    window: int = 0  # sliding-window size for local_attn blocks (0 = full)
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # 0 -> same as rope_theta
    use_qk_norm: bool = False
    use_post_norm: bool = False  # gemma-style sandwich norm
    use_bias: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False  # arctic: dense MLP branch parallel to MoE
    moe_capacity_factor: float = 2.0  # EP modes drop slots beyond capacity
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # zamba-style shared block
    shared_period: int = 0  # apply shared_attn every N backbone layers
    shared_lora_rank: int = 0
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500
    # vlm stub
    n_image_patches: int = 0
    # numerics / embeddings
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma multiplies embeds by sqrt(d)
    norm_eps: float = 1e-6
    # training-time knobs (can be overridden per launch config)
    remat: bool = True
    remat_policy: str = "full"  # full | dots (dots_with_no_batch_dims)
    seq_shard_activations: bool = False  # Megatron-style sequence parallel
    attn_impl: str = "xla"  # xla | pallas | pallas_interpret
    moe_impl: str = "ragged"  # ragged | dense

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """The per-layer block kinds for the full depth."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def unit_size(self) -> int:
        """Layers per scan unit (= len(block_pattern), padded to divide)."""
        return len(self.block_pattern)

    @property
    def n_units(self) -> int:
        if self.n_layers % self.unit_size != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"block_pattern length {self.unit_size}"
            )
        return self.n_layers // self.unit_size

    def param_count(self) -> int:
        """Parameter count via shape-only init (no allocation)."""
        from .registry import build_model  # lazy: avoid circular import
        shapes = jax.eval_shape(build_model(self).init,
                                jax.random.PRNGKey(0))
        return sum(int(math.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k of n_experts)."""
        total = self.param_count()
        if self.n_experts and self.top_k:
            # expert weights: 3 matrices per expert per moe layer
            n_moe_layers = sum(1 for k in self.layer_kinds if k == "attn" or k == "local_attn")
            expert_params = n_moe_layers * self.n_experts * 3 * self.d_model * self.moe_d_ff
            active_expert = expert_params * self.top_k // self.n_experts
            return total - expert_params + active_expert
        return total


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype, use_bias: bool = False,
               scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}  # (1 + scale) parametrisation


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dtype)


def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": _normal(key, (vocab, d), 1.0, dtype)}


def embed(p: Params, ids: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0).astype(compute_dtype)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    # einsum (not x @ table.T): the explicit contraction keeps GSPMD from
    # all-gathering grad_logits over the vocab axis in the backward pass
    return jnp.einsum("bsd,vd->bsv", x, p["table"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype, use_bias: bool = False) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d_model, d_ff, dtype, use_bias),
        "up": init_dense(k2, d_model, d_ff, dtype, use_bias),
        "down": init_dense(k3, d_ff, d_model, dtype, use_bias,
                           scale=1.0 / math.sqrt(d_ff)),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


# ---------------------------------------------------------------------------
# Stacked-layer helpers
# ---------------------------------------------------------------------------


def stack_init(init_fn, key, n: int) -> Params:
    """vmap an initialiser over a leading layer axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def scan_blocks(body, carry, stacked_params, *, remat: bool, length: int):
    """lax.scan over stacked layer params with optional full remat."""
    fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    return jax.lax.scan(fn, carry, stacked_params, length=length)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def take_layer(stacked: Params, i) -> Params:
    return jax.tree.map(lambda x: x[i], stacked)
