"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM is the paper's parallel-trainable cell:
    C_t = f_t C_{t-1} + i_t v_t k_t^T ,   n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
which is exactly the SSD recurrence with per-head B=k, C=q, x=v,
decay=f, dt=i — so it reuses the chunked ``ssm_scan`` kernel (one call
with hd=head_dim for the numerator, one with hd=1 for the normaliser).
The paper's running-max stabiliser is omitted in the parallel path (gates
are bounded here: f = sigmoid, i = exp(clip(ĩ))); noted in DESIGN.md.

sLSTM has recurrent gate preactivations (R h_{t-1}) and is inherently
sequential: lax.scan over time with block-diagonal (per-head) recurrence,
exponential gating and the m-stabiliser from the paper.

Block layout follows the official xLSTM blocks: mLSTM block is a
pre-LN up-projection (pf=2) sandwich with causal conv + gating; sLSTM
block is pre-LN with a gated (pf=4/3) FFN after the cell. d_ff=0 in the
assigned config — the blocks own their projections.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import ssm_scan
from .common import (ModelConfig, Params, _normal, dense, init_dense,
                     init_rmsnorm, rmsnorm)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    d_inner = 2 * d            # pf = 2 up-projection
    hd = d_inner // h
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    return {
        "up": init_dense(ks[0], d, 2 * d_inner, dt),   # [x_inner, z gate]
        "conv_w": _normal(ks[1], (4, d_inner), 0.5, dt),
        "conv_b": jnp.zeros((d_inner,), dt),
        "wq": init_dense(ks[2], d_inner, d_inner, dt),
        "wk": init_dense(ks[3], d_inner, d_inner, dt),
        "wv": init_dense(ks[4], d_inner, d_inner, dt),
        "wi": init_dense(ks[5], d_inner, h, dt),       # input gate (exp)
        "wf": init_dense(ks[6], d_inner, h, dt),       # forget gate (sigmoid)
        "norm": init_rmsnorm(d_inner, dt),
        "down": init_dense(ks[7], d_inner, d, dt,
                           scale=1.0 / math.sqrt(d_inner)),
    }


def _conv4(x, w, b, state: Optional[jnp.ndarray]):
    k = w.shape[0]
    if state is None:
        padding = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        padding = state.astype(x.dtype)
    xp = jnp.concatenate([padding, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    new_state = xp[:, xp.shape[1] - (k - 1):]
    return jax.nn.silu(out + b.astype(x.dtype)), new_state


def mlstm(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
          cache: Optional[Dict[str, jnp.ndarray]] = None
          ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    b, s, d = x.shape
    h = cfg.n_heads
    up = dense(p["up"], x)
    xi, z = jnp.split(up, 2, axis=-1)          # (b, s, 2d) each
    d_inner = xi.shape[-1]
    hd = d_inner // h

    conv_state = cache.get("conv") if cache is not None else None
    xc, new_conv = _conv4(xi, p["conv_w"], p["conv_b"], conv_state)

    q = dense(p["wq"], xc).reshape(b, s, h, hd)
    k = dense(p["wk"], xc).reshape(b, s, h, hd) / math.sqrt(hd)
    v = dense(p["wv"], xi).reshape(b, s, h, hd)
    i_gate = jnp.exp(jnp.clip(dense(p["wi"], xc).astype(jnp.float32),
                              -10.0, 10.0))    # (b, s, h)
    f_gate = jax.nn.sigmoid(dense(p["wf"], xc).astype(jnp.float32))

    num_prev = cache.get("num") if cache is not None else None
    den_prev = cache.get("den") if cache is not None else None
    impl = "xla"
    # numerator: state (hd, hd_k); normaliser: state (1, hd_k)
    y_num, num_state = ssm_scan(v, i_gate, f_gate, k, q,
                                initial_state=num_prev, impl=impl)
    ones = jnp.ones((b, s, h, 1), v.dtype)
    y_den, den_state = ssm_scan(ones, i_gate, f_gate, k, q,
                                initial_state=den_prev, impl=impl)
    y = y_num / jnp.maximum(jnp.abs(y_den), 1.0).astype(y_num.dtype)
    y = y.reshape(b, s, d_inner)

    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = dense(p["down"], y)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "num": num_state, "den": den_state}
    return out, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner = 2 * cfg.d_model
    h = cfg.n_heads
    hd = d_inner // h
    return {
        "conv": jnp.zeros((batch, 3, d_inner), dtype),
        "num": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "den": jnp.zeros((batch, h, 1, hd), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 7)
    dt = cfg.param_dtype
    d_ff = int(d * 4 / 3)
    return {
        # fused input weights for gates [z, i, f, o]
        "w_in": init_dense(ks[0], d, 4 * d, dt),
        # block-diagonal recurrent weights, per head: (h, hd, 4*hd)
        "r": _normal(ks[1], (h, hd, 4 * hd), 1.0 / math.sqrt(hd), dt),
        "norm": init_rmsnorm(d, dt),
        "up_gate": init_dense(ks[2], d, d_ff, dt),
        "up": init_dense(ks[3], d, d_ff, dt),
        "down": init_dense(ks[4], d_ff, d, dt, scale=1.0 / math.sqrt(d_ff)),
    }


def slstm(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
          cache: Optional[Dict[str, jnp.ndarray]] = None
          ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    w = dense(p["w_in"], x).astype(jnp.float32)  # (b, s, 4d)
    r = p["r"].astype(jnp.float32)

    if cache is not None:
        state0 = (cache["h"].astype(jnp.float32),
                  cache["c"].astype(jnp.float32),
                  cache["n"].astype(jnp.float32),
                  cache["m"].astype(jnp.float32))
    else:
        zero = jnp.zeros((b, h, hd), jnp.float32)
        state0 = (zero, zero, zero, jnp.full((b, h, 1), -10.0, jnp.float32))

    def step(state, wt):
        hp, cp, np_, mp = state  # (b, h, hd) each; mp: (b, h, 1)
        rec = jnp.einsum("bhd,hde->bhe", hp, r)            # (b, h, 4hd)
        pre = wt.reshape(b, h, 4 * hd) + rec
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        # exponential gating with stabiliser (per paper): scalar per head
        it_ = jnp.mean(it, axis=-1, keepdims=True)
        ft_ = jnp.mean(ft, axis=-1, keepdims=True)
        mt = jnp.maximum(ft_ + mp, it_)
        i_s = jnp.exp(it_ - mt)
        f_s = jnp.exp(ft_ + mp - mt)
        ct = f_s * cp + i_s * zt
        nt = f_s * np_ + i_s
        ht = ot * ct / jnp.maximum(nt, 1e-6)
        return (ht, ct, nt, mt), ht

    (hT, cT, nT, mT), ys = jax.lax.scan(step, state0, w.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)

    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = dense(p["down"], jax.nn.silu(dense(p["up_gate"], y))
                * dense(p["up"], y))

    new_cache = None
    if cache is not None:
        new_cache = {"h": hT, "c": cT, "n": nT, "m": mT}
    return out, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h = cfg.n_heads
    hd = cfg.d_model // h
    zero = jnp.zeros((batch, h, hd), jnp.float32)
    return {"h": zero, "c": zero, "n": zero,
            "m": jnp.full((batch, h, 1), -10.0, jnp.float32)}
