"""Mamba2 (SSD) block.

Parallel (training/prefill) mode uses the chunked SSD algorithm:
within-chunk quadratic attention-like term + across-chunk recurrent state
passing (lax.scan over chunks). The Pallas kernel in
``repro.kernels.ssm_scan`` implements the same chunked algorithm with VMEM
tiling; ``ops.ssm_scan(..., impl=...)`` dispatches, and this module calls
through it so the dry-run sees the XLA path while TPU runs the kernel.

Decode mode carries (conv_state, ssm_state) and costs O(1) per token —
this is what makes the long_500k cells runnable for SSM/hybrid archs.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import ssm_scan
from .common import ModelConfig, Params, _normal, dense, init_dense, init_rmsnorm, rmsnorm


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def init_mamba2(key, cfg: ModelConfig) -> Params:
    d_inner, n_heads = _dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    dt = cfg.param_dtype
    # in_proj -> [z (gate), x, B, C, dt] fused as in the reference impl
    d_proj = 2 * d_inner + 2 * n + n_heads
    p = {
        "in_proj": init_dense(k1, cfg.d_model, d_proj, dt),
        "conv_w": _normal(k2, (cfg.ssm_conv, d_inner + 2 * n),
                          1.0 / math.sqrt(cfg.ssm_conv), dt),
        "conv_b": jnp.zeros((d_inner + 2 * n,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm": init_rmsnorm(d_inner, dt),
        "out_proj": init_dense(k3, d_inner, cfg.d_model, dt,
                               scale=1.0 / math.sqrt(d_inner)),
    }
    return p


def _split_proj(proj: jnp.ndarray, cfg: ModelConfig):
    d_inner, n_heads = _dims(cfg)
    n = cfg.ssm_state
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d. xBC: (b, s, c); w: (k, c).

    Returns (out, new_state) where state caches the last k-1 inputs.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], k - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)  # (b, s+k-1, c)
    out = jnp.zeros_like(xBC)
    for i in range(k):
        out = out + xp[:, i:i + xBC.shape[1]] * w[i].astype(xBC.dtype)
    out = out + b.astype(xBC.dtype)
    new_state = xp[:, xp.shape[1] - (k - 1):]
    return jax.nn.silu(out), new_state


def mamba2(
    p: Params,
    x: jnp.ndarray,  # (b, s, d_model)
    cfg: ModelConfig,
    *,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    d_inner, n_heads = _dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_head_dim
    b, s, _ = x.shape

    proj = dense(p["in_proj"], x)
    z, xBC, dt_raw = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])            # (b, s, heads)
    A = -jnp.exp(p["A_log"])                         # (heads,)

    conv_state = cache.get("conv") if cache is not None else None
    xBC, new_conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                       conv_state)
    xs, B, C = jnp.split(xBC, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(b, s, n_heads, hd)
    # B, C shared across heads (n_groups=1)
    decay = jnp.exp(dt * A[None, None, :])           # (b, s, heads)

    ssm_prev = cache.get("ssm") if cache is not None else None
    y, ssm_state = ssm_scan(
        xs, dt, decay, B, C,
        initial_state=ssm_prev,
        impl=cfg.attn_impl if cfg.attn_impl.startswith("pallas") else "xla",
    )
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv_state.astype(cache["conv"].dtype),
                     "ssm": ssm_state.astype(cache["ssm"].dtype)}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    d_inner, n_heads = _dims(cfg)
    n = cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * n), dtype),
        "ssm": jnp.zeros((batch, n_heads, cfg.ssm_head_dim, n), dtype),
    }
