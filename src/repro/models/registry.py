"""Unified model bundle API over the zoo.

Every architecture exposes the same four entry points so the training
loop, serving engine, and dry-run launcher are architecture-agnostic:

    bundle.init(key)                          -> params
    bundle.train_logits(params, batch)        -> (logits, aux_loss)
    bundle.init_cache(params, batch_size, max_len, batch) -> caches
    bundle.decode_step(params, caches, tokens, positions) -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, Params
from . import transformer as tf
from . import whisper as wh


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[Any], Params]
    train_logits: Callable[[Params, Dict[str, jnp.ndarray]], Any]
    init_cache: Callable[..., Params]
    decode_step: Callable[..., Any]


def _lm_bundle(cfg: ModelConfig) -> ModelBundle:
    def init(key):
        return tf.init_lm(key, cfg)

    def train_logits(params, batch):
        logits, _, aux = tf.lm_forward(
            params, batch["tokens"], cfg,
            image_embeds=batch.get("image_embeds"),
            image_mask=batch.get("image_mask"))
        return logits, aux

    def init_cache(params, batch_size, max_len, batch=None,
                   dtype=jnp.bfloat16):
        return tf.init_decode_cache(cfg, batch_size, max_len, dtype=dtype)

    def decode_step(params, caches, tokens, positions):
        logits, new_caches, _ = tf.lm_forward(
            params, tokens, cfg, positions=positions, caches=caches)
        return logits, new_caches

    return ModelBundle(cfg, init, train_logits, init_cache, decode_step)


def _encdec_bundle(cfg: ModelConfig) -> ModelBundle:
    def init(key):
        return wh.init_encdec(key, cfg)

    def train_logits(params, batch):
        enc_out = wh.encode(params, batch["frame_embeds"], cfg)
        logits, _ = wh.decode(params, batch["tokens"], enc_out, cfg)
        return logits, jnp.zeros((), jnp.float32)

    def init_cache(params, batch_size, max_len, batch=None,
                   dtype=jnp.bfloat16):
        assert batch is not None and "frame_embeds" in batch, \
            "encoder-decoder cache needs frame_embeds to precompute cross KV"
        enc_out = wh.encode(params, batch["frame_embeds"], cfg)
        return wh.init_encdec_cache(params, enc_out, cfg, batch_size,
                                    max_len, dtype=dtype)

    def decode_step(params, caches, tokens, positions):
        logits, new_caches = wh.decode(params, tokens, None, cfg,
                                       positions=positions, caches=caches)
        return logits, new_caches

    return ModelBundle(cfg, init, train_logits, init_cache, decode_step)


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.is_encoder_decoder:
        return _encdec_bundle(cfg)
    return _lm_bundle(cfg)
