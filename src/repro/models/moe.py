"""Mixture-of-Experts FFN with expert parallelism.

Routing: softmax over all experts -> top-k -> renormalised combine weights
(qwen3 style; arctic uses the same with k=2 plus a dense residual branch).

Compute paths:
  - ``dense``   every expert on every token (einsum). Oracle for tests.
  - ``ragged``  sort-by-expert + jax.lax.ragged_dot (dropless grouped GEMM,
                the TPU-native analogue of megablocks). Default.

Expert parallelism (installed DistContext, ep_mode != "none"):
  - ``allgather``  shard_map over the model axis: every model shard sees
                   the full local-batch token set (activations arrive
                   replicated over `model`, GSPMD inserts the all-gather),
                   compacts the slots routed to its E/ep local experts into
                   a capacity-bounded buffer, runs the grouped GEMM, and
                   scatter-adds partial outputs combined with one psum.
  - ``a2a``        capacity-bounded all_to_all dispatch: each shard sends
                   only the tokens routed to remote experts (2 all_to_alls
                   of ~(tokens*topk/ep, d_model)). Beyond-paper
                   optimisation for the collective-bound MoE cells.

Capacity semantics: slots beyond ``moe_capacity_factor * expected`` per
shard are dropped (their combine weight contributes nothing) — standard
capacity-based MoE behaviour; the dense/ragged local paths are dropless.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import get_context, shard_map
from .common import ModelConfig, Params, _normal, init_mlp, mlp


def init_moe(key, cfg: ModelConfig) -> Params:
    ke, kr, kd = jax.random.split(key, 3)
    dt = cfg.param_dtype
    d, dff = cfg.d_model, cfg.moe_d_ff
    ne = cfg.n_experts
    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": _normal(kr, (d, ne), 1.0 / math.sqrt(d), jnp.float32),
        "gate": _normal(k1, (ne, d, dff), 1.0 / math.sqrt(d), dt),
        "up": _normal(k2, (ne, d, dff), 1.0 / math.sqrt(d), dt),
        "down": _normal(k3, (ne, dff, d), 1.0 / math.sqrt(dff), dt),
    }
    if cfg.dense_residual:
        p["dense_mlp"] = init_mlp(kd, d, cfg.d_ff, dt, cfg.use_bias)
    return p


def _route(router: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """x: (T, d) -> (weights (T, k), idx (T, k), aux_loss)."""
    logits = x.astype(jnp.float32) @ router  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # switch-style load balancing aux loss
    ne = router.shape[1]
    density = jnp.mean(jax.nn.one_hot(idx, ne, dtype=jnp.float32), axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * ne
    return weights, idx, aux


def _experts_dense(p: Params, x: jnp.ndarray, weights, idx, top_k: int):
    """Every expert on every token; combine with routing weights."""
    ne = p["gate"].shape[0]
    xg = jnp.einsum("td,edf->tef", x, p["gate"].astype(x.dtype))
    xu = jnp.einsum("td,edf->tef", x, p["up"].astype(x.dtype))
    h = jax.nn.silu(xg) * xu
    y = jnp.einsum("tef,efd->ted", h, p["down"].astype(x.dtype))  # (T,E,d)
    combine = jnp.zeros((x.shape[0], ne), x.dtype)
    combine = combine.at[jnp.arange(x.shape[0])[:, None], idx].set(
        weights.astype(x.dtype))
    return jnp.einsum("ted,te->td", y, combine)


def _grouped_gemm(gate, up, down, xs, group_sizes, dtype,
                  impl: str = "ragged"):
    from repro.kernels.grouped_gemm import grouped_gemm as gmm
    hg = gmm(xs, gate.astype(dtype), group_sizes, impl=impl)
    hu = gmm(xs, up.astype(dtype), group_sizes, impl=impl)
    return gmm(jax.nn.silu(hg) * hu, down.astype(dtype), group_sizes,
               impl=impl)


def _experts_ragged(gate, up, down, x, weights, idx, top_k, n_experts,
                    impl: str = "ragged"):
    """Dropless: sort-by-expert + grouped GEMM over all T*k slots."""
    t, d = x.shape
    flat_idx = idx.reshape(-1)                       # (T*k,)
    order = jnp.argsort(flat_idx)
    token_of = order // top_k
    xs = jnp.take(x, token_of, axis=0)               # sorted by expert
    group_sizes = jnp.bincount(flat_idx, length=n_experts).astype(jnp.int32)
    ys = _grouped_gemm(gate, up, down, xs, group_sizes, x.dtype, impl=impl)
    w_sorted = jnp.take(weights.reshape(-1), order)
    out = jnp.zeros((t, d), ys.dtype).at[token_of].add(
        ys * w_sorted[:, None].astype(ys.dtype))
    return out


def _moe_local(p: Params, x2: jnp.ndarray, cfg: ModelConfig):
    weights, idx, aux = _route(p["router"], x2, cfg.top_k)
    if cfg.moe_impl == "dense":
        out = _experts_dense(p, x2, weights, idx, cfg.top_k)
    else:
        impl = "xla" if cfg.moe_impl == "gmm" else "ragged"
        out = _experts_ragged(p["gate"], p["up"], p["down"], x2, weights,
                              idx, cfg.top_k, cfg.n_experts, impl=impl)
    return out, aux


def _compact_by_expert(local_expert_id, valid, e_local, cap):
    """Sort (slot -> local expert) placing invalid slots last; keep `cap`.

    Returns (perm (cap,), group_sizes (e_local,), kept (cap,) bool).
    """
    sort_key = jnp.where(valid, local_expert_id, e_local)
    perm_full = jnp.argsort(sort_key)                # valid groups first
    perm = perm_full[:cap]
    counts = jnp.bincount(sort_key, length=e_local + 1)[:e_local]
    # clip group sizes so their cumsum never exceeds cap
    cum = jnp.cumsum(counts)
    cum_clipped = jnp.minimum(cum, cap)
    group_sizes = jnp.diff(jnp.concatenate([jnp.zeros(1, cum.dtype),
                                            cum_clipped])).astype(jnp.int32)
    kept_rank = jnp.arange(cap)
    kept = kept_rank < cum_clipped[-1]
    return perm, group_sizes, kept


def _expert_specs(ctx):
    """(gate/up, down) PartitionSpecs incl. optional FSDP on the ff dim."""
    axis, fsdp = ctx.model_axis, ctx.fsdp_axis
    if fsdp:
        return P(axis, None, fsdp), P(axis, fsdp, None)
    return P(axis), P(axis)


def _unshard_experts(ctx, gate, up, down):
    """All-gather FSDP-sharded expert weights inside the shard_map body."""
    if ctx.fsdp_axis:
        gate = jax.lax.all_gather(gate, ctx.fsdp_axis, axis=2, tiled=True)
        up = jax.lax.all_gather(up, ctx.fsdp_axis, axis=2, tiled=True)
        down = jax.lax.all_gather(down, ctx.fsdp_axis, axis=1, tiled=True)
    return gate, up, down


def _moe_allgather_ep(p: Params, x2: jnp.ndarray, cfg: ModelConfig):
    """shard_map body: local experts, full local-batch tokens, psum combine."""
    ctx = get_context()
    axis = ctx.model_axis
    ep = ctx.model_size
    e_local = cfg.n_experts // ep

    def body(router, gate, up, down, xb):
        t, d = xb.shape
        gate, up, down = _unshard_experts(ctx, gate, up, down)
        weights, idx, aux = _route(router, xb, cfg.top_k)
        shard = jax.lax.axis_index(axis)
        lo = shard * e_local
        flat_idx = idx.reshape(-1)
        local = (flat_idx >= lo) & (flat_idx < lo + e_local)
        cap = max(8, int(math.ceil(t * cfg.top_k / ep
                                   * cfg.moe_capacity_factor)))
        cap = min(cap, t * cfg.top_k)
        perm, group_sizes, kept = _compact_by_expert(
            flat_idx - lo, local, e_local, cap)
        token_of = perm // cfg.top_k
        xs = jnp.take(xb, token_of, axis=0)          # (cap, d)
        ys = _grouped_gemm(gate, up, down, xs, group_sizes, xb.dtype,
                          impl="xla" if cfg.moe_impl == "gmm"
                          else "ragged")
        w = jnp.take(weights.reshape(-1), perm) * kept
        out = jnp.zeros((t, d), ys.dtype).at[token_of].add(
            ys * w[:, None].astype(ys.dtype))
        out = jax.lax.psum(out, axis)
        aux = jax.lax.pmean(aux, axis)
        for a in ctx.batch_axes:
            aux = jax.lax.pmean(aux, a)
        return out, aux

    bspec = P(ctx.batch_axes)
    gspec, dspec = _expert_specs(ctx)
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(), gspec, gspec, dspec, bspec),
        out_specs=(bspec, P()),
    )(p["router"], p["gate"], p["up"], p["down"], x2)


def _moe_a2a_ep(p: Params, x2: jnp.ndarray, cfg: ModelConfig):
    """shard_map body: capacity-bounded all_to_all expert dispatch."""
    ctx = get_context()
    axis = ctx.model_axis
    ep = ctx.model_size
    e_local = cfg.n_experts // ep

    def body(router, gate, up, down, xb):
        t, d = xb.shape
        gate, up, down = _unshard_experts(ctx, gate, up, down)
        weights, idx, aux = _route(router, xb, cfg.top_k)
        flat_idx = idx.reshape(-1)                   # (T*k,)
        dest = flat_idx // e_local                   # destination shard
        cap = max(8, int(math.ceil(t * cfg.top_k / ep
                                   * cfg.moe_capacity_factor)))
        # rank of each slot within its destination group
        order = jnp.argsort(dest)
        sorted_dest = dest[order]
        rank = jnp.arange(t * cfg.top_k) - jnp.searchsorted(
            sorted_dest, sorted_dest, side="left")
        keep = rank < cap
        # slot in the send buffer; dropped slots write to a trash row
        slot = jnp.where(keep, sorted_dest * cap + rank, ep * cap)
        nbuf = ep * cap + 1
        src_token = order // cfg.top_k
        send_x = jnp.zeros((nbuf, d), xb.dtype).at[slot].set(
            jnp.take(xb, src_token, axis=0))
        send_e = jnp.zeros((nbuf,), jnp.int32).at[slot].set(
            flat_idx[order] % e_local)
        send_valid = jnp.zeros((nbuf,), bool).at[slot].set(keep)

        rx = jax.lax.all_to_all(send_x[:-1].reshape(ep, cap, d),
                                axis, 0, 0).reshape(ep * cap, d)
        re_ = jax.lax.all_to_all(send_e[:-1].reshape(ep, cap),
                                 axis, 0, 0).reshape(ep * cap)
        rv = jax.lax.all_to_all(send_valid[:-1].reshape(ep, cap),
                                axis, 0, 0).reshape(ep * cap)

        perm, group_sizes, kept = _compact_by_expert(
            re_, rv, e_local, ep * cap)
        rx_s = jnp.take(rx, perm, axis=0)
        ys = _grouped_gemm(gate, up, down, rx_s, group_sizes, rx.dtype,
                          impl="xla" if cfg.moe_impl == "gmm"
                          else "ragged")
        ys = ys * kept[:, None]
        y = jnp.zeros((ep * cap, d), ys.dtype).at[perm].set(ys)

        back = jax.lax.all_to_all(y.reshape(ep, cap, d),
                                  axis, 0, 0).reshape(ep * cap, d)
        back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], 0)
        w_sorted = jnp.take(weights.reshape(-1), order)
        contrib = back[slot] * jnp.where(keep, w_sorted, 0.0)[:, None].astype(back.dtype)
        out = jnp.zeros((t, d), back.dtype).at[src_token].add(contrib)
        aux = jax.lax.pmean(aux, axis)
        for a in ctx.batch_axes:
            aux = jax.lax.pmean(aux, a)
        return out, aux

    bspec = P(ctx.batch_axes)
    gspec, dspec = _expert_specs(ctx)
    # the two all_to_alls make the (mathematically model-replicated)
    # outputs unprovable for the varying-axes checker: disable it
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(), gspec, gspec, dspec, bspec),
        out_specs=(bspec, P()),
        check_vma=False,
    )(p["router"], p["gate"], p["up"], p["down"], x2)


def moe(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (out (b, s, d), aux_loss scalar)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    ctx = get_context()
    if ctx.mesh is not None and ctx.ep_mode == "allgather":
        out, aux = _moe_allgather_ep(p, x2, cfg)
    elif ctx.mesh is not None and ctx.ep_mode == "a2a":
        out, aux = _moe_a2a_ep(p, x2, cfg)
    else:
        out, aux = _moe_local(p, x2, cfg)
    out = out.reshape(b, s, d).astype(x.dtype)
    if cfg.dense_residual:
        out = out + mlp(p["dense_mlp"], x)
    return out, aux
