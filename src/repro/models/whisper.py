"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (batch, n_audio_frames, d_model).
The encoder is a non-causal transformer over frames with learned
(sinusoidal-initialised) positions; the decoder is a causal transformer
with cross-attention into the encoder output.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, init_attention, init_kv_cache
from .common import (ModelConfig, Params, dense, embed, init_dense,
                     init_embedding, init_mlp, init_rmsnorm, mlp, rmsnorm,
                     unembed)
from .transformer import _shard_activations


def _sinusoid(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * dim / (d // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    dt = cfg.param_dtype
    return {
        "pre_norm": init_rmsnorm(cfg.d_model, dt),
        "attn": init_attention(k1, cfg),
        "pre_mlp_norm": init_rmsnorm(cfg.d_model, dt),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dt, cfg.use_bias),
    }


def _init_dec_block(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {
        "pre_norm": init_rmsnorm(cfg.d_model, dt),
        "attn": init_attention(k1, cfg),
        "cross_norm": init_rmsnorm(cfg.d_model, dt),
        "cross_attn": init_attention(k2, cfg, cross=True),
        "pre_mlp_norm": init_rmsnorm(cfg.d_model, dt),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dt, cfg.use_bias),
    }


def init_encdec(key, cfg: ModelConfig) -> Params:
    ke, kd, kt, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": init_embedding(kt, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "enc_pos": _sinusoid(cfg.n_audio_frames, cfg.d_model
                             ).astype(cfg.param_dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "final_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }


def encode(params: Params, frame_embeds: jnp.ndarray, cfg: ModelConfig
           ) -> jnp.ndarray:
    """frame_embeds: (b, frames, d) precomputed by the stub frontend."""
    x = frame_embeds.astype(cfg.compute_dtype)
    x = x + params["enc_pos"][None, :x.shape[1]].astype(x.dtype)
    x = _shard_activations(x)

    def body(x, bp):
        h = rmsnorm(bp["pre_norm"], x, cfg.norm_eps)
        h, _ = attention(bp["attn"], h, cfg, causal=False, use_rope=False)
        x = x + h
        h = mlp(bp["mlp"], rmsnorm(bp["pre_mlp_norm"], x, cfg.norm_eps))
        return _shard_activations(x + h), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(bp: Params, enc_out: jnp.ndarray, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    b, f, _ = enc_out.shape
    k = dense(bp["cross_attn"]["wk"], enc_out).reshape(b, f, cfg.n_kv_heads, hd)
    v = dense(bp["cross_attn"]["wv"], enc_out).reshape(b, f, cfg.n_kv_heads, hd)
    return k, v


def decode(
    params: Params,
    tokens: jnp.ndarray,               # (b, s)
    enc_out: Optional[jnp.ndarray],    # (b, frames, d) or None if cached
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    caches: Optional[Params] = None,   # {"self": stacked kv, "cross_k/v"}
) -> Tuple[jnp.ndarray, Optional[Params]]:
    x = embed(params["embed"], tokens, cfg.compute_dtype)
    x = _shard_activations(x)

    cross_k = caches["cross_k"] if caches is not None else None
    cross_v = caches["cross_v"] if caches is not None else None

    def body(x, scanned):
        bp, self_cache, ck, cv = scanned
        h = rmsnorm(bp["pre_norm"], x, cfg.norm_eps)
        h, new_kv = attention(bp["attn"], h, cfg, positions=positions,
                              cache=None if self_cache is None else
                              self_cache["kv"])
        x = x + h
        if ck is None:
            ckv = _cross_kv(bp, enc_out, cfg)
        else:
            ckv = (ck, cv)
        h = rmsnorm(bp["cross_norm"], x, cfg.norm_eps)
        h, _ = attention(bp["cross_attn"], h, cfg, kv=ckv, use_rope=False)
        x = x + h
        h = mlp(bp["mlp"], rmsnorm(bp["pre_mlp_norm"], x, cfg.norm_eps))
        x = _shard_activations(x + h)
        new_cache = {"kv": new_kv} if new_kv is not None else self_cache
        return x, new_cache

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    scanned = (params["dec_blocks"],
               None if caches is None else caches["self"],
               cross_k, cross_v)
    x, new_self = jax.lax.scan(body_fn, x, scanned)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x).astype(jnp.float32)
    new_caches = None
    if caches is not None:
        new_caches = {"self": new_self, "cross_k": cross_k,
                      "cross_v": cross_v}
    return logits, new_caches


def init_encdec_cache(params: Params, enc_out: jnp.ndarray,
                      cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> Params:
    """Self-attn KV caches + precomputed cross KV for every layer."""
    unit = {"kv": init_kv_cache(cfg, batch, max_len, dtype=dtype)}
    self_caches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape).copy(),
        unit)
    ck, cv = jax.vmap(
        lambda bp: _cross_kv(bp, enc_out, cfg))(params["dec_blocks"])
    return {"self": self_caches, "cross_k": ck.astype(dtype),
            "cross_v": cv.astype(dtype)}
