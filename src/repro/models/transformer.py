"""Universal decoder-only LM assembly.

One ``init_lm`` / ``lm_forward`` pair covers the dense, MoE, SSM, hybrid
and VLM-backbone architectures: the per-layer behaviour is selected by
``cfg.block_pattern`` (repeated cyclically), and the whole depth is a
``lax.scan`` over stacked "units" (one unit = one pass over the pattern),
so the HLO contains a single unit body regardless of depth.

Zamba-style weight-tied shared blocks live outside the scanned stack and
are applied inside the unit body with per-unit LoRA deltas.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import get_context
from .attention import attention, init_attention, init_kv_cache
from .common import (ModelConfig, Params, dense, embed, init_dense,
                     init_embedding, init_mlp, init_rmsnorm, mlp, rmsnorm,
                     softcap, unembed, _normal)
from .mamba import init_mamba2, init_mamba_cache, mamba2
from .moe import init_moe, moe
from .xlstm import (init_mlstm, init_mlstm_cache, init_slstm,
                    init_slstm_cache, mlstm, slstm)


def _shard_activations(x: jnp.ndarray, *, seq_parallel: bool = False
                       ) -> jnp.ndarray:
    ctx = get_context()
    if ctx.mesh is None:
        return x
    # shard batch over the longest batch-axis prefix that divides it
    from jax.sharding import NamedSharding
    prod, axes = 1, []
    for a in ctx.batch_axes:
        prod *= ctx.mesh.shape[a]
        if x.shape[0] % prod == 0:
            axes.append(a)
        else:
            break
    # Megatron-style sequence parallelism: between blocks the residual
    # stream is additionally sharded over `model` on the sequence axis,
    # turning per-block all-reduces into reduce-scatter/all-gather pairs
    # (half the on-wire bytes, and the stream stays sharded at rest).
    seq_spec = None
    if seq_parallel and x.ndim >= 3 and x.shape[1] % ctx.model_size == 0:
        seq_spec = ctx.model_axis
    spec = P(tuple(axes) if axes else None, seq_spec,
             *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    p: Dict[str, Any] = {"pre_norm": init_rmsnorm(cfg.d_model, dt)}
    if kind in ("attn", "local_attn"):
        p["attn"] = init_attention(ks[0], cfg)
        if cfg.use_post_norm:
            p["post_norm"] = init_rmsnorm(cfg.d_model, dt)
        p["pre_mlp_norm"] = init_rmsnorm(cfg.d_model, dt)
        if cfg.n_experts:
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt, cfg.use_bias)
        if cfg.use_post_norm:
            p["post_mlp_norm"] = init_rmsnorm(cfg.d_model, dt)
    elif kind == "mamba2":
        p["mamba"] = init_mamba2(ks[0], cfg)
    elif kind == "mlstm":
        p["mlstm"] = init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = init_slstm(ks[0], cfg)
    elif kind == "shared_attn":
        r = max(cfg.shared_lora_rank, 1)
        p["lora_a"] = _normal(ks[0], (2 * cfg.d_model, r),
                              1.0 / math.sqrt(2 * cfg.d_model), dt)
        p["lora_b"] = jnp.zeros((r, cfg.d_model), dt)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def _init_shared(key, cfg: ModelConfig) -> Params:
    """Zamba-style shared transformer block (weight-tied across uses)."""
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {
        "in_norm": init_rmsnorm(2 * cfg.d_model, dt),
        "in_proj": init_dense(ks[0], 2 * cfg.d_model, cfg.d_model, dt),
        "attn": init_attention(ks[1], cfg),
        "pre_mlp_norm": init_rmsnorm(cfg.d_model, dt),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dt, cfg.use_bias),
    }


def _apply_block(
    bp: Params,
    kind: str,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    layer_in_pattern: int,
    shared: Optional[Params],
    embeds0: Optional[jnp.ndarray],
    positions: Optional[jnp.ndarray],
    cache: Optional[Params],
    aux: jnp.ndarray,
):
    new_cache = None
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        h = rmsnorm(bp["pre_norm"], x, cfg.norm_eps)
        h, new_kv = attention(bp["attn"], h, cfg, window=window,
                              positions=positions,
                              cache=None if cache is None else cache["kv"],
                              is_global=(kind == "attn"))
        if cfg.use_post_norm:
            h = rmsnorm(bp["post_norm"], h, cfg.norm_eps)
        x = x + h
        h = rmsnorm(bp["pre_mlp_norm"], x, cfg.norm_eps)
        if cfg.n_experts:
            h, aux_l = moe(bp["moe"], h, cfg)
            aux = aux + aux_l
        else:
            h = mlp(bp["mlp"], h)
        if cfg.use_post_norm:
            h = rmsnorm(bp["post_mlp_norm"], h, cfg.norm_eps)
        x = x + h
        if new_kv is not None:
            new_cache = {"kv": new_kv}
    elif kind == "mamba2":
        h = rmsnorm(bp["pre_norm"], x, cfg.norm_eps)
        h, new_cache = mamba2(bp["mamba"], h, cfg, cache=cache)
        x = x + h
    elif kind == "mlstm":
        h = rmsnorm(bp["pre_norm"], x, cfg.norm_eps)
        h, new_cache = mlstm(bp["mlstm"], h, cfg, cache=cache)
        x = x + h
    elif kind == "slstm":
        h = rmsnorm(bp["pre_norm"], x, cfg.norm_eps)
        h, new_cache = slstm(bp["slstm"], h, cfg, cache=cache)
        x = x + h
    elif kind == "shared_attn":
        assert shared is not None and embeds0 is not None
        xn = rmsnorm(bp["pre_norm"], x, cfg.norm_eps)
        cat = jnp.concatenate([xn, embeds0], axis=-1)    # (b, s, 2d)
        cat = rmsnorm(shared["in_norm"], cat, cfg.norm_eps)
        h = dense(shared["in_proj"], cat)
        # per-unit LoRA delta on the input projection
        h = h + (cat @ bp["lora_a"].astype(cat.dtype)) @ \
            bp["lora_b"].astype(cat.dtype)
        a, new_kv = attention(shared["attn"], h, cfg, window=0,
                              positions=positions,
                              cache=None if cache is None else cache["kv"])
        h = h + a
        m = mlp(shared["mlp"], rmsnorm(shared["pre_mlp_norm"], h,
                                       cfg.norm_eps))
        x = x + h + m
        if new_kv is not None:
            new_cache = {"kv": new_kv}
    else:
        raise ValueError(kind)
    x = _shard_activations(x, seq_parallel=cfg.seq_shard_activations)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model init / forward
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig) -> Params:
    k_embed, k_units, k_shared, k_head = jax.random.split(key, 4)

    def unit_init(k):
        kb = jax.random.split(k, len(cfg.block_pattern))
        return {f"b{i}": _init_block(kb[i], kind, cfg)
                for i, kind in enumerate(cfg.block_pattern)}

    keys = jax.random.split(k_units, cfg.n_units)
    params: Dict[str, Any] = {
        "embed": init_embedding(k_embed, cfg.vocab, cfg.d_model,
                                cfg.param_dtype),
        "units": jax.vmap(unit_init)(keys),
        "final_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }
    if "shared_attn" in cfg.block_pattern:
        params["shared"] = _init_shared(k_shared, cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_head, cfg.d_model, cfg.vocab,
                                       cfg.param_dtype)
    return params


def _merge_image_embeds(embeds, image_embeds, image_mask):
    """Scatter precomputed patch embeddings over masked token positions."""
    if image_embeds is None:
        return embeds
    idx = jnp.cumsum(image_mask.astype(jnp.int32), axis=1) - 1
    idx = jnp.clip(idx, 0, image_embeds.shape[1] - 1)
    gathered = jnp.take_along_axis(
        image_embeds.astype(embeds.dtype), idx[..., None], axis=1)
    return jnp.where(image_mask[..., None], gathered, embeds)


def lm_forward(
    params: Params,
    tokens: jnp.ndarray,                    # (b, s) int32
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    caches: Optional[Params] = None,        # stacked over units
    image_embeds: Optional[jnp.ndarray] = None,
    image_mask: Optional[jnp.ndarray] = None,
    input_embeds: Optional[jnp.ndarray] = None,  # bypass embedding (audio)
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Returns (logits, new_caches, aux_loss)."""
    if input_embeds is not None:
        x = input_embeds.astype(cfg.compute_dtype)
    else:
        x = embed(params["embed"], tokens, cfg.compute_dtype)
    x = _merge_image_embeds(x, image_embeds, image_mask)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    x = _shard_activations(x)
    embeds0 = x if "shared" in params else None
    shared = params.get("shared")

    aux0 = jnp.zeros((), jnp.float32)

    def unit_body(carry, scanned):
        x, aux = carry
        unit_params, unit_caches = scanned
        new_unit_caches = {} if caches is not None else None
        for i, kind in enumerate(cfg.block_pattern):
            bc = None if caches is None else unit_caches[f"b{i}"]
            x, nc, aux = _apply_block(
                unit_params[f"b{i}"], kind, x, cfg,
                layer_in_pattern=i, shared=shared, embeds0=embeds0,
                positions=positions, cache=bc, aux=aux)
            if caches is not None:
                new_unit_caches[f"b{i}"] = nc if nc is not None else bc
        return (x, aux), new_unit_caches

    body = unit_body
    if cfg.remat:
        policy = None
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(unit_body, prevent_cse=False, policy=policy)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, aux0), (params["units"], caches), length=cfg.n_units)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["lm_head"], x)
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    logits = _shard_logits(logits)
    return logits, new_caches, aux


def _shard_logits(logits: jnp.ndarray) -> jnp.ndarray:
    """Pin logits to (batch over data axes, vocab over model): keeps the
    CE loss and its backward local to the vocab shards."""
    ctx = get_context()
    if ctx.mesh is None:
        return logits
    from jax.sharding import NamedSharding
    prod, axes = 1, []
    for a in ctx.batch_axes:
        prod *= ctx.mesh.shape[a]
        if logits.shape[0] % prod == 0:
            axes.append(a)
        else:
            break
    vspec = ctx.model_axis if logits.shape[-1] % ctx.model_size == 0 \
        else None
    spec = P(tuple(axes) if axes else None, None, vspec)
    return jax.lax.with_sharding_constraint(
        logits, NamedSharding(ctx.mesh, spec))


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> Params:
    """Per-unit caches, stacked over units (leading axis n_units)."""

    def one_block(kind: str):
        if kind == "attn":
            return {"kv": init_kv_cache(cfg, batch, max_len, dtype=dtype)}
        if kind == "local_attn":
            return {"kv": init_kv_cache(cfg, batch, max_len,
                                        window=cfg.window, dtype=dtype)}
        if kind == "shared_attn":
            return {"kv": init_kv_cache(cfg, batch, max_len, dtype=dtype)}
        if kind == "mamba2":
            return init_mamba_cache(cfg, batch)
        if kind == "mlstm":
            return init_mlstm_cache(cfg, batch)
        if kind == "slstm":
            return init_slstm_cache(cfg, batch)
        raise ValueError(kind)

    unit = {f"b{i}": one_block(kind)
            for i, kind in enumerate(cfg.block_pattern)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_units,) + x.shape).copy(),
        unit)
