from .common import ModelConfig
from .registry import ModelBundle, build_model

__all__ = ["ModelConfig", "ModelBundle", "build_model"]
