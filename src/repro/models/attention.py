"""GQA attention block with RoPE, sliding window, softcap, QK-norm.

Supports three call modes:
  - training / prefill: full-sequence self-attention (causal or not)
  - decode: single (or few) new token(s) against a preallocated KV cache
  - cross-attention (whisper decoder): kv comes from the encoder output
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from .common import ModelConfig, Params, apply_rope, dense, init_dense, init_rmsnorm, rmsnorm


def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p = {
        "wq": init_dense(kq, cfg.d_model, cfg.n_heads * hd, dt, cfg.use_bias),
        "wk": init_dense(kk, cfg.d_model, cfg.n_kv_heads * hd, dt, cfg.use_bias),
        "wv": init_dense(kv, cfg.d_model, cfg.n_kv_heads * hd, dt, cfg.use_bias),
        "wo": init_dense(ko, cfg.n_heads * hd, cfg.d_model, dt, cfg.use_bias,
                         scale=1.0 / math.sqrt(cfg.n_heads * hd)),
    }
    if cfg.use_qk_norm and not cross:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def _project_kv(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                positions: Optional[jnp.ndarray], *, use_rope: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    k = dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    if "k_norm" in p:
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if use_rope and positions is not None:
        k = apply_rope(k, positions, _theta(cfg))
    return k, v


def _theta(cfg: ModelConfig, is_global: bool = False) -> float:
    if is_global and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


def attention(
    p: Params,
    x: jnp.ndarray,                       # (b, s, d)
    cfg: ModelConfig,
    *,
    window: int = 0,                       # 0 = full attention
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,      # (b, s)
    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn KV
    cache: Optional[Dict[str, jnp.ndarray]] = None,        # decode KV cache
    use_rope: bool = True,
    is_global: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Returns (output, updated_cache)."""
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)

    theta = _theta(cfg, is_global)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if use_rope:
        q = apply_rope(q, positions, theta)

    new_cache = None
    if kv is not None:
        # cross attention: fixed kv, no cache update, no causal mask
        kc, vc = kv
        out = flash_attention(q, kc, vc, causal=False, window=0,
                              softcap=cfg.attn_logit_softcap,
                              impl=cfg.attn_impl)
    elif cache is not None:
        # scatter new kv into the ring/linear cache
        k_new = dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
        v_new = dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
        if "k_norm" in p:
            k_new = rmsnorm(p["k_norm"], k_new, cfg.norm_eps)
        if use_rope:
            k_new = apply_rope(k_new, positions, theta)
        cache_len = cache["k"].shape[1]
        # slot index: absolute position for linear cache, modulo for window
        slots = positions % cache_len if window else positions
        k_buf = _scatter_cache(cache["k"], k_new, slots)
        v_buf = _scatter_cache(cache["v"], v_new, slots)
        kv_pos = _scatter_positions(cache["pos"], positions, slots)
        new_cache = {"k": k_buf, "v": v_buf, "pos": kv_pos}
        if s > 8:
            # prefill-from-scratch: attend the fresh segment only (the
            # cache is write-only here) — keeps attention free of cache
            # resharding and matches production prefill engines.
            out = flash_attention(
                q, k_new, v_new, causal=causal, window=window,
                softcap=cfg.attn_logit_softcap, q_positions=positions,
                kv_positions=positions, impl=cfg.attn_impl)
        else:
            kv_mask = kv_pos >= 0
            out = flash_attention(
                q, k_buf, v_buf, causal=causal, window=window,
                softcap=cfg.attn_logit_softcap, q_positions=positions,
                kv_positions=jnp.maximum(kv_pos, 0), kv_mask=kv_mask,
                impl=cfg.attn_impl)
    else:
        # full self-attention over x
        k, v = _project_kv(p, x, cfg, positions, use_rope=use_rope)
        out = flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.attn_logit_softcap,
                              q_positions=positions, kv_positions=positions,
                              impl=cfg.attn_impl)

    out = out.reshape(b, s, cfg.n_heads * hd)
    return dense(p["wo"], out), new_cache


def _scatter_cache(buf: jnp.ndarray, new: jnp.ndarray, slots: jnp.ndarray) -> jnp.ndarray:
    """buf: (b, cache, h, d); new: (b, s, h, d); slots: (b, s)."""
    b = buf.shape[0]
    bidx = jnp.arange(b)[:, None]
    return buf.at[bidx, slots].set(new.astype(buf.dtype))


def _scatter_positions(pos_buf: jnp.ndarray, positions: jnp.ndarray,
                       slots: jnp.ndarray) -> jnp.ndarray:
    bidx = jnp.arange(pos_buf.shape[0])[:, None]
    return pos_buf.at[bidx, slots].set(positions.astype(pos_buf.dtype))


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                  window: int = 0, dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Linear cache for full/global attention; ring cache for windowed."""
    hd = cfg.resolved_head_dim
    size = min(window, max_len) if window else max_len
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }
