"""Algorithm 1 selection + repository semantics."""
import numpy as np

from repro.core import Repository, RunRecord, select_similar, select_similar_batched
from repro.simdata import make_emulator


def _records(emu, shared_id, wid, n, seed, space):
    rng = np.random.default_rng(seed)
    out = []
    for ci in rng.choice(len(space), n, replace=False):
        out.append(emu.make_record(shared_id, wid, space.configs[ci], rng))
    return out


def test_selection_prefers_same_algorithm():
    emu = make_emulator()
    space = emu.space
    wids = emu.workload_ids()
    # target: spark2.1 kmeans; candidates: the same-algo twin + others
    target_id = "spark2.1/kmeans/points-100m"
    twin = "spark1.5/kmeans/points-100m"
    others = ["hadoop2.7/terasort/tera-300g", "spark2.1/als/ratings-1b"]
    target_runs = _records(emu, "t", target_id, 8, 0, space)
    candidates = {
        "twin": _records(emu, "twin", twin, 10, 1, space),
        "other1": _records(emu, "other1", others[0], 10, 2, space),
        "other2": _records(emu, "other2", others[1], 10, 3, space),
    }
    ranked = select_similar(target_runs, candidates, k=3)
    assert ranked[0][0] == "twin", ranked
    batched = select_similar_batched(target_runs, candidates, k=3)
    assert batched[0][0] == "twin", batched
    # both paths agree on scores
    d1 = dict(ranked); d2 = dict(batched)
    for z in d1:
        np.testing.assert_allclose(d1[z], d2[z], atol=1e-6)


def test_repository_roundtrip(tmp_path):
    emu = make_emulator()
    space = emu.space
    repo = Repository()
    repo.add_runs(_records(emu, "anon-1", emu.workload_ids()[0], 5, 0,
                           space))
    repo.add_runs(_records(emu, "anon-2", emu.workload_ids()[1], 4, 1,
                           space))
    path = str(tmp_path / "repo.json")
    repo.save(path)
    back = Repository.load(path)
    assert len(back) == 9
    assert set(back.workloads()) == {"anon-1", "anon-2"}
    r0 = repo.runs("anon-1")[0]
    b0 = back.runs("anon-1")[0]
    np.testing.assert_allclose(r0.metrics, b0.metrics)
    assert r0.measures["cost"] == b0.measures["cost"]


def test_repository_minimalism():
    """Shared records must not contain framework/algorithm/dataset tags."""
    emu = make_emulator()
    rec = emu.make_record("anon-1", emu.workload_ids()[0],
                          emu.space.configs[0])
    assert set(rec.config.keys()) == {"machine_type", "node_count"}
    assert rec.workload_id == "anon-1"   # opaque id only


def test_truncated_counts():
    emu = make_emulator()
    repo = Repository()
    repo.add_runs(_records(emu, "a", emu.workload_ids()[0], 10, 0,
                           emu.space))
    t = repo.truncated({"a": 4})
    assert len(t.runs("a")) == 4
