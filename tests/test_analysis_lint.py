"""The analyzer on the CLEAN tree: zero unsuppressed errors, working
suppression semantics, and a well-formed CLI report. The companion
``test_analysis_mutants`` pins the other direction (seeded bugs ARE
caught)."""
import json

import pytest

from repro.analysis.findings import (Finding, SUPPRESSIONS,
                                     apply_suppressions, max_severity)
from repro.analysis.lint import RULES, main, run_all, run_rule


@pytest.fixture(scope="module")
def clean_findings():
    return run_all()


def test_clean_tree_has_no_unsuppressed_errors(clean_findings):
    errors = [f for f in clean_findings if f.severity == "error"]
    assert errors == [], [f"{f.rule}:{f.launch}:{f.path}: {f.message}"
                          for f in errors]


def test_known_waiver_is_present_and_justified(clean_findings):
    """The fit's weak ``lr`` scalar is the designed suppression demo:
    it must still be REPORTED (demoted, with its justification) — a
    suppression hides the exit-code consequence, never the finding."""
    waived = [f for f in clean_findings if f.suppressed]
    assert any(f.key() == ("vocab-closure", "fit", "lr")
               for f in waived)
    assert all(f.severity == "info" and
               f.suppressed == SUPPRESSIONS[f.key()] for f in waived)


def test_every_rule_runs_standalone():
    for rule in RULES:
        findings = run_rule(rule)
        assert all(f.rule == rule for f in findings)


def test_suppression_only_demotes_exact_key():
    hit = Finding("vocab-closure", "error", "fit", "lr", "weak")
    miss = Finding("vocab-closure", "error", "fit", "other", "weak")
    out = apply_suppressions([hit, miss])
    assert out[0].severity == "info" and out[0].suppressed
    assert out[1].severity == "error" and not out[1].suppressed
    assert max_severity(out) == "error"


def test_cli_json_report(tmp_path, capsys):
    out_path = tmp_path / "findings.json"
    rc = main(["--format=json", f"--output={out_path}",
               "--rules=prng-audit,donation-safety"])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert json.loads(out_path.read_text()) == report
    assert set(report["summary"]["rules"]) == {"prng-audit",
                                               "donation-safety"}
    assert report["summary"]["errors"] == 0
    for f in report["findings"]:
        assert {"rule", "severity", "launch", "path",
                "message"} <= set(f)


def test_cli_rejects_unknown_rule():
    with pytest.raises(SystemExit):
        main(["--rules=made-up-rule"])
