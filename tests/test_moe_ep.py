"""Expert-parallel MoE paths vs the local oracle on an 8-device host mesh.

Runs in a subprocess because the device-count flag must be set before
jax initialises (the main test process keeps 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.distributed import DistContext, use_context
    from repro.models.common import ModelConfig
    from repro.models.moe import init_moe, moe

    cfg = ModelConfig(name="t", d_model=32, d_ff=64, n_experts=8, top_k=2,
                      moe_d_ff=48, moe_capacity_factor=8.0,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))

    ref, aux_ref = moe(p, x, cfg)   # local (no mesh)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for ep_mode in ["allgather", "a2a"]:
        ctx = DistContext(mesh=mesh, batch_axes=("data",), ep_mode=ep_mode)
        with use_context(ctx):
            with mesh:
                out, aux = jax.jit(lambda p, x: moe(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4, rtol=2e-4,
                                   err_msg=ep_mode)
        # aux is per-shard-then-averaged under EP (nonlinear in the
        # token mean) — expect agreement only to ~ batch-variance level
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=0.1)
        print("OK", ep_mode)
""")


@pytest.mark.slow
def test_ep_modes_match_local():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True, env=env,
                       timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK allgather" in r.stdout and "OK a2a" in r.stdout
