import os
import sys

# NOTE: no XLA_FLAGS here on purpose — tests and benches see 1 CPU device;
# only launch/dryrun.py installs the 512-placeholder-device flag.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
