"""Regression tests for the compile-once accounting itself: per-name
watcher snapshots (late-registered twins count) and strict dynamic
launch registration."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import compile_stats


@pytest.fixture
def scratch_names():
    """Pop any launch names a test registers, keeping _DYNAMIC clean
    for the rest of the suite."""
    names = []
    yield names
    for name in names:
        compile_stats._DYNAMIC.pop(name, None)


def test_late_registered_twin_counts_as_miss(scratch_names):
    """A sharded twin minted AFTER a watcher was constructed must have
    its first compile attributed to that watcher — under the old
    single-total snapshot the twin was invisible (absent from the base
    resolution), so serving-time compiles went uncounted."""
    watcher = compile_stats.CompileWatcher()
    twin = jax.jit(lambda x: x * 2.0)
    scratch_names.append("cs_test_late_twin")
    compile_stats.register_launch("cs_test_late_twin", twin)
    assert watcher.misses() == 0          # registered, not yet compiled
    twin(jnp.ones((3,)))
    assert watcher.misses() == 1
    twin(jnp.ones((3,)))                  # cache hit: no new miss
    assert watcher.misses() == 1
    twin(jnp.ones((5,)))                  # new shape: one more
    assert watcher.misses() == 2
    watcher.reset()
    assert watcher.misses() == 0


def test_reregistering_same_fn_is_idempotent(scratch_names):
    twin = jax.jit(lambda x: x + 1.0)
    scratch_names.append("cs_test_idempotent")
    compile_stats.register_launch("cs_test_idempotent", twin)
    compile_stats.register_launch("cs_test_idempotent", twin)
    assert compile_stats.tracked_launches()["cs_test_idempotent"] \
        is twin


def test_reregistering_different_fn_raises(scratch_names):
    """Replacing a name's fn would drop the old twin's cache entries
    from the accounting and mask real misses."""
    scratch_names.append("cs_test_clash")
    compile_stats.register_launch("cs_test_clash",
                                  jax.jit(lambda x: x + 1.0))
    with pytest.raises(ValueError, match="different"):
        compile_stats.register_launch("cs_test_clash",
                                      jax.jit(lambda x: x + 2.0))


def test_registering_a_static_name_raises():
    """The merged tracked dict gives static names precedence; a dynamic
    registration under one would be silently ignored."""
    with pytest.raises(ValueError, match="static vocabulary"):
        compile_stats.register_launch("fit", jax.jit(lambda x: x))
    assert "fit" not in compile_stats._DYNAMIC


def test_static_name_guard_covers_whole_vocabulary():
    assert compile_stats._STATIC_NAMES == \
        set(compile_stats.tracked_launches()) - \
        set(compile_stats._DYNAMIC)


def test_watcher_immune_to_other_launches_base(scratch_names):
    """Per-name bases: one launch's pre-existing cache entries can
    never offset another launch's misses."""
    warm = jax.jit(lambda x: x - 1.0)
    scratch_names.append("cs_test_warm")
    compile_stats.register_launch("cs_test_warm", warm)
    warm(jnp.ones((2,)))
    watcher = compile_stats.CompileWatcher()
    cold = jax.jit(lambda x: x * 3.0)
    scratch_names.append("cs_test_cold")
    compile_stats.register_launch("cs_test_cold", cold)
    cold(jnp.ones((2,)))
    assert watcher.misses() == 1
