"""launch.hlo_stats: trip-count-weighted HLO accounting.

Synthetic-HLO unit tests + an end-to-end check against a jitted scan
whose true dot flops are known analytically (the property cost_analysis
itself gets wrong by a factor of the trip count).
"""
import textwrap

import pytest

from repro.launch import hlo_stats

_SYNTH = textwrap.dedent("""
    HloModule test, num_partitions=4

    %body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %g0 = s32[] get-tuple-element(%p), index=0
      %c1 = s32[] constant(1)
      %add.5 = s32[] add(%g0, %c1)
      %g1 = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.9 = f32[8,16]{1,0} dot(%g1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %all-reduce.3 = f32[8,16]{1,0} all-reduce(%dot.9), replica_groups=[2,2]<=[4], to_apply=%sum
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%add.5, %all-reduce.3)
    }

    %cond.1 (pc: (s32[], f32[8,16])) -> pred[] {
      %pc = (s32[], f32[8,16]{1,0}) parameter(0)
      %gc = s32[] get-tuple-element(%pc), index=0
      %c5 = s32[] constant(5)
      ROOT %lt = pred[] compare(%gc, %c5), direction=LT
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x: f32[8,16]) -> f32[8,16] {
      %x = f32[8,16]{1,0} parameter(0)
      %c0 = s32[] constant(0)
      %tup = (s32[], f32[8,16]{1,0}) tuple(%c0, %x)
      %while.1 = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond.1, body=%body.1
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
    }
""")


def test_synthetic_while_multiplication():
    s = hlo_stats.analyze(_SYNTH)
    # dot: 2 * 8*16 * 16 = 4096 flops, x5 trips
    assert s["dot_flops"] == 5 * 2 * 8 * 16 * 16
    # all-reduce: 8*16*4 bytes * 2*(g-1)/g with g=2 -> 512 B/trip x5
    assert s["collective_bytes"] == pytest.approx(5 * 512.0)
    assert s["collectives"]["all-reduce"] == pytest.approx(5 * 512.0)


def test_shape_parsing():
    els, by = hlo_stats._parse_shape("bf16[4,8]{1,0}")
    assert (els, by) == (32, 64)
    els, by = hlo_stats._parse_shape("(f32[2,2], s32[3])")
    assert els == 7 and by == 28


_FUSED_SYNTH = textwrap.dedent("""
    HloModule fused_test

    %mm (p0: f32[8,16]) -> f32[8,16] {
      %p0 = f32[8,16]{1,0} parameter(0)
      %w = f32[16,16]{1,0} constant({...})
      ROOT %dot.7 = f32[8,16]{1,0} dot(f32[8,16]{1,0} %p0, f32[16,16]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }

    %body.2 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %g0 = s32[] get-tuple-element(%p), index=0
      %c1 = s32[] constant(1)
      %add.5 = s32[] add(%g0, %c1)
      %g1 = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %fusion.1 = f32[8,16]{1,0} fusion(%g1), kind=kOutput, calls=%mm
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%add.5, %fusion.1)
    }

    %cond.2 (pc: (s32[], f32[8,16])) -> pred[] {
      %pc = (s32[], f32[8,16]{1,0}) parameter(0)
      %gc = s32[] get-tuple-element(%pc), index=0
      %c9 = s32[] constant(9)
      ROOT %lt = pred[] compare(%gc, %c9), direction=LT
    }

    ENTRY %main (x: f32[8,16]) -> f32[8,16] {
      %x = f32[8,16]{1,0} parameter(0)
      %pre = f32[8,16]{1,0} fusion(%x), kind=kLoop, calls=%mm
      %c0 = s32[] constant(0)
      %tup = (s32[], f32[8,16]{1,0}) tuple(%c0, %pre)
      %while.2 = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond.2, body=%body.2
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.2), index=1
    }
""")


def test_fused_dot_under_while_gets_trip_multiplier():
    """Regression: a dot living in a fused computation reached via
    ``calls=`` from the while BODY must carry the trip count even when
    the entry also calls the same computation at multiplier 1 — the
    stale single-visit BFS used to freeze it at whichever multiplier
    discovered it first."""
    s = hlo_stats.analyze(_FUSED_SYNTH)
    per_call = 2 * 8 * 16 * 16
    # diamond: 1 entry call + 9 trips through the body's fusion; the
    # shared computation is counted at its MAX multiplier (9), which is
    # the honest per-site accounting short of call-site cloning
    assert s["dot_flops"] == 9 * per_call


def test_typed_operands_resolve_contracting_dims():
    """Compiled modules print `dot(f32[16,64]{1,0} %lhs, ...)`; the lhs
    contracting extent must come from the inline type, not a failed
    symbol-table lookup (which silently yielded contract=1)."""
    hlo = textwrap.dedent("""
        HloModule t

        ENTRY %main (x: f32[4,8]) -> f32[4,2] {
          %x = f32[4,8]{1,0} parameter(0)
          %w = f32[8,2]{1,0} constant({...})
          ROOT %dot.1 = f32[4,2]{1,0} dot(f32[4,8]{1,0} %x, f32[8,2]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }
    """)
    s = hlo_stats.analyze(hlo)
    assert s["dot_flops"] == 2 * 4 * 2 * 8
    # operand + result bytes: (4*8 + 8*2 + 4*2) * 4
    assert s["dot_bytes"] == (32 + 16 + 8) * 4


def test_known_trip_count_overrides_condition_constant():
    """backend_config known_trip_count is exact; the max-constant walk
    of the condition is only the fallback (a condition comparing
    against an unrelated large constant must not inflate the count)."""
    hlo = _SYNTH.replace(
        "condition=%cond.1, body=%body.1",
        'condition=%cond.1, body=%body.1, '
        'backend_config={"known_trip_count":{"n":"3"}}')
    s = hlo_stats.analyze(hlo)
    assert s["dot_flops"] == 3 * 2 * 8 * 16 * 16


def test_end_to_end_against_known_scan():
    """Compiled 7-step scan of one (16x64)@(64x32) matmul: the parser must
    report 7x the per-iteration dots (cost_analysis reports ~1x)."""
    import jax
    import jax.numpy as jnp

    w = jnp.ones((7, 64, 32), jnp.float32)

    def f(x):
        def body(c, wi):
            return jnp.tanh(c @ wi) @ wi.T, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((16, 64), jnp.float32)).compile()
    stats = hlo_stats.analyze(compiled.as_text())
    per_iter = 2 * 16 * 64 * 32 * 2       # two matmuls
    expected = 7 * per_iter
    assert stats["dot_flops"] == pytest.approx(expected, rel=0.05), \
        (stats["dot_flops"], expected)
