"""Hypothesis property: vocabulary closure holds for RANDOM serving
envelopes, not just the lint CLI's representative one. Same oracle as
the static ``vocab-closure`` pass — every signature a live cohort
within random ``CohortLimits`` emits is in ``enumerate_buckets``,
under every mesh lane-lifting divisor."""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.analysis.vocab_closure import check_closure
from repro.core.plan import CohortLimits

_knob = lambda *vals: st.sets(st.sampled_from(vals), max_size=2).map(
    lambda s: tuple(sorted(s)))

_limits = st.builds(
    CohortLimits,
    d=st.integers(1, 4),
    q_grid=st.integers(1, 12),
    max_obs=st.integers(1, 10),
    max_lanes=st.integers(1, 4),
    n_samples=_knob(8, 32),
    n_mc=_knob(8, 16),
    n_objectives=_knob(2, 3),
    # generous box budget: the random fronts (0..3 points) must stay
    # inside the envelope, or a "hole" would just be a limits breach
    max_ehvi_boxes=st.just(64),
)


@settings(max_examples=12, deadline=None, derandomize=True)
@given(limits=_limits, shards=st.sampled_from((1, 2, 4)))
def test_live_signatures_stay_inside_enumerated_vocabulary(
        limits, shards):
    findings = check_closure(limits=limits, shard_sizes=(shards,))
    assert findings == [], [f.path for f in findings]
