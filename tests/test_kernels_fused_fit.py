"""Fused warm-startable fit kernel vs its oracles.

Three-way parity chain: the Pallas kernel (interpret mode) must match
the analytic vmapped reference (``fused_fit_ref``) bit-for-bit-ish
(<= 1e-4), and the reference must match the legacy autodiff fit
(``core.gp._fit_batched`` + ``_batched_chol_alpha``) from a cold start
— the analytic gradient IS the autodiff gradient of the masked NLML.
The autodiff leg pins lanes at n >= 5: tiny-n lanes sit in flat NLML
basins where f32 roundoff between the two gradient formulations is
Adam-amplified over 120 steps (an intrinsic property, not a kernel
bug); the ref-vs-interpret leg has no such caveat and runs the
degenerate shapes (n_obs = 1, fully-masked lanes) directly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gp as gp_mod
from repro.kernels.fused_fit import (fused_fit, fused_fit_pallas,
                                     fused_fit_ref)

TOL = 1e-4
NOISE = 0.1


def _lanes(seed=0, counts=(7, 5, 3), n_pad=8, d=3, warm=False):
    """Padded fit-bucket arrays exactly as ``_exec_fit`` packs them:
    standardised targets, zero pads, validity mask, warm-start rows."""
    rng = np.random.default_rng(seed)
    m = len(counts)
    x = np.zeros((m, n_pad, d), np.float32)
    y = np.zeros((m, n_pad), np.float32)
    mask = np.zeros((m, n_pad), np.float32)
    for i, n in enumerate(counts):
        if n == 0:
            continue
        xi = rng.random((n, d))
        yi = np.sin(xi.sum(axis=1)) + 0.1 * rng.normal(size=n)
        sd = yi.std() if n > 1 and yi.std() > 1e-8 else 1.0
        x[i, :n] = xi
        y[i, :n] = (yi - yi.mean()) / sd
        mask[i, :n] = 1.0
    if warm:
        ils = rng.normal(0.0, 0.3, (m, d)).astype(np.float32)
        isf = rng.normal(0.0, 0.3, (m,)).astype(np.float32)
    else:
        ils = np.zeros((m, d), np.float32)
        isf = np.zeros((m,), np.float32)
    return x, y, mask, ils, isf


@pytest.mark.parametrize("counts,n_pad,warm", [
    ((7, 5, 3), 8, False),       # ragged cold bucket
    ((8, 8), 8, True),           # full lanes, warm start
    ((1,), 8, False),            # single observation
    ((5, 0), 8, True),           # fully-masked lane rides along
])
def test_pallas_interpret_matches_ref(counts, n_pad, warm):
    parts = _lanes(seed=1, counts=counts, n_pad=n_pad, warm=warm)
    steps = 16 if warm else 40
    ref = fused_fit_ref(*parts, steps=steps, noise=NOISE)
    got = fused_fit_pallas(*parts, steps=steps, noise=NOISE,
                           interpret=True)
    for r, g, name in zip(ref, got, ("log_ls", "log_sf", "chol",
                                     "alpha")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=TOL, err_msg=name)


def test_ref_matches_legacy_autodiff_fit_cold():
    """Cold start (zero init, 120 steps) against the vmapped autodiff
    fit + factorisation pair the executor used before the fused leg."""
    x, y, mask, ils, isf = _lanes(seed=2, counts=(7, 6, 5), n_pad=8)
    ls, sf, chol, alpha = fused_fit_ref(x, y, mask, ils, isf,
                                        steps=120, noise=NOISE)
    fitted = gp_mod._fit_batched(x, y, mask, steps=120, noise=NOISE)
    chol0, alpha0 = gp_mod._batched_chol_alpha(
        fitted["ls"], fitted["sf"], x, y, mask, NOISE)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(fitted["ls"]),
                               atol=TOL)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(fitted["sf"]),
                               atol=TOL)
    np.testing.assert_allclose(np.asarray(chol), np.asarray(chol0),
                               atol=TOL)
    np.testing.assert_allclose(np.asarray(alpha), np.asarray(alpha0),
                               atol=TOL)


def test_fully_masked_lane_keeps_its_warm_start():
    """The init-invariance contract ``_regroup_fit`` and the padded
    executor lanes rely on: zero mask -> zero gradient, params stay AT
    the caller's init, and the factorisation degenerates to the padding
    contract (diag sqrt(1 + noise + jitter), alpha 0)."""
    x, y, mask, _, _ = _lanes(seed=3, counts=(0, 0), n_pad=4)
    ils = np.asarray([[0.5, -0.25, 1.0], [-1.0, 0.0, 2.0]], np.float32)
    isf = np.asarray([0.75, -0.5], np.float32)
    ls, sf, chol, alpha = fused_fit_ref(x, y, mask, ils, isf,
                                        steps=30, noise=NOISE)
    np.testing.assert_allclose(np.asarray(ls), ils, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sf), isf, atol=1e-6)
    diag = np.sqrt(1.0 + NOISE + 1e-6)
    np.testing.assert_allclose(
        np.asarray(chol),
        np.broadcast_to(diag * np.eye(4, dtype=np.float32), (2, 4, 4)),
        atol=1e-6)
    np.testing.assert_allclose(np.asarray(alpha), np.zeros((2, 4)),
                               atol=1e-6)


def test_warm_refine_does_not_degrade_nlml():
    """Warm-refining from an already-converged point must not hurt the
    fit. The contract is in FUNCTION space, not parameter space: a
    restarted Adam takes ~lr-sized sign-normalised steps regardless of
    gradient magnitude, so along flat NLML directions the params may
    wander — but each lane's masked NLML after the 16-step warm rung
    must be no worse than the cold 120-step solution's."""
    import jax

    from repro.core.gp import GPParams, _masked_nlml
    x, y, mask, ils, isf = _lanes(seed=4, counts=(8, 6), n_pad=8)
    ls0, sf0, _, _ = fused_fit_ref(x, y, mask, ils, isf,
                                   steps=120, noise=NOISE)
    ls1, sf1, _, _ = fused_fit_ref(x, y, mask, np.asarray(ls0),
                                   np.asarray(sf0), steps=16,
                                   noise=NOISE)
    nlml = jax.vmap(
        lambda ls, sf, xi, yi, mi:
        _masked_nlml(GPParams(ls, sf, NOISE), xi, yi, mi))
    n_cold = np.asarray(nlml(ls0, sf0, x, y, mask))
    n_warm = np.asarray(nlml(ls1, sf1, x, y, mask))
    assert (n_warm <= n_cold + 0.05).all(), (n_warm, n_cold)


def test_impl_dispatch():
    parts = _lanes(seed=5, counts=(4, 3), n_pad=4)
    ref = fused_fit(*parts, steps=8, impl="xla")
    got = fused_fit(*parts, steps=8, impl="pallas_interpret")
    for r, g in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=TOL)
    with pytest.raises(ValueError):
        fused_fit(*parts, steps=8, impl="nope")
