"""Serving: prefill/decode consistency and the continuous-batching engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def test_decode_matches_full_forward():
    """Greedy decode through the cache == argmax of the full forward at
    each position (teacher forcing)."""
    cfg = get_smoke_config("minitron-8b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab)
    full_logits, _ = bundle.train_logits(params, {"tokens": toks})
    caches = bundle.init_cache(params, 1, 16, dtype=jnp.float32)
    for t in range(6):
        pos = jnp.full((1, 1), t, jnp.int32)
        step_logits, caches = bundle.decode_step(
            params, caches, toks[:, t:t + 1], pos)
        np.testing.assert_allclose(
            np.asarray(step_logits[0, 0]), np.asarray(full_logits[0, t]),
            atol=2e-2, rtol=2e-2)


def test_engine_continuous_batching():
    cfg = get_smoke_config("h2o-danube-1.8b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for rid in range(4):   # more requests than slots
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, size=5), 6))
    done = eng.run(max_steps=200)
    assert len(done) == 4
    assert sorted(c.rid for c in done) == [0, 1, 2, 3]
    for c in done:
        assert len(c.tokens) == 6
