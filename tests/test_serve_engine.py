"""Serving: prefill/decode consistency and the continuous-batching engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def test_decode_matches_full_forward():
    """Greedy decode through the cache == argmax of the full forward at
    each position (teacher forcing)."""
    cfg = get_smoke_config("minitron-8b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab)
    full_logits, _ = bundle.train_logits(params, {"tokens": toks})
    caches = bundle.init_cache(params, 1, 16, dtype=jnp.float32)
    for t in range(6):
        pos = jnp.full((1, 1), t, jnp.int32)
        step_logits, caches = bundle.decode_step(
            params, caches, toks[:, t:t + 1], pos)
        np.testing.assert_allclose(
            np.asarray(step_logits[0, 0]), np.asarray(full_logits[0, t]),
            atol=2e-2, rtol=2e-2)


def test_engine_continuous_batching():
    cfg = get_smoke_config("h2o-danube-1.8b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for rid in range(4):   # more requests than slots
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, size=5), 6))
    done = eng.run(max_steps=200)
    assert len(done) == 4
    assert sorted(c.rid for c in done) == [0, 1, 2, 3]
    for c in done:
        assert len(c.tokens) == 6


def _greedy_reference(bundle, params, prompt, max_new, max_len=48):
    """Batch-1, exact-length prefill greedy decode — the oracle for the
    engine's padded-prefill + masked-decode path."""
    caches = bundle.init_cache(params, 1, max_len, dtype=jnp.float32)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    pos = jnp.arange(toks.shape[1], dtype=jnp.int32)[None]
    logits, caches = bundle.decode_step(params, caches, toks, pos)
    out = [int(jnp.argmax(logits[0, -1]))]
    p = toks.shape[1]
    while len(out) < max_new:
        logits, caches = bundle.decode_step(
            params, caches, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.full((1, 1), p, jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
        p += 1
    return out


def test_engine_prefill_buckets_stabilise_compiles():
    """Prompt lengths land in round-to-8 buckets: one prefill compile
    per bucket (not per length) and exactly one decode compile, while
    the padded path still matches exact-length greedy decode."""
    cfg = get_smoke_config("minitron-8b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, slots=2, max_len=48)
    rng = np.random.default_rng(1)
    prompts = {rid: rng.integers(0, cfg.vocab, size=n)
               for rid, n in enumerate((3, 5, 7, 9, 12))}
    for rid, p in prompts.items():
        eng.submit(Request(rid, p, 4))
    done = {c.rid: c.tokens for c in eng.run(max_steps=200)}
    assert sorted(done) == [0, 1, 2, 3, 4]
    # lengths 3/5/7 share the 8-bucket, 9/12 the 16-bucket
    stats = eng.compile_stats()
    assert stats["prefill_compiles"] == 2
    assert stats["decode_compiles"] == 1
    for rid, p in prompts.items():
        assert done[rid] == _greedy_reference(bundle, params, p, 4)


def test_engine_freed_slot_cache_rows_stay_bit_identical():
    """After a slot frees, ongoing decode steps must not write into its
    cache rows: they stay bit-identical until re-admission."""
    cfg = get_smoke_config("minitron-8b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, slots=2, max_len=48)
    rng = np.random.default_rng(2)
    eng.submit(Request(0, rng.integers(0, cfg.vocab, size=5), 2))
    eng.submit(Request(1, rng.integers(0, cfg.vocab, size=5), 10))
    eng._admit()
    while not eng.done:
        eng._step_decode()
    (freed,) = eng.free
    snapshot = [np.asarray(leaf[:, freed]).copy()
                for leaf in jax.tree.leaves(eng.caches)]
    for _ in range(4):
        eng._step_decode()
    for before, leaf in zip(snapshot, jax.tree.leaves(eng.caches)):
        np.testing.assert_array_equal(before, np.asarray(leaf[:, freed]))
    # the other tenant kept decoding the whole time
    done = eng.run(max_steps=50)
    assert sorted(c.rid for c in done) == [0, 1]


def test_engine_ring_window_guard_skips_padding():
    """With a sliding-window (ring) cache, prompts whose padded length
    would exceed the window keep exact-length prefill — padding there
    would evict still-needed rows — and still decode correctly."""
    cfg = get_smoke_config("h2o-danube-1.8b")
    assert cfg.window and cfg.window < 24
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, slots=2, max_len=48)
    rng = np.random.default_rng(3)
    short = rng.integers(0, cfg.vocab, size=5)       # pads to 8
    long_a = rng.integers(0, cfg.vocab, size=cfg.window + 1)
    long_b = rng.integers(0, cfg.vocab, size=cfg.window + 2)
    for rid, p in enumerate((short, long_a, long_b)):
        eng.submit(Request(rid, p, 3))
    done = {c.rid: c.tokens for c in eng.run(max_steps=100)}
    assert sorted(done) == [0, 1, 2]
    # short bucketed (1 compile), both long prompts exact (2 compiles)
    assert eng.compile_stats()["prefill_compiles"] == 3
    for rid, p in enumerate((short, long_a, long_b)):
        assert done[rid] == _greedy_reference(bundle, params, p, 3)
