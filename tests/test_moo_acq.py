"""Acquisition functions + multi-objective search."""
import numpy as np
import jax.numpy as jnp

from repro.core.acquisition import (_hv_2d, expected_improvement, mc_ehvi,
                                    pareto_front, probability_of_feasibility)
from repro.core import (BOConfig, Constraint, Objective, run_search_moo,
                        scout_search_space, pareto_of_result)
from repro.simdata import make_emulator


def test_ei_properties():
    mu = jnp.array([0.0, 1.0, -1.0])
    var = jnp.array([1.0, 1.0, 1e-8])
    ei = np.asarray(expected_improvement(mu, var, best=0.0))
    assert ei[2] > ei[0] > ei[1]          # lower mean -> higher EI
    assert np.all(ei >= 0)


def test_pof_monotone():
    mu, var = jnp.array([0.0]), jnp.array([1.0])
    lo = float(probability_of_feasibility(mu, var, -1.0)[0])
    hi = float(probability_of_feasibility(mu, var, 1.0)[0])
    assert lo < 0.5 < hi


def test_hv_and_pareto():
    pts = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0], [3.0, 3.0]])
    front = pareto_front(pts)
    assert len(front) == 3                # (3,3) dominated
    hv = _hv_2d(front, np.array([4.0, 4.0]))
    assert hv == 3.0 + 2.0 + 1.0          # staircase area


def test_mc_ehvi_prefers_dominating_point():
    obs = np.array([[2.0, 2.0]])
    ref = np.array([4.0, 4.0])
    # candidate 0 dominates obs; candidate 1 is dominated
    sa = np.tile(np.array([[1.0, 3.0]]), (16, 1))
    sb = np.tile(np.array([[1.0, 3.0]]), (16, 1))
    acq = mc_ehvi(sa, sb, obs, ref)
    assert acq[0] > acq[1]


def test_moo_search_runs_and_finds_pareto():
    emu = make_emulator()
    space = scout_search_space()
    wid = emu.workload_ids()[8]
    rng = np.random.default_rng(0)
    target_rt = emu.runtime_target(wid, 75)
    r = run_search_moo(space, lambda c: emu.run(wid, c, rng=rng),
                       [Objective("cost"), Objective("energy")],
                       [Constraint("runtime", target_rt)],
                       method="naive", bo_config=BOConfig(max_iters=8),
                       seed=0, n_mc=16)
    assert len(r.observations) == 8
    front = pareto_of_result(r, [Objective("cost"), Objective("energy")],
                             [Constraint("runtime", target_rt)])
    assert len(front) >= 1
