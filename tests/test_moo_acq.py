"""Acquisition functions + multi-objective search."""
import numpy as np
import jax.numpy as jnp

from repro.core.acquisition import (_hv_2d, expected_improvement, hv_nd,
                                    mc_ehvi, mc_ehvi_batched, mc_ehvi_multi,
                                    mc_ehvi_nd, nondominated_boxes,
                                    pareto_front,
                                    probability_of_feasibility)
from repro.core import (BOConfig, Constraint, Objective, run_search_moo,
                        scout_search_space, pareto_of_result)
from repro.simdata import make_emulator


def test_ei_properties():
    mu = jnp.array([0.0, 1.0, -1.0])
    var = jnp.array([1.0, 1.0, 1e-8])
    ei = np.asarray(expected_improvement(mu, var, best=0.0))
    assert ei[2] > ei[0] > ei[1]          # lower mean -> higher EI
    assert np.all(ei >= 0)


def test_pof_monotone():
    mu, var = jnp.array([0.0]), jnp.array([1.0])
    lo = float(probability_of_feasibility(mu, var, -1.0)[0])
    hi = float(probability_of_feasibility(mu, var, 1.0)[0])
    assert lo < 0.5 < hi


def test_zero_variance_posterior_yields_finite_acquisitions():
    """Regression: a degenerate posterior (var=0, e.g. querying an
    observed point with tiny noise) must not produce NaN that survives
    `maximum(ei, 0)` and poisons argmax."""
    mu = jnp.array([0.5, -0.5, 0.0])
    var = jnp.zeros(3)
    ei = np.asarray(expected_improvement(mu, var, best=0.0))
    assert np.all(np.isfinite(ei))
    # below the incumbent the EI limit is the improvement itself
    np.testing.assert_allclose(ei, [0.0, 0.5, 0.0], atol=1e-6)
    assert int(np.argmax(ei)) == 1          # argmax stays meaningful
    pof = np.asarray(probability_of_feasibility(mu, var, 0.0))
    assert np.all(np.isfinite(pof))
    np.testing.assert_allclose(pof, [0.0, 1.0, 0.5], atol=1e-6)


def test_hv_and_pareto():
    pts = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0], [3.0, 3.0]])
    front = pareto_front(pts)
    assert len(front) == 3                # (3,3) dominated
    hv = _hv_2d(front, np.array([4.0, 4.0]))
    assert hv == 3.0 + 2.0 + 1.0          # staircase area


def test_hv_2d_edge_cases():
    ref = np.array([4.0, 4.0])
    # empty front dominates nothing
    assert _hv_2d(np.empty((0, 2)), ref) == 0.0
    # duplicate / tied points collapse onto one staircase step
    dup = np.array([[1.0, 3.0], [1.0, 3.0], [2.0, 2.0], [2.0, 2.0]])
    assert _hv_2d(dup, ref) == _hv_2d(np.array([[1.0, 3.0], [2.0, 2.0]]),
                                      ref)
    # points at/outside the reference contribute nothing
    assert _hv_2d(np.array([[4.0, 4.0], [5.0, 1.0]]), ref) == 0.0
    # a fully dominated point changes nothing
    base = np.array([[1.0, 1.0]])
    with_dom = np.array([[1.0, 1.0], [2.0, 3.0]])
    assert _hv_2d(with_dom, ref) == _hv_2d(base, ref) == 9.0


def test_pareto_front_edge_cases():
    # empty input -> empty front, shape preserved
    assert pareto_front(np.empty((0, 2))).shape == (0, 2)
    # duplicates: neither strictly dominates the other, but a reported
    # front must not carry the same point twice — first occurrence wins
    dup = np.array([[1.0, 2.0], [1.0, 2.0]])
    assert len(pareto_front(dup)) == 1
    # all points dominated by one
    pts = np.array([[0.0, 0.0], [1.0, 2.0], [3.0, 1.0], [2.0, 2.0]])
    front = pareto_front(pts)
    assert front.shape == (1, 2)
    np.testing.assert_array_equal(front[0], [0.0, 0.0])
    # ties on one coordinate: both non-dominated points survive
    tied = np.array([[1.0, 2.0], [1.0, 3.0], [2.0, 1.0]])
    front = pareto_front(tied)
    assert len(front) == 2


def test_mc_ehvi_batched_matches_per_candidate_loop():
    """The vectorised staircase EHVI must agree with the reference
    per-(sample, candidate) `_hv_2d` loop — including duplicate front
    points, all-dominated samples, and an empty front."""
    rng = np.random.default_rng(7)
    for trial in range(4):
        n = int(rng.integers(1, 12))
        obs = rng.random((n, 2)) * 4.0
        ref = obs.max(axis=0) * 1.1 + 1e-9
        sa = rng.normal(2.0, 1.5, (12, 7))
        sb = rng.normal(2.0, 1.5, (12, 7))
        np.testing.assert_allclose(
            mc_ehvi_batched(sa, sb, obs, ref), mc_ehvi(sa, sb, obs, ref),
            atol=1e-10)
    # duplicates + ties in the observed set
    obs = np.array([[1.0, 3.0], [1.0, 3.0], [2.0, 2.0], [2.0, 2.0]])
    ref = np.array([4.0, 4.0])
    sa = rng.normal(2.0, 1.0, (8, 5))
    sb = rng.normal(2.0, 1.0, (8, 5))
    np.testing.assert_allclose(mc_ehvi_batched(sa, sb, obs, ref),
                               mc_ehvi(sa, sb, obs, ref), atol=1e-10)
    # all samples dominated -> exactly zero improvement everywhere
    dom_a = np.full((4, 3), 3.0)
    dom_b = np.full((4, 3), 3.9)
    np.testing.assert_array_equal(
        mc_ehvi_batched(dom_a, dom_b, np.array([[1.0, 1.0]]), ref),
        np.zeros(3))
    # empty front: improvement is the whole box below the reference
    np.testing.assert_allclose(
        mc_ehvi_batched(np.array([[1.0]]), np.array([[1.0]]),
                        np.empty((0, 2)), ref),
        [9.0], atol=1e-12)


def test_mc_ehvi_multi_matches_per_session_batched():
    """The vmapped multi-session EHVI (one launch per (S, q) bucket,
    fronts padded with zero-width segments) must agree with the f64
    numpy oracle per job — including single-point, duplicate-heavy,
    and empty fronts sharing one launch."""
    rng = np.random.default_rng(11)
    jobs = []
    fronts = [rng.random((int(rng.integers(2, 10)), 2)) * 4.0,
              np.array([[1.0, 1.0]]),                       # single point
              np.array([[1.0, 3.0], [1.0, 3.0], [2.0, 2.0]]),  # dups
              np.empty((0, 2))]                             # empty front
    for obs in fronts:
        ref = (obs.max(axis=0) * 1.1 + 1e-9 if len(obs)
               else np.array([4.0, 4.0]))
        sa = rng.normal(2.0, 1.5, (16, 9))
        sb = rng.normal(2.0, 1.5, (16, 9))
        jobs.append((sa, sb, obs, ref))
    # a (S, q) bucket of its own
    jobs.append((rng.normal(2.0, 1.0, (8, 5)),
                 rng.normal(2.0, 1.0, (8, 5)),
                 fronts[0], fronts[0].max(axis=0) * 1.1 + 1e-9))
    counters = {}
    outs = mc_ehvi_multi(jobs, counters=counters)
    assert counters["launches"] == 2 and counters["queries"] == 5
    for (sa, sb, obs, ref), got in zip(jobs, outs):
        want = mc_ehvi_batched(sa, sb, obs, ref)
        scale = max(1.0, float(np.abs(want).max()))
        np.testing.assert_allclose(got, want, atol=1e-4 * scale)


# -- n-objective hypervolume -------------------------------------------------


def test_hv_nd_matches_hv_2d():
    rng = np.random.default_rng(3)
    for _ in range(5):
        pts = rng.random((int(rng.integers(1, 10)), 2)) * 4.0
        ref = pts.max(axis=0) * 1.1 + 1e-9
        np.testing.assert_allclose(hv_nd(pts, ref),
                                   _hv_2d(pareto_front(pts), ref),
                                   atol=1e-12)
    assert hv_nd(np.empty((0, 2)), np.array([4.0, 4.0])) == 0.0


def test_hv_nd_3d_known_values():
    ref = np.array([2.0, 2.0, 2.0])
    # one point dominates a unit cube's complement box
    assert hv_nd(np.array([[1.0, 1.0, 1.0]]), ref) == 1.0
    # two boxes of volume 2 overlapping in a unit cube -> union 3
    front = np.array([[0.0, 1.0, 1.0], [1.0, 0.0, 1.0]])
    assert hv_nd(front, ref) == 3.0
    # dominated and out-of-reference points contribute nothing
    assert hv_nd(np.array([[1.0, 1.0, 1.0], [1.5, 1.5, 1.5],
                           [3.0, 0.0, 0.0]]), ref) == 1.0


def test_nondominated_boxes_tile_the_complement():
    """The boxes are a disjoint cover of the non-dominated region: for
    any floor point f below the front, the clipped box volumes must sum
    to vol([f, ref]) - hv(front) — in 2 and 3 objectives."""
    rng = np.random.default_rng(4)
    for d in (2, 3):
        for _ in range(4):
            front = pareto_front(rng.random((int(rng.integers(1, 8)), d))
                                 * 4.0)
            ref = front.max(axis=0) * 1.1 + 1e-9
            floor = front.min(axis=0) - rng.random(d)
            los, his = nondominated_boxes(front, ref)
            vols = np.prod(np.clip(np.minimum(his, ref)
                                   - np.maximum(los, floor), 0.0, None),
                           axis=1)
            want = np.prod(ref - floor) - hv_nd(front, ref)
            np.testing.assert_allclose(vols.sum(), want, rtol=1e-10)


def test_mc_ehvi_nd_matches_2d_references():
    rng = np.random.default_rng(5)
    obs = rng.random((6, 2)) * 4.0
    ref = obs.max(axis=0) * 1.1 + 1e-9
    sa = rng.normal(2.0, 1.5, (8, 5))
    sb = rng.normal(2.0, 1.5, (8, 5))
    want = mc_ehvi(sa, sb, obs, ref)
    np.testing.assert_allclose(mc_ehvi_nd([sa, sb], obs, ref), want,
                               atol=1e-10)


def test_mc_ehvi_multi_3obj_matches_nd_oracle():
    """3-objective jobs (the n-ary job form) through the fused box
    launch vs the recursive-sweep f64 oracle — mixed with a legacy
    2-objective job in the same call."""
    rng = np.random.default_rng(6)
    fronts = [rng.random((int(rng.integers(2, 7)), 3)) * 4.0,
              np.array([[1.0, 1.0, 1.0]]),
              np.array([[1.0, 2.0, 3.0], [1.0, 2.0, 3.0]]),   # dups
              np.empty((0, 3))]
    jobs, oracle = [], []
    for obs in fronts:
        ref = (obs.max(axis=0) * 1.1 + 1e-9 if len(obs)
               else np.array([4.0, 4.0, 4.0]))
        samples = tuple(rng.normal(2.0, 1.5, (8, 7)) for _ in range(3))
        jobs.append((samples, obs, ref))
        oracle.append(mc_ehvi_nd(samples, obs, ref))
    # a legacy 4-tuple 2-objective job joins the same call (own bucket)
    obs2 = rng.random((4, 2)) * 4.0
    ref2 = obs2.max(axis=0) * 1.1 + 1e-9
    sa, sb = rng.normal(2, 1.5, (8, 7)), rng.normal(2, 1.5, (8, 7))
    jobs.append((sa, sb, obs2, ref2))
    oracle.append(mc_ehvi_batched(sa, sb, obs2, ref2))
    counters = {}
    outs = mc_ehvi_multi(jobs, counters=counters)
    assert counters["launches"] == 2 and counters["queries"] == 5
    for got, want in zip(outs, oracle):
        scale = max(1.0, float(np.abs(want).max()))
        np.testing.assert_allclose(got, want, atol=1e-4 * scale)


def test_mc_ehvi_prefers_dominating_point():
    obs = np.array([[2.0, 2.0]])
    ref = np.array([4.0, 4.0])
    # candidate 0 dominates obs; candidate 1 is dominated
    sa = np.tile(np.array([[1.0, 3.0]]), (16, 1))
    sb = np.tile(np.array([[1.0, 3.0]]), (16, 1))
    acq = mc_ehvi(sa, sb, obs, ref)
    assert acq[0] > acq[1]


def test_moo_search_runs_and_finds_pareto():
    emu = make_emulator()
    space = scout_search_space()
    wid = emu.workload_ids()[8]
    rng = np.random.default_rng(0)
    target_rt = emu.runtime_target(wid, 75)
    r = run_search_moo(space, lambda c: emu.run(wid, c, rng=rng),
                       [Objective("cost"), Objective("energy")],
                       [Constraint("runtime", target_rt)],
                       method="naive", bo_config=BOConfig(max_iters=8),
                       seed=0, n_mc=16)
    assert len(r.observations) == 8
    front = pareto_of_result(r, [Objective("cost"), Objective("energy")],
                             [Constraint("runtime", target_rt)])
    assert len(front) >= 1


def test_moo_search_three_objectives_runs_and_finds_pareto():
    """n=3 objectives ride the box-decomposition EHVI plan node end to
    end through run_search_moo (which serves via SearchService)."""
    emu = make_emulator()
    space = scout_search_space()
    wid = emu.workload_ids()[8]
    objectives = [Objective("cost"), Objective("energy"),
                  Objective("runtime")]
    r = run_search_moo(space, lambda c: emu.run(wid, c, rng=None),
                       objectives, method="naive",
                       bo_config=BOConfig(max_iters=6), seed=1, n_mc=8)
    assert len(r.observations) == 6
    assert r.meta["moo"] is True
    assert r.meta["objectives"] == ["cost", "energy", "runtime"]
    front = r.meta["pareto_front"]
    assert front.ndim == 2 and front.shape[1] == 3 and len(front) >= 1
    np.testing.assert_array_equal(front, pareto_of_result(r, objectives))


def test_ehvi_box_launch_non_multiple_chunk_remainder():
    """Regression: a box count past EHVI_BOX_CHUNK that is NOT a chunk
    multiple (direct callers bypass the planner's padding) must pad the
    trailing block with zero-volume boxes, not reshape it away — the
    result matches the single-block reduction over the same boxes."""
    from repro.core.acquisition import EHVI_BOX_CHUNK, _ehvi_box_launch

    rng = np.random.default_rng(11)
    l, d, s, q = 1, 2, 4, 3
    k = EHVI_BOX_CHUNK + 5
    corners = np.sort(rng.random((l, k + 1, d)), axis=1)
    los = jnp.asarray(corners[:, :-1], jnp.float32)
    his = jnp.asarray(corners[:, 1:], jnp.float32)
    refs = jnp.full((l, d), 2.0, jnp.float32)
    ps = jnp.asarray(rng.random((l, d, s, q)), jnp.float32)
    got = np.asarray(_ehvi_box_launch(los, his, refs, ps))
    # unchunked f64 oracle over the same boxes
    want = np.zeros((l, q))
    for li in range(l):
        vol = np.ones((s, q, k))
        for dim in range(d):
            w = np.clip(
                np.minimum(np.asarray(his, np.float64)[li, :, dim], 2.0)
                [None, None]
                - np.maximum(np.asarray(los, np.float64)[li, :, dim]
                             [None, None],
                             np.asarray(ps, np.float64)[li, dim]
                             [:, :, None]), 0.0, None)
            vol = vol * w
        want[li] = vol.sum(axis=-1).mean(axis=0)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    assert np.all(np.isfinite(got))


def test_pareto_of_observations_dedupes_repeated_observations():
    """Regression: profiling the same configuration twice (identical
    measures) must not report the point twice in the front."""
    from types import SimpleNamespace

    from repro.core.acquisition import pareto_of_observations

    objectives = [Objective("cost"), Objective("energy")]
    obs = [SimpleNamespace(measures={"cost": 1.0, "energy": 2.0},
                           metrics={}),
           SimpleNamespace(measures={"cost": 1.0, "energy": 2.0},
                           metrics={}),
           SimpleNamespace(measures={"cost": 2.0, "energy": 1.0},
                           metrics={}),
           SimpleNamespace(measures={"cost": 3.0, "energy": 3.0},
                           metrics={})]
    front = pareto_of_observations(obs, objectives)
    assert front.shape == (2, 2)
    np.testing.assert_array_equal(front,
                                  [[1.0, 2.0], [2.0, 1.0]])
