"""The query-plan layer (core/plan.py): golden bucketing (query set ->
exact bucket keys / pad shapes), mixed-kind execution parity against the
per-query references, scatter-to-owner semantics, and the plan-stats
invariants on a mixed SO + MOO + 3-objective service cohort."""
import jax
import numpy as np
import pytest

from repro.core import (BOConfig, Constraint, Objective, Repository,
                        scout_search_space)
from repro.core.acquisition import mc_ehvi_batched, mc_ehvi_nd
from repro.core.gp import (batched_posterior, batched_sample, fit_gp,
                           fit_gp_batched, gp_loo_samples)
from repro.core.plan import (CohortLimits, EhviQuery, FitQuery,
                             LooSampleQuery, PlanExecutor,
                             PosteriorDrawQuery, PosteriorQuery,
                             SampleQuery, StepPlanner)
from repro.serve.search_service import SearchRequest, SearchService
from repro.simdata import make_emulator

TOL = 1e-4


def _stack(rng, sizes, d=3):
    xs = [rng.random((n, d)) for n in sizes]
    return fit_gp_batched(xs, [x[:, 0] + np.sin(3 * x[:, 1]) for x in xs])


def _by_kind(plan):
    return {(b.kind, b.key): b for b in plan.buckets}


def test_golden_bucketing_posterior():
    """(q, d) bucket keys; observation axis rounds to 8, fused lane axis
    to a power of two — asserted from the PLAN alone, nothing runs."""
    rng = np.random.default_rng(0)
    st_a = _stack(rng, (5, 9))          # m=2, n_max=9
    st_b = _stack(rng, (4,))            # m=1, n_max=4
    g25, g13 = rng.random((25, 3)), rng.random((13, 3))
    plan = StepPlanner().plan([
        PosteriorQuery(st_a, g25), PosteriorQuery(st_b, g25),
        PosteriorQuery(st_a, g13)])
    assert plan.stats() == {"batches": 2, "queries": 3}
    b = _by_kind(plan)
    big = b[("posterior", (25, 3))]
    assert big.indices == (0, 1)
    assert big.pads == {"n_pad": 16, "m_pad": 4, "lanes": 3}
    small = b[("posterior", (13, 3))]
    assert small.indices == (2,)
    assert small.pads == {"n_pad": 16, "m_pad": 2, "lanes": 2}


def test_golden_bucketing_sample_loo_ehvi_draw():
    """(S, q, d) / (S, n) / (n_obj, S, q) / (S, q) bucket keys with the
    grid axis rounding to 8 and EHVI boxes to a power of two."""
    rng = np.random.default_rng(1)
    st = _stack(rng, (5, 9))
    xt = rng.random((6, 3))
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    gp = fit_gp(rng.random((6, 2)), rng.random(6))
    obs2 = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    sa = rng.normal(2.0, 1.0, (16, 9))
    obs3 = np.array([[1.0, 2.0, 3.0], [3.0, 2.0, 1.0]])
    planner = StepPlanner()
    plan = planner.plan([
        SampleQuery(st, xt, keys, 32),
        LooSampleQuery(gp, jax.random.PRNGKey(1), 32),
        EhviQuery((sa, sa + 1.0), obs2, np.array([4.0, 4.0])),
        EhviQuery((sa, sa, sa), obs3, np.array([4.0, 4.0, 4.0])),
        PosteriorDrawQuery(np.zeros(9), np.ones(9), 0.0, 1.0,
                           jax.random.PRNGKey(2), 16),
    ])
    assert plan.stats() == {"batches": 5, "queries": 5}
    b = _by_kind(plan)
    assert b[("sample", (32, 6, 3))].pads == \
        {"n_pad": 16, "q_pad": 8, "m_pad": 2, "lanes": 2}
    assert b[("loo", (32, 6))].pads == {"n_pad": 8, "l_pad": 1, "lanes": 1}
    # 3 staircase points -> 4 segments (already a power of two)
    assert b[("ehvi", (2, 16, 9))].pads == \
        {"k_pad": 4, "q_pad": 16, "l_pad": 1, "lanes": 1}
    # 2 front points, 3 objectives: the coordinate grid has 3*2*3 = 18
    # cells, of which 3 are dominated -> 15 boxes, padded up to 16
    e3 = b[("ehvi", (3, 16, 9))]
    assert e3.pads["k_pad"] == 16 and e3.pads["q_pad"] == 16
    # draw queries deliberately stay exact (not jitted)
    assert b[("draw", (16, 9))].pads == {"lanes": 1}


def test_policy_knobs_live_in_planner():
    """Overriding the planner's policy changes the pads — no other
    module needs touching (the acceptance criterion: one home for
    shape policy)."""
    rng = np.random.default_rng(2)
    st = _stack(rng, (5, 9))
    loose = StepPlanner(obs_round_to=1, m_round_pow2=False)
    plan = loose.plan([PosteriorQuery(st, rng.random((25, 3)))])
    assert plan.buckets[0].pads == {"n_pad": 9, "m_pad": 2, "lanes": 2}


def test_mixed_kind_plan_executes_and_scatters_in_order():
    """One plan carrying every node kind: per-query results match the
    per-query references, and callable owners fire in query order."""
    rng = np.random.default_rng(3)
    st = _stack(rng, (5, 9))
    grid = rng.random((12, 3))
    xt = rng.random((6, 3))
    skeys = jax.random.split(jax.random.PRNGKey(4), 2)
    gp = fit_gp(rng.random((7, 2)), rng.random(7))
    lkey = jax.random.PRNGKey(5)
    dkey = jax.random.PRNGKey(6)
    mu_row, var_row = rng.random(12), rng.random(12) + 0.1
    obs = rng.random((5, 2)) * 3.0
    ref = obs.max(axis=0) * 1.1 + 1e-9
    sa, sb = rng.normal(2, 1, (16, 12)), rng.normal(2, 1, (16, 12))

    fired = []
    queries = [
        PosteriorQuery(st, grid, owner=lambda r: fired.append("post")),
        SampleQuery(st, xt, skeys, 32,
                    owner=lambda r: fired.append("sample")),
        LooSampleQuery(gp, lkey, 32, owner=lambda r: fired.append("loo")),
        PosteriorDrawQuery(mu_row, var_row, 2.0, 3.0, dkey, 16,
                           owner=lambda r: fired.append("draw")),
        EhviQuery((sa, sb), obs, ref, owner=lambda r: fired.append("ehvi")),
    ]
    planner = StepPlanner()
    res = PlanExecutor().execute(planner.plan(queries), counters=(c := {}))
    assert fired == ["post", "sample", "loo", "draw", "ehvi"]
    assert set(c) == {"posterior", "sample", "loo", "draw", "ehvi"}
    assert all(v["launches"] == 1 and v["queries"] == 1
               for v in c.values())

    mu, var = res[0]
    mu0, var0 = batched_posterior(st, grid)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu0), atol=TOL)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var0), atol=TOL)
    np.testing.assert_allclose(np.asarray(res[1]),
                               np.asarray(batched_sample(st, xt, skeys, 32)),
                               atol=TOL)
    np.testing.assert_allclose(np.asarray(res[2]),
                               np.asarray(gp_loo_samples(gp, lkey, 32)),
                               atol=TOL)
    eps = jax.random.normal(dkey, (16, 12))
    want_draw = (mu_row[None] + np.asarray(eps) * np.sqrt(var_row)[None]) \
        * 3.0 + 2.0
    np.testing.assert_allclose(np.asarray(res[3]), want_draw, atol=TOL)
    want_ehvi = mc_ehvi_batched(sa, sb, obs, ref)
    scale = max(1.0, float(np.abs(want_ehvi).max()))
    np.testing.assert_allclose(res[4], want_ehvi, atol=TOL * scale)


def test_ehvi_node_three_objectives_matches_oracle():
    """The fused box-decomposition EHVI node vs the recursive-sweep f64
    oracle, n=3 — including an empty and a single-point front sharing
    one launch."""
    rng = np.random.default_rng(7)
    fronts = [rng.random((5, 3)) * 4.0,
              np.array([[1.0, 1.0, 1.0]]),
              np.empty((0, 3))]
    queries, oracles = [], []
    for obs in fronts:
        ref = (obs.max(axis=0) * 1.1 + 1e-9 if len(obs)
               else np.array([4.0, 4.0, 4.0]))
        samples = tuple(rng.normal(2.0, 1.5, (8, 6)) for _ in range(3))
        queries.append(EhviQuery(samples, obs, ref))
        oracles.append(mc_ehvi_nd(samples, obs, ref))
    plan = StepPlanner().plan(queries)
    assert plan.stats() == {"batches": 1, "queries": 3}
    res = PlanExecutor().execute(plan)
    for got, want in zip(res, oracles):
        scale = max(1.0, float(np.abs(want).max()))
        np.testing.assert_allclose(got, want, atol=TOL * scale)


def test_ehvi_deep_front_chunks_box_axis_and_matches_oracle():
    """A deep 3-objective front whose decomposition exceeds one launch
    block: the planner pads the box axis to a chunk multiple (not a
    power of two) and the scanned launch still matches the oracle."""
    from repro.core.acquisition import EHVI_BOX_CHUNK
    rng = np.random.default_rng(9)
    # anti-correlated points are mutually non-dominated -> deep front
    a = np.linspace(0.0, 1.0, 12)
    obs = np.column_stack([a, 1.0 - a, (a * 7.3) % 1.0]) * 4.0
    ref = obs.max(axis=0) * 1.1 + 1e-9
    samples = tuple(rng.normal(2.0, 1.5, (4, 3)) for _ in range(3))
    plan = StepPlanner().plan([EhviQuery(samples, obs, ref)])
    k_pad = plan.buckets[0].pads["k_pad"]
    assert k_pad > EHVI_BOX_CHUNK and k_pad % EHVI_BOX_CHUNK == 0
    (got,) = PlanExecutor().execute(plan)
    want = mc_ehvi_nd(samples, obs, ref)
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, atol=TOL * scale)


def test_ehvi_observed_shape_mismatch_rejected():
    """observed columns must match the objective count — a mismatch is
    an immediate planning error, not a silently garbled front."""
    rng = np.random.default_rng(10)
    sa = rng.normal(2.0, 1.0, (4, 3))
    with pytest.raises(ValueError, match="observed"):
        StepPlanner().plan([EhviQuery((sa, sa, sa),
                                      rng.random((3, 2)) * 4.0,
                                      np.array([4.0, 4.0, 4.0]))])


def test_enumerate_buckets_covers_live_plan_signatures():
    """The enumerated vocabulary is CLOSED over a cohort within its
    limits: every bucket a live mixed plan produces (draw excepted —
    unjitted) has a launch signature among the enumerated ones, with
    exact key dims normalised to their padded values."""
    rng = np.random.default_rng(11)
    st = _stack(rng, (5, 9))                       # m=2, n<=9, d=3
    xt = rng.random((6, 3))
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    gp = fit_gp(rng.random((6, 3)), rng.random(6))
    obs2 = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    sa = rng.normal(2.0, 1.0, (16, 9))
    planner = StepPlanner()
    plan = planner.plan([
        PosteriorQuery(st, rng.random((25, 3))),
        SampleQuery(st, xt, keys, 32),
        LooSampleQuery(gp, jax.random.PRNGKey(1), 32),
        EhviQuery((sa, sa + 1.0), obs2, np.array([4.0, 4.0])),
        PosteriorDrawQuery(np.zeros(9), np.ones(9), 0.0, 1.0,
                           jax.random.PRNGKey(2), 16),
    ])
    limits = CohortLimits(d=3, q_grid=25, max_obs=9, max_lanes=2,
                          n_samples=(32,), n_mc=(16,),
                          n_objectives=(2,), max_ehvi_boxes=4)
    enumerated = planner.enumerate_buckets(limits)
    sigs = {planner.launch_signature(b) for b in enumerated}
    # no duplicate shapes, no unjitted draw buckets in the vocabulary
    assert len(sigs) == len(enumerated)
    assert all(b.kind != "draw" for b in enumerated)
    for b in plan.buckets:
        if b.kind == "draw":
            continue
        assert planner.launch_signature(b) in sigs, (b.kind, b.key, b.pads)
    # signature normalisation: the live sample bucket keys the EXACT
    # grid length (6) but signs at the padded one (8), equal to its
    # enumerated twin
    live = {b.kind: b for b in plan.buckets}
    assert live["sample"].key == (32, 6, 3)
    assert planner.launch_signature(live["sample"]) == \
        ("sample", 32, 8, 3, 16, 2)


def test_plan_executor_fused_posterior_matches_default():
    """PlanExecutor(fused_posterior=True) routes posterior buckets
    through the fused kernel dispatch: (mu, var) match the vmapped
    baseline, and the in-kernel EI head matches the eager
    expected_improvement chain the default path uses."""
    rng = np.random.default_rng(12)
    st_a = _stack(rng, (5, 9))
    st_b = _stack(rng, (4,))
    grid = rng.random((13, 3))

    def queries():
        return [PosteriorQuery(st_a, grid),
                PosteriorQuery(st_b, grid, best=0.4)]

    planner = StepPlanner()
    base = PlanExecutor().execute(planner.plan(queries()))
    fused = PlanExecutor(fused_posterior=True).execute(
        planner.plan(queries()))
    assert len(base[0]) == 2 and len(base[1]) == 3
    for b, f in zip(base, fused):
        assert len(b) == len(f)
        for want, got in zip(b, f):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=TOL)


# -- plan stats on a live mixed cohort ---------------------------------------


EMU = make_emulator()
SPACE = scout_search_space()
WID = EMU.workload_ids()[6]


def _support_repo(wid=WID, users=2, runs=12, seed=99):
    repo = Repository()
    rng = np.random.default_rng(seed)
    for u in range(users):
        for ci in rng.choice(len(SPACE), runs, replace=False):
            repo.add_run(EMU.make_record(f"anon-{u}", wid,
                                         SPACE.configs[ci], rng))
    return repo


def test_plan_stats_invariants_mixed_so_moo_3obj_cohort():
    """plan_batches <= plan_queries always, and the aggregate counters
    are exactly the sum of the per-kind ones — on a cohort mixing
    single-objective, 2-objective, and 3-objective karasu tenants."""
    svc = SearchService(_support_repo(), slots=3)
    cons = [Constraint("runtime", EMU.runtime_target(WID, 50))]
    cfg = BOConfig(max_iters=5)
    svc.submit(SearchRequest(SPACE, lambda c: EMU.run(WID, c, rng=None),
                             Objective("cost"), cons, method="karasu",
                             bo_config=cfg, seed=0))
    svc.submit(SearchRequest(
        SPACE, lambda c: EMU.run(WID, c, rng=None), None, cons,
        method="karasu", bo_config=cfg, seed=1,
        objectives=[Objective("cost"), Objective("energy")], n_mc=8))
    svc.submit(SearchRequest(
        SPACE, lambda c: EMU.run(WID, c, rng=None), None, cons,
        method="karasu", bo_config=cfg, seed=2,
        objectives=[Objective("cost"), Objective("energy"),
                    Objective("runtime")], n_mc=8))
    done = {c.rid: c.result for c in svc.run()}
    assert sorted(done) == [0, 1, 2]
    # the 3-objective session produced a (k, 3) front
    front = done[2].meta["pareto_front"]
    assert front.ndim == 2 and front.shape[1] == 3 and len(front) >= 1

    s = svc.stats
    assert s["plan_batches"] >= 1
    assert s["plan_batches"] <= s["plan_queries"]
    assert s["plan_batches"] == (s["posterior_batches"]
                                 + s["sample_batches"] + s["ehvi_batches"]
                                 + s["fit_batches"])
    assert s["plan_queries"] == (s["posterior_queries"]
                                 + s["sample_queries"] + s["ehvi_jobs"]
                                 + s["fit_jobs"])
    # fusion engaged on every leg, the fit round included
    assert s["posterior_batches"] < s["posterior_queries"]
    assert s["sample_batches"] < s["sample_queries"]
    assert s["ehvi_batches"] <= s["ehvi_jobs"]
    assert 0 < s["fit_batches"] < s["fit_jobs"]


def test_plan_stats_zero_without_fusion():
    """The loop baselines never enter the plan: with
    fuse_posteriors=False, fuse_samples=False the only planned launches
    are the fit rounds, which ALWAYS ride the plan (the fit leg is a
    first-class plan node with no loop twin)."""
    svc = SearchService(_support_repo(), slots=1, fuse_posteriors=False,
                        fuse_samples=False)
    svc.submit(SearchRequest(
        SPACE, lambda c: EMU.run(WID, c, rng=None), None,
        [Constraint("runtime", EMU.runtime_target(WID, 50))],
        method="karasu", bo_config=BOConfig(max_iters=4), seed=0,
        objectives=[Objective("cost"), Objective("energy"),
                    Objective("runtime")], n_mc=8))
    (c,) = svc.run()
    assert len(c.result.observations) == 4
    assert svc.stats["plan_batches"] == svc.stats["fit_batches"] > 0
    assert svc.stats["plan_queries"] == svc.stats["fit_jobs"]
    assert svc.stats["posterior_batches"] == 0
    assert svc.stats["sample_batches"] == 0
    assert svc.stats["ehvi_batches"] == 0


def test_posterior_form_ehvi_query_shares_sample_form_bucket():
    """A posterior-form EhviQuery (mu/var rows + PRNG keys, no
    materialised samples) must land in the same ``("ehvi", (n_obj, S,
    q))`` bucket as its sample-form twin — the fused executor relies on
    mixed buckets, and the AOT vocabulary must not split on form."""
    rng = np.random.default_rng(9)
    obs = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    ref = np.array([4.0, 4.0])
    sa = rng.normal(2.0, 1.0, (16, 9))
    sample_q = EhviQuery((sa, sa + 1.0), obs, ref)
    post_q = EhviQuery(
        None, obs, ref,
        mu=(rng.normal(size=9), rng.normal(size=9)),
        var=(rng.uniform(0.1, 1.0, 9), rng.uniform(0.1, 1.0, 9)),
        y_mean=(0.0, 0.0), y_std=(1.0, 1.0),
        keys=(jax.random.PRNGKey(0), jax.random.PRNGKey(1)), n_mc=16)
    planner = StepPlanner()
    assert planner.bucket_key(post_q) == planner.bucket_key(sample_q) \
        == ("ehvi", (2, 16, 9))
    plan = planner.plan([sample_q, post_q])
    assert plan.stats() == {"batches": 1, "queries": 2}
    # both forms execute through one launch, fused or vmapped, and agree
    from repro.core.plan import PlanExecutor
    outs = {}
    for name, ex in (("vmapped", PlanExecutor(donate=False)),
                     ("fused", PlanExecutor(fused_ehvi=True, impl="xla",
                                            donate=False))):
        got = []
        q1 = EhviQuery((sa, sa + 1.0), obs, ref,
                       owner=lambda r: got.append(np.asarray(r)))
        q2 = EhviQuery(None, obs, ref, mu=post_q.mu, var=post_q.var,
                       y_mean=post_q.y_mean, y_std=post_q.y_std,
                       keys=post_q.keys, n_mc=16,
                       owner=lambda r: got.append(np.asarray(r)))
        ex.execute(planner.plan([q1, q2]))
        outs[name] = got
    for a, b in zip(outs["vmapped"], outs["fused"]):
        np.testing.assert_allclose(a, b, atol=1e-5)


def _fit_q(rng, n, steps, d=3, warm=False):
    x = rng.random((n, d)).astype(np.float32)
    y = (x[:, 0] + np.sin(3 * x[:, 1])).astype(np.float32)
    if warm:
        return FitQuery(x, y, 0.1, steps,
                        init_ls=rng.normal(0, 0.3, d).astype(np.float32),
                        init_sf=np.float32(rng.normal(0, 0.3)))
    return FitQuery(x, y, 0.1, steps)


def test_golden_bucketing_fit_warm_and_cold():
    """Warm (short-refine) and cold (full-schedule) FitQuery nodes of
    one step land in DIFFERENT buckets by construction — ``steps`` and
    ``noise`` are jit-static on the fit launch, so both sit in the
    bucket key — while the padded shapes follow the shared policy:
    observation axis to multiples of 8, lane axis to a power of two."""
    rng = np.random.default_rng(21)
    plan = StepPlanner().plan([
        _fit_q(rng, 5, 120), _fit_q(rng, 9, 120),
        _fit_q(rng, 7, 16, warm=True), _fit_q(rng, 4, 16, warm=True)])
    assert plan.stats() == {"batches": 2, "queries": 4}
    b = _by_kind(plan)
    cold = b[("fit", (3, 120, 0.1))]
    assert cold.indices == (0, 1)
    assert cold.pads == {"n_pad": 16, "m_pad": 2, "lanes": 2}
    warm = b[("fit", (3, 16, 0.1))]
    assert warm.indices == (2, 3)
    assert warm.pads == {"n_pad": 8, "m_pad": 2, "lanes": 2}
    # the signature names the schedule rung and noise explicitly, so
    # they can never be confused with the (positional) axis pads
    planner = StepPlanner()
    assert planner.launch_signature(cold) == \
        ("fit", 3, 16, 2, ("steps", 120), ("noise", 0.1))
    assert planner.launch_signature(warm) == \
        ("fit", 3, 8, 2, ("steps", 16), ("noise", 0.1))


def test_enumerate_buckets_walks_both_fit_rungs():
    """The AOT vocabulary carries BOTH fit schedule rungs (warm refine
    + cold full fit) across the whole (n_pad, m_pad) ladder, and live
    warm/cold fit buckets sign inside it. Disabling warm starting
    (``fit_warm_steps=None``) collapses the ladder to the cold rung
    only — at which point a live warm bucket is out-of-vocabulary."""
    planner = StepPlanner()
    limits = CohortLimits(d=3, q_grid=8, max_obs=9, max_lanes=2)
    assert planner.fit_step_rungs(limits) == [16, 120]
    cold_only = CohortLimits(d=3, q_grid=8, max_obs=9, max_lanes=2,
                             fit_warm_steps=None)
    assert planner.fit_step_rungs(cold_only) == [120]
    sigs = {planner.launch_signature(b)
            for b in planner.enumerate_buckets(limits) if b.kind == "fit"}
    # full cross product: 2 rungs x obs pads {8, 16} x lane pads {1, 2}
    assert sigs == {("fit", 3, n, m, ("steps", s), ("noise", 0.1))
                    for s in (16, 120) for n in (8, 16) for m in (1, 2)}
    rng = np.random.default_rng(22)
    live = StepPlanner().plan([
        _fit_q(rng, 9, 120), _fit_q(rng, 6, 16, warm=True)])
    for b in live.buckets:
        assert planner.launch_signature(b) in sigs, (b.key, b.pads)
    cold_sigs = {planner.launch_signature(b)
                 for b in planner.enumerate_buckets(cold_only)
                 if b.kind == "fit"}
    warm_live = next(b for b in live.buckets if b.key[1] == 16)
    assert planner.launch_signature(warm_live) not in cold_sigs
