"""GP + RGPE unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (WeightJob, build_ensemble, compute_weights,
                        compute_weights_batched, compute_weights_multi,
                        ensemble_posterior, fit_gp)
from repro.core.gp import (gp_loo_samples, gp_posterior, gp_posterior_raw,
                           gp_sample, stack_gps)


def _surface(x):
    return np.sin(3 * x[:, 0]) + 0.5 * x[:, 1]


def test_gp_interpolates_and_ranks():
    rng = np.random.default_rng(0)
    x = rng.random((12, 2))
    y = _surface(x)
    gp = fit_gp(x, y, noise=0.01)
    xq = rng.random((50, 2))
    mu, _ = gp_posterior_raw(gp, xq)
    corr = np.corrcoef(np.asarray(mu), _surface(xq))[0, 1]
    assert corr > 0.8, corr


def test_gp_posterior_variance_shrinks_at_observed():
    rng = np.random.default_rng(1)
    x = rng.random((8, 2))
    y = _surface(x)
    gp = fit_gp(x, y)
    _, var_obs = gp_posterior(gp, x)
    far = np.full((1, 2), 5.0)
    _, var_far = gp_posterior(gp, far)
    assert float(jnp.mean(var_obs)) < float(var_far[0])


def test_gp_sample_shape_and_spread():
    rng = np.random.default_rng(2)
    x = rng.random((6, 2))
    gp = fit_gp(x, _surface(x))
    s = gp_sample(gp, rng.random((9, 2)), jax.random.PRNGKey(0), 64)
    assert s.shape == (64, 9)
    assert float(jnp.std(s)) > 0


def test_rgpe_weights_prefer_related_model():
    rng = np.random.default_rng(3)
    xs = rng.random((30, 2))
    related = fit_gp(xs, _surface(xs))                      # same surface
    unrelated = fit_gp(xs, rng.normal(size=30))             # noise
    x_t = rng.random((8, 2))
    target = fit_gp(x_t, _surface(x_t))
    w = np.asarray(compute_weights([related, unrelated], target,
                                   jax.random.PRNGKey(0)))
    assert w.shape == (3,)
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-6)
    assert np.all(w >= 0)
    assert w[0] > w[1], w  # related model must outweigh noise model


def test_rgpe_ensemble_posterior_improves_ranking():
    rng = np.random.default_rng(4)
    xs = rng.random((40, 2))
    related = fit_gp(xs, _surface(xs))
    x_t = rng.random((4, 2))     # very few target points
    target = fit_gp(x_t, _surface(x_t))
    ens = build_ensemble([related], target, jax.random.PRNGKey(1))
    xq = rng.random((60, 2))
    mu_e, _ = ensemble_posterior(ens, xq)
    mu_t, _ = gp_posterior(target, xq)
    truth = _surface(xq)
    corr_e = np.corrcoef(np.asarray(mu_e), truth)[0, 1]
    corr_t = np.corrcoef(np.asarray(mu_t), truth)[0, 1]
    assert corr_e > corr_t - 0.05  # ensemble at least as informative


def test_loo_samples_shape():
    rng = np.random.default_rng(5)
    x = rng.random((7, 2))
    gp = fit_gp(x, _surface(x))
    s = gp_loo_samples(gp, jax.random.PRNGKey(0), 32)
    assert s.shape == (32, 7)
    assert bool(jnp.all(jnp.isfinite(s)))


def test_compute_weights_multi_matches_per_ensemble_path():
    """The cross-tenant scorer (one padded ranking-loss launch for many
    ensembles, ragged n_obs and m) must reproduce compute_weights_batched
    per ensemble to <= 1e-4 — including the n_obs < 2 uniform-weight
    short-circuit."""
    rng = np.random.default_rng(7)
    jobs, want = [], []
    # heterogeneous: (n_bases, n_target_obs) incl. a single-obs target
    for j, (nb, nt) in enumerate([(2, 6), (3, 9), (1, 4), (2, 1)]):
        bases = []
        for i in range(nb):
            xb = rng.random((10 + i, 2))
            bases.append(fit_gp(xb, _surface(xb)))
        xt = rng.random((nt, 2))
        tgt = fit_gp(xt, _surface(xt))
        stack = stack_gps(bases)
        key = jax.random.PRNGKey(j)
        jobs.append(WeightJob(stack, tgt, key, n_samples=128))
        want.append(compute_weights_batched(stack, tgt, key,
                                            n_samples=128))
    got = compute_weights_multi(jobs)
    assert len(got) == len(want)
    for w_got, w_want in zip(got, want):
        np.testing.assert_allclose(np.asarray(w_got), np.asarray(w_want),
                                   atol=1e-4)
        np.testing.assert_allclose(float(jnp.sum(w_got)), 1.0, atol=1e-5)


def test_compute_weights_multi_ragged_sample_counts():
    """Jobs may carry different n_samples (per-tenant rgpe_samples)."""
    rng = np.random.default_rng(8)
    jobs, want = [], []
    for j, s in enumerate([64, 96]):
        xb = rng.random((12, 2))
        stack = stack_gps([fit_gp(xb, _surface(xb))])
        xt = rng.random((5, 2))
        tgt = fit_gp(xt, _surface(xt))
        key = jax.random.PRNGKey(10 + j)
        jobs.append(WeightJob(stack, tgt, key, n_samples=s))
        want.append(compute_weights_batched(stack, tgt, key, n_samples=s))
    for w_got, w_want in zip(compute_weights_multi(jobs), want):
        np.testing.assert_allclose(np.asarray(w_got), np.asarray(w_want),
                                   atol=1e-4)
