"""Training substrate: optimizers, checkpoint/restore/elastic, fault
tolerance, data pipeline determinism, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train.checkpoint import (latest_checkpoint, list_checkpoints,
                                    restore_checkpoint, save_checkpoint)
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.fault import FailureInjector, StepWatchdog, run_resilient
from repro.train.grad_compress import (compress_with_error_feedback,
                                       init_error_feedback)
from repro.train.optim import adafactor, adamw, cosine_schedule
from repro.train.step import make_train_step


def _setup(arch="minitron-8b", opt_name="adamw"):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt = adamw() if opt_name == "adamw" else adafactor()
    return cfg, bundle, params, opt


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizers_descend(opt_name):
    cfg, bundle, params, opt = _setup(opt_name=opt_name)
    opt_state = opt.init(params)
    step = make_train_step(bundle, opt, lambda s: 1e-2, microbatches=1)
    data = SyntheticLM(cfg.vocab, 16, 4, seed=0)
    losses = []
    for i in range(8):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        params, opt_state, m = step(params, opt_state, b,
                                    jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses  # same batch -> must descend


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,)), jnp.zeros((2, 2), jnp.bfloat16)]}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, tree)
    save_checkpoint(d, 7, jax.tree.map(lambda x: x + 1, tree))
    step, path = latest_checkpoint(d)
    assert step == 7
    back = restore_checkpoint(path, tree)
    np.testing.assert_allclose(np.asarray(back["a"]),
                               np.asarray(tree["a"]) + 1)
    # corrupt directory is skipped
    os.makedirs(os.path.join(d, "step_00000009"))
    assert latest_checkpoint(d)[0] == 7


def test_resilient_loop_recovers_from_failures(tmp_path):
    cfg, bundle, params0, opt = _setup()
    opt_state0 = opt.init(params0)
    step = make_train_step(bundle, opt, cosine_schedule(1e-3, 2, 50))
    data = SyntheticLM(cfg.vocab, 12, 2, seed=1)
    inj = FailureInjector(fail_at=[5, 12])
    report = run_resilient(
        init_state=lambda: (params0, opt_state0),
        step_fn=step,
        batch_at=lambda s: {k: jnp.asarray(v)
                            for k, v in data.batch_at(s).items()},
        total_steps=16, ckpt_dir=str(tmp_path / "ck"), ckpt_every=4,
        injector=inj)
    assert report.steps_done == 16
    assert report.restarts == 2
    assert inj.injected == [5, 12]
    assert all(np.isfinite(report.losses))


def test_data_pipeline_deterministic_and_prefetch():
    data = SyntheticLM(vocab=101, seq_len=8, global_batch=2, seed=3)
    b1, b2 = data.batch_at(5), data.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    pf = Prefetcher(data, start_step=0, depth=2)
    try:
        first = pf.next()
        np.testing.assert_array_equal(first["tokens"],
                                      data.batch_at(0)["tokens"])
    finally:
        pf.close()


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(timeout_factor=3.0)
    for i in range(6):
        wd.observe(i, 0.1)
    assert not wd.stragglers
    wd.observe(6, 1.0)
    assert wd.stragglers == [6]


def test_grad_compression_error_feedback_preserves_sum():
    """Error feedback: compressed grads + residuals == raw grads."""
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    ef = init_error_feedback(grads)
    comp, new_ef = compress_with_error_feedback(grads, ef)
    np.testing.assert_allclose(
        np.asarray(comp["w"]) + np.asarray(new_ef["w"]),
        np.asarray(grads["w"]), atol=1e-6)
    # int8 quantization error bounded by scale/2
    scale = np.abs(np.asarray(grads["w"])).max() / 127.0
    assert np.abs(np.asarray(new_ef["w"])).max() <= scale * 0.5 + 1e-7
