"""Fused posterior-draw + EHVI bucket kernel vs its oracles.

The fused kernel collapses one (n_obj, S, q) EHVI bucket — per-lane
affine draws from standardised posterior rows, then the box-
decomposition overlap-volume reduction — into one launch. Contract:
match the f64 recursive-sweep ``mc_ehvi_nd`` oracle (through the same
box decompositions the planner preps) to 1e-4 on every bucket shape the
planner can emit, including the degenerate ones — empty fronts,
all-dominated candidates, +inf-padded candidates, repeated padding
lanes, and box counts past the scan threshold.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.acquisition import (mc_ehvi_nd, nondominated_boxes,
                                    pareto_front)
from repro.kernels.fused_ehvi import (fused_ehvi, fused_ehvi_pallas,
                                      fused_ehvi_ref)
from repro.kernels.fused_ehvi.ref import BOX_CHUNK

TOL = 1e-4


def _bucket(n_obj=2, seed=0, lanes=2, n_obs=7, q=11, s=32):
    """Lanes with distinct fronts, unpacked into the fused launch's
    arrays exactly as ``PlanExecutor._exec_ehvi_fused`` assembles them
    (box axes padded to the deepest lane with +inf zero-volume boxes).
    Returns (args, per-lane (observed, ref) pairs)."""
    rng = np.random.default_rng(seed)
    los, his, refs, fronts = [], [], [], []
    for li in range(lanes):
        observed = rng.normal(size=(n_obs, n_obj))
        ref = observed.max(axis=0) * 1.1 + 1e-9
        lo, hi = nondominated_boxes(pareto_front(observed),
                                    np.asarray(ref, np.float64))
        los.append(lo)
        his.append(hi)
        refs.append(ref)
        fronts.append((observed, ref))
    k_pad = max(lo.shape[0] for lo in los)
    los = np.stack([np.pad(lo, ((0, k_pad - lo.shape[0]), (0, 0)),
                           constant_values=np.inf) for lo in los])
    his = np.stack([np.pad(hi, ((0, k_pad - hi.shape[0]), (0, 0)),
                           constant_values=np.inf) for hi in his])
    mu = rng.normal(size=(lanes, n_obj, q)).astype(np.float32)
    var = rng.uniform(0.1, 1.0, (lanes, n_obj, q)).astype(np.float32)
    y_mean = rng.normal(size=(lanes, n_obj)).astype(np.float32)
    y_std = rng.uniform(0.5, 1.5, (lanes, n_obj)).astype(np.float32)
    eps = np.asarray(jax.vmap(
        lambda k: jax.random.normal(k, (s, q)))(
            jax.random.split(jax.random.PRNGKey(seed), lanes * n_obj))
    ).reshape(lanes, n_obj, s, q)
    args = [jnp.asarray(a.astype(np.float32)) for a in
            (los, his, np.stack(refs), mu, var, y_mean, y_std, eps)]
    return args, fronts


def _raw_draws(args):
    """The raw-scale draws the launch consumes, f64, (L, D, S, q)."""
    _, _, _, mu, var, ym, ys, eps = [np.asarray(a, np.float64)
                                     for a in args]
    ps = mu[:, :, None, :] + eps * np.sqrt(var)[:, :, None, :]
    return ps * ys[:, :, None, None] + ym[:, :, None, None]


def _np_ehvi(los, his, refs, ps):
    """Direct f64 box-overlap reduction, no chunking — pins the ref's
    scan path independently of the front-derived oracle."""
    l, k, d = los.shape
    out = np.zeros((l, ps.shape[3]))
    for li in range(l):
        vol = np.ones((ps.shape[2], ps.shape[3], k))
        for dim in range(d):
            w = np.clip(np.minimum(his[li, :, dim], refs[li, dim])[None, None]
                        - np.maximum(los[li, :, dim][None, None],
                                     ps[li, dim][:, :, None]), 0.0, None)
            vol = vol * w
        out[li] = vol.sum(axis=-1).mean(axis=0)
    return out


@pytest.mark.parametrize("n_obj", [2, 3])
def test_ref_matches_f64_oracle(n_obj):
    args, fronts = _bucket(n_obj=n_obj, seed=n_obj)
    got = np.asarray(fused_ehvi_ref(*args))
    ps = _raw_draws(args)
    for li, (observed, ref) in enumerate(fronts):
        want = mc_ehvi_nd(list(ps[li]), observed, ref)
        np.testing.assert_allclose(got[li], want, atol=TOL, rtol=TOL)


@pytest.mark.parametrize("n_obj", [2, 3])
def test_pallas_interpret_matches_oracle_and_ref(n_obj):
    args, fronts = _bucket(n_obj=n_obj, seed=10 + n_obj)
    ref_out = np.asarray(fused_ehvi_ref(*args))
    got = np.asarray(fused_ehvi_pallas(*args, interpret=True))
    np.testing.assert_allclose(got, ref_out, atol=TOL)
    ps = _raw_draws(args)
    for li, (observed, ref) in enumerate(fronts):
        want = mc_ehvi_nd(list(ps[li]), observed, ref)
        np.testing.assert_allclose(got[li], want, atol=TOL, rtol=TOL)


def test_empty_front_is_plain_expected_volume():
    """No observations: one (-inf, +inf) box, so EHVI reduces to the
    expected clipped volume of [draw, ref] — checked against the oracle
    with an empty observed set."""
    args, _ = _bucket(n_obj=2, seed=3, lanes=1, q=6, s=64)
    ref = np.array([2.0, 2.0])
    lo, hi = nondominated_boxes(pareto_front(np.zeros((0, 2))), ref)
    args[0] = jnp.asarray(lo[None].astype(np.float32))
    args[1] = jnp.asarray(hi[None].astype(np.float32))
    args[2] = jnp.asarray(ref[None].astype(np.float32))
    got = np.asarray(fused_ehvi_ref(*args))
    goti = np.asarray(fused_ehvi_pallas(*args, interpret=True))
    ps = _raw_draws(args)
    want = mc_ehvi_nd(list(ps[0]), np.zeros((0, 2)), ref)
    np.testing.assert_allclose(got[0], want, atol=TOL, rtol=TOL)
    np.testing.assert_allclose(goti, got, atol=TOL)


def test_all_dominated_candidates_zero():
    """Every draw lands beyond the reference point: zero improvement on
    every path, not NaN."""
    args, fronts = _bucket(n_obj=2, seed=4, lanes=1, q=5, s=16)
    args[3] = args[3] + 100.0            # mu far past every ref
    args[4] = jnp.zeros_like(args[4]) + 1e-6
    for out in (fused_ehvi_ref(*args),
                fused_ehvi_pallas(*args, interpret=True)):
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_padded_candidates_and_repeated_lanes():
    """The executor's padding contract: +inf-mean / zero-var padded
    candidate columns contribute exactly 0, and a repeated padding lane
    reproduces lane 0's row bit for bit."""
    args, _ = _bucket(n_obj=2, seed=5, lanes=1, q=6, s=16)
    los, his, refs, mu, var, ym, ys, eps = args
    pq = 4
    mu = jnp.pad(mu, ((0, 0), (0, 0), (0, pq)), constant_values=jnp.inf)
    var = jnp.pad(var, ((0, 0), (0, 0), (0, pq)))
    eps = jnp.pad(eps, ((0, 0), (0, 0), (0, 0), (0, pq)))
    padded = [jnp.concatenate([a, a]) for a in
              (los, his, refs, mu, var, ym, ys, eps)]
    for out in (fused_ehvi_ref(*padded),
                fused_ehvi_pallas(*padded, interpret=True)):
        out = np.asarray(out)
        np.testing.assert_array_equal(out[0], out[1])
        np.testing.assert_allclose(out[:, -pq:], 0.0, atol=1e-6)
        assert np.all(np.isfinite(out))


def test_ref_scan_path_past_box_chunk():
    """More boxes than one launch block (and not a chunk multiple):
    the ref must scan fixed-size blocks with zero-volume remainders and
    still match the direct unchunked f64 reduction."""
    rng = np.random.default_rng(6)
    l, k, d, s, q = 1, BOX_CHUNK + 37, 2, 4, 3
    corners = np.sort(rng.random((l, k + 1, d)), axis=1)
    los, his = corners[:, :-1], corners[:, 1:]
    refs = np.full((l, d), 2.0)
    mu = rng.normal(size=(l, d, q))
    var = rng.uniform(0.1, 0.5, (l, d, q))
    ym = np.zeros((l, d))
    ys = np.ones((l, d))
    eps = rng.normal(size=(l, d, s, q))
    args = [jnp.asarray(a, jnp.float32) for a in
            (los, his, refs, mu, var, ym, ys, eps)]
    got = np.asarray(fused_ehvi_ref(*args))
    want = _np_ehvi(*[np.asarray(a, np.float64) for a in
                      (args[0], args[1], args[2])], _raw_draws(args))
    np.testing.assert_allclose(got, want, atol=TOL, rtol=TOL)


def test_pallas_interpret_multi_block_grid():
    """Small block_q / block_k force a multi-program grid along q and
    a multi-iteration box loop (with a non-multiple remainder)."""
    args, _ = _bucket(n_obj=2, seed=7, lanes=2, n_obs=9, q=11, s=16)
    ref_out = np.asarray(fused_ehvi_ref(*args))
    got = np.asarray(fused_ehvi_pallas(*args, block_q=4, block_k=8,
                                       interpret=True))
    np.testing.assert_allclose(got, ref_out, atol=TOL)


def test_staircase_sort_and_block_early_exit_parity():
    """The pallas wrapper sorts each lane's boxes into staircase order
    (ascending lo[0]) and the kernel skips box blocks whose smallest
    lo[0] is +inf. Lanes padded far past their real front depth (the
    fused bucket pads to the deepest lane) must produce identical rows:
    the sort is a permutation of a disjoint decomposition, the skipped
    blocks hold only zero-volume boxes. Checked against the unsorted
    ref and the f64 oracle on a multi-block box axis with a heavily
    +inf-padded shallow lane."""
    args, fronts = _bucket(n_obj=2, seed=9, lanes=2, n_obs=9, q=11, s=16)
    los, his = np.asarray(args[0]), np.asarray(args[1])
    # deep +inf padding on the box axis: shallow lanes become mostly
    # padding blocks once sorted to the tail
    extra = 64 - los.shape[1]
    los = np.pad(los, ((0, 0), (0, extra), (0, 0)),
                 constant_values=np.inf)
    his = np.pad(his, ((0, 0), (0, extra), (0, 0)),
                 constant_values=np.inf)
    # scramble the box order so the test exercises the sort, not a
    # luckily-ordered decomposition
    rng = np.random.default_rng(9)
    for li in range(los.shape[0]):
        perm = rng.permutation(los.shape[1])
        los[li] = los[li, perm]
        his[li] = his[li, perm]
    args[0] = jnp.asarray(los)
    args[1] = jnp.asarray(his)
    ref_out = np.asarray(fused_ehvi_ref(*args))
    # block_k=8 over 64 boxes: the real fronts (<= ~10 boxes) occupy
    # the first block or two, the rest early-exit
    got = np.asarray(fused_ehvi_pallas(*args, block_q=4, block_k=8,
                                       interpret=True))
    np.testing.assert_allclose(got, ref_out, atol=TOL)
    ps = _raw_draws(args)
    for li, (observed, ref) in enumerate(fronts):
        want = mc_ehvi_nd(list(ps[li]), observed, ref)
        np.testing.assert_allclose(got[li], want, atol=TOL, rtol=TOL)


def test_dispatcher_impls_and_errors():
    args, _ = _bucket(n_obj=2, seed=8, lanes=1, q=5, s=8)
    via_xla = fused_ehvi(*args, impl="xla")
    np.testing.assert_allclose(np.asarray(via_xla),
                               np.asarray(fused_ehvi_ref(*args)), atol=0)
    # auto on CPU CI resolves to the XLA reference and stays finite
    assert np.all(np.isfinite(np.asarray(fused_ehvi(*args, impl="auto"))))
    with pytest.raises(ValueError, match="fused_ehvi impl"):
        fused_ehvi(*args, impl="nope")
