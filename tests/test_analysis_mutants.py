"""Mutation tests: each seeded bug in ``repro.analysis.mutants`` must
be CAUGHT by its rule. This pins the analyzer's detection power — a
refactor that silently blinds a rule fails here, not in production."""
import pytest

from repro.analysis import mutants
from repro.analysis.donation_safety import (check_donated_params,
                                            check_post_donation_reads)
from repro.analysis.padding_taint import check_padding_taint
from repro.analysis.prng_audit import (check_fold_in_tags,
                                       check_schedule_collisions)
from repro.analysis.vocab_closure import check_closure, check_weak_types


def test_dropped_mask_leaks_padding():
    findings = check_padding_taint([mutants.bad_mask_posterior_spec()])
    assert findings and all(f.rule == "padding-taint" and
                            f.severity == "error" for f in findings)
    # the taint path names the unmasked cross-kernel contraction
    assert any("dot_general" in f.path for f in findings)


def test_cross_lane_reduction_leaks_pad_lanes():
    findings = check_padding_taint(
        [mutants.lane_leak_posterior_spec()])
    assert findings and all(f.launch == "posterior[lane-leak]"
                            for f in findings)


def test_donating_a_cached_param_is_flagged():
    findings = check_donated_params(mutants.DONATES_CACHED_PARAM_SRC,
                                    "mutant")
    assert len(findings) == 1
    assert "log_ls" in findings[0].path
    assert findings[0].severity == "error"


def test_post_donation_read_is_flagged():
    findings = check_post_donation_reads(
        mutants.POST_DONATION_READ_SRC, "mutant")
    assert len(findings) == 1
    assert "parts" in findings[0].path


def test_missing_alias_guard_is_flagged():
    findings = check_post_donation_reads(
        mutants.MISSING_ALIAS_GUARD_SRC, "mutant")
    assert len(findings) == 1
    assert "_fresh_parts" in findings[0].path


def test_vocabulary_hole_is_flagged():
    findings = check_closure(
        planner_factory=mutants.vocab_hole_planner_factory(),
        shard_sizes=(1,))
    assert findings and all(f.launch == "ehvi" for f in findings)


def test_fit_rung_vocabulary_hole_is_flagged():
    """Dropping the warm steps rung from the fit enumeration must
    surface: the live cohort (whose warm cache emits short-refine
    FitQuery nodes) produces fit signatures outside the vocabulary."""
    findings = check_closure(
        planner_factory=mutants.fit_rung_hole_planner_factory(),
        shard_sizes=(1,))
    assert findings and all(f.launch == "fit" for f in findings)
    assert any("'steps', 16" in f.path for f in findings)


def test_weak_typed_launch_arg_is_flagged():
    findings = check_weak_types([mutants.weak_type_posterior_spec()])
    assert len(findings) == 1
    assert findings[0].path == "jitter"


def test_flattened_key_tag_collides():
    findings = check_schedule_collisions(
        derive=mutants.colliding_derive_key, purposes=(0, 1))
    assert len(findings) == 1
    assert findings[0].severity == "error"


def test_arithmetic_fold_in_tag_is_flagged():
    findings = check_fold_in_tags(mutants.ARITHMETIC_TAG_SRC, "mutant")
    assert len(findings) == 1
    assert "mutant:5" in findings[0].path


def test_clean_sources_pass_the_mutant_rules():
    """The flip side: the real executor passes the same source checks
    the mutants fail (no false positives from the rule itself)."""
    assert check_post_donation_reads() == []
    assert check_closure(shard_sizes=(1,)) == []
