"""Grouped GEMM (MoE expert matmul): padded-bmm XLA path + megablox-style
Pallas kernel vs the masked-dense oracle and lax.ragged_dot."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped_gemm import grouped_gemm, grouped_gemm_ref


def _sizes(key, m, g):
    w = jax.random.dirichlet(key, jnp.ones(g)) * m
    s = jnp.floor(w).astype(jnp.int32)
    return s.at[-1].add(m - jnp.sum(s))


@pytest.mark.parametrize("m,k,n,g", [
    (64, 16, 24, 4), (200, 32, 48, 8), (37, 8, 8, 3), (128, 64, 128, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["xla", "ragged", "pallas_interpret"])
def test_grouped_gemm_vs_oracle(m, k, n, g, dtype, impl):
    key = jax.random.PRNGKey(0)
    lhs = jax.random.normal(key, (m, k), dtype)
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (g, k, n), dtype)
    sizes = _sizes(jax.random.fold_in(key, 2), m, g)
    ref = grouped_gemm_ref(lhs, rhs, sizes)
    out = grouped_gemm(lhs, rhs, sizes, impl=impl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol,
                               err_msg=f"{impl} {(m, k, n, g)}")


def test_empty_groups_and_single_group():
    key = jax.random.PRNGKey(3)
    lhs = jax.random.normal(key, (32, 8))
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 16))
    sizes = jnp.array([0, 32, 0, 0], jnp.int32)   # all rows in group 1
    ref = grouped_gemm_ref(lhs, rhs, sizes)
    for impl in ["xla", "pallas_interpret"]:
        out = grouped_gemm(lhs, rhs, sizes, impl=impl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4, rtol=2e-4, err_msg=impl)


def test_padded_bmm_flops_near_ideal():
    """The reason this kernel exists: XLA-CPU's ragged_dot costs g x the
    dropless ideal; the padded bmm stays within ~1.3x at realistic
    group sizes."""
    from repro.launch import hlo_stats
    m, k, n, g = 4096, 64, 32, 8
    c = jax.jit(lambda l, r, s: grouped_gemm(l, r, s, impl="xla")).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((g, k, n), jnp.float32),
        jax.ShapeDtypeStruct((g,), jnp.int32)).compile()
    s = hlo_stats.analyze(c.as_text())
    ratio = s["dot_flops"] / (2 * m * k * n)
    assert ratio < 1.4, ratio


def test_grouped_gemm_differentiable():
    key = jax.random.PRNGKey(4)
    lhs = jax.random.normal(key, (48, 8))
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 12))
    sizes = _sizes(jax.random.fold_in(key, 2), 48, 4)

    def loss(impl):
        return lambda l, r: jnp.sum(
            grouped_gemm(l, r, sizes, impl=impl) ** 2)

    gl_x, gr_x = jax.grad(loss("xla"), argnums=(0, 1))(lhs, rhs)
    gl_r, gr_r = jax.grad(loss("ragged"), argnums=(0, 1))(lhs, rhs)
    np.testing.assert_allclose(np.asarray(gl_x), np.asarray(gl_r),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(gr_x), np.asarray(gr_r),
                               atol=2e-4, rtol=2e-4)
