"""Data-parallel plan execution: the shard-aware shape policy and
launch signatures, the process-shared support stacks, and sharded-vs-
unsharded serving parity.

The parity test runs in a subprocess because the device-count flag must
be set before jax initialises (the main test process keeps 1 device);
both executors then run in THAT one process so they share the emulator,
the spaces, and the jit caches being compared.
"""
import os
import pickle
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import Repository, scout_search_space
from repro.core.plan import Bucket, CohortLimits, StepPlanner
from repro.core.repository import (_SHARED_STACK_FIELDS,
                                   SharedSupportModelStore,
                                   SupportModelStore, load_shared_stack)
from repro.distributed import DistContext, mesh_axis_size
from repro.simdata import make_emulator

EMU = make_emulator()
SPACE = scout_search_space()
WID = EMU.workload_ids()[6]


# -- mesh axis lookups (satellite: model_size on a data-only mesh) ----------

def test_model_size_on_data_only_mesh_is_one():
    # regression: a ("data",)-only mesh carries no model axis; that is a
    # size-1 degree of model parallelism, not a KeyError
    mesh = jax.make_mesh((1,), ("data",))
    ctx = DistContext(mesh=mesh)
    assert ctx.model_size == 1
    assert ctx.data_size == 1
    assert mesh_axis_size(mesh, "model") == 1
    assert mesh_axis_size(mesh, "data") == 1
    assert mesh_axis_size(None, "data") == 1
    assert DistContext().model_size == 1


# -- planner shape policy under lane sharding -------------------------------

def test_round_models_lifts_pow2_rungs_to_shard_multiples():
    # lane_shards=3 is deliberately coprime with the pow2 ladder so the
    # lift is visible on every rung
    p = StepPlanner(lane_shards=3)
    assert [p.round_models(m) for m in (1, 2, 3, 5)] == [3, 3, 6, 9]
    # shards=1 must be the historical pow2 policy, bit for bit
    p1 = StepPlanner(lane_shards=1)
    assert [p1.round_models(m) for m in (1, 2, 3, 5)] == [1, 2, 4, 8]


def test_lane_pads_and_enumerated_buckets_are_shard_divisible():
    limits = CohortLimits(d=5, q_grid=24, max_obs=8, max_lanes=13,
                          n_samples=(32,), n_mc=(8,), n_objectives=(2,),
                          max_ehvi_boxes=16)
    p = StepPlanner(lane_shards=4)
    pads = p._lane_pads(limits.max_lanes)
    assert pads == sorted(set(pads)) and pads
    assert all(v % 4 == 0 for v in pads)
    for b in p.enumerate_buckets(limits):
        lanes_pad = b.pads.get("m_pad", b.pads.get("l_pad"))
        assert lanes_pad is not None and lanes_pad % 4 == 0, b


def test_launch_signature_carries_shard_count():
    limits = CohortLimits(d=5, q_grid=24, max_obs=8, max_lanes=8,
                          n_samples=(32,), n_mc=(8,), n_objectives=(2,),
                          max_ehvi_boxes=16)
    plain = StepPlanner(lane_shards=1)
    sharded = StepPlanner(lane_shards=4)
    sigs_p = {plain.launch_signature(b)
              for b in plain.enumerate_buckets(limits)}
    sigs_s = {sharded.launch_signature(b)
              for b in sharded.enumerate_buckets(limits)}
    # every sharded signature names its shard count — the shard-mapped
    # twin of a shape is a different compiled program
    assert all(s[-1] == ("shards", 4) for s in sigs_s)
    assert not any(("shards", 4) in s for s in sigs_p)
    # stripping the tag leaves shapes of the same families (the sharded
    # vocabulary is the plain one with lane axes lifted to multiples)
    assert {s[0] for s in sigs_s} == {s[0] for s in sigs_p}
    # draw buckets are unjitted: no compile identity, no shard tag
    draw = Bucket("draw", (8, 4), (), {"lanes": 2})
    assert sharded.launch_signature(draw) == plain.launch_signature(draw)


# -- process-shared support stacks ------------------------------------------

def _support_repo(users=2, runs=12, seed=99):
    repo = Repository()
    rng = np.random.default_rng(seed)
    for u in range(users):
        for ci in rng.choice(len(SPACE), runs, replace=False):
            repo.add_run(EMU.make_record(f"anon-{u}", WID,
                                         SPACE.configs[ci], rng))
    return repo


def test_shared_stack_handle_pickles_and_roundtrips_bitwise():
    repo = _support_repo()
    store = SupportModelStore(repo, SPACE)
    wids = sorted(repo.workloads())
    want, ids = store.get_stacked(wids, "cost")
    assert want is not None
    handle = store.export_shared(wids, "cost")
    assert handle is not None
    # the handle crosses the process boundary; the arrays never do
    wire = pickle.dumps(handle)
    got, got_ids = load_shared_stack(pickle.loads(wire))
    assert got_ids == list(ids)
    for f in _SHARED_STACK_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)),
                                      err_msg=f)
    assert got.noise == want.noise
    # unchanged versions: re-export reuses the one live segment
    assert store.export_shared(wids, "cost").shm_name == handle.shm_name
    store.close_shared()


def test_shared_store_worker_twin_caches_and_invalidates():
    repo = _support_repo()
    store = SupportModelStore(repo, SPACE)
    wids = sorted(repo.workloads())
    handle = store.export_shared(wids, "cost")

    worker = SharedSupportModelStore()
    assert worker.get_stacked(wids, "cost") == (None, [])
    worker.publish(wids, "cost", handle)
    stack, ids = worker.get_stacked(wids, "cost")
    assert stack is not None and ids and worker.misses == 1
    again, _ = worker.get_stacked(wids, "cost")
    assert again is stack and worker.hits == 1

    # the repository moves: the owner re-exports at new versions and the
    # worker re-attaches instead of serving the stale stack
    repo.add_run(EMU.make_record(wids[0], WID, SPACE.configs[0],
                                 np.random.default_rng(1)))
    fresh = store.export_shared(wids, "cost")
    assert fresh.versions != handle.versions
    worker.publish(wids, "cost", fresh)
    restacked, _ = worker.get_stacked(wids, "cost")
    assert restacked is not stack and worker.misses == 2
    worker.publish(wids, "cost", None)
    assert worker.get_stacked(wids, "cost") == (None, [])
    store.close_shared()


def test_export_shared_unusable_key_returns_none():
    store = SupportModelStore(Repository(), SPACE)
    assert store.export_shared(["nobody"], "cost") is None
    store.close_shared()


# -- sharded vs unsharded serving parity ------------------------------------

_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax
    import numpy as np
    assert len(jax.devices()) >= 4, jax.devices()
    from repro.core import (BOConfig, Constraint, Objective, Repository,
                            scout_search_space)
    from repro.serve.search_service import SearchRequest, SearchService
    from repro.simdata import make_emulator

    emu = make_emulator()
    sp = scout_search_space()
    wid = emu.workload_ids()[6]
    cons = [Constraint("runtime", emu.runtime_target(wid, 50))]
    cfg = BOConfig(n_init=2, max_iters=5)

    def support_repo():
        repo = Repository()
        rng = np.random.default_rng(99)
        for u in range(2):
            for ci in rng.choice(len(sp), 8, replace=False):
                repo.add_run(emu.make_record(f"anon-{u}", wid,
                                             sp.configs[ci], rng))
        return repo

    def run_cohort(mesh):
        svc = SearchService(support_repo(), slots=3, mesh=mesh)
        for s in range(3):
            rng = np.random.default_rng(s)
            svc.submit(SearchRequest(
                sp, lambda c, rng=rng: emu.run(wid, c, rng=rng),
                Objective("cost"), cons, method="karasu",
                bo_config=cfg, seed=s))
        return svc, {c.rid: c.result for c in svc.run()}

    base_svc, base = run_cohort(None)
    sh_svc, sh = run_cohort(jax.make_mesh((4,), ("data",)))

    assert sorted(base) == sorted(sh)
    for rid in base:
        a, b = base[rid], sh[rid]
        # per-lane launch results only match up to float roundoff (XLA
        # fuses the per-shard batch size differently), but the DISCRETE
        # trajectory must be identical: same configs profiled in the
        # same order, hence bitwise-identical measured outcomes
        assert [o.config for o in a.observations] == \\
               [o.config for o in b.observations], rid
        for oa, ob in zip(a.observations, b.observations):
            assert oa.measures == ob.measures, rid
        assert list(a.best_index_per_iter) == list(b.best_index_per_iter)
    # same plan both ways: equal fused-launch and step counts
    for k in ("plan_batches", "plan_queries", "steps"):
        assert base_svc.stats[k] == sh_svc.stats[k], (
            k, base_svc.stats[k], sh_svc.stats[k])
    # and the sharded cohort really dispatched shard-mapped twins
    from repro.launch.compile_stats import tracked_launches
    assert any("sharded" in name for name in tracked_launches()), \\
        sorted(tracked_launches())
    print("PARITY-OK")
""")


def test_sharded_trajectory_matches_unsharded():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PARITY_SCRIPT],
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True, env=env,
                       timeout=540)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    assert "PARITY-OK" in r.stdout
