"""Multi-tenant SearchService: batching, shared cache, serving semantics."""
import numpy as np
import pytest

from repro.core import (BOConfig, Constraint, Objective, Repository,
                        run_search, scout_search_space)
from repro.serve.search_service import (SearchRequest, SearchService)
from repro.simdata import make_emulator

EMU = make_emulator()
SPACE = scout_search_space()
WIDS = EMU.workload_ids()
WID = WIDS[6]
RT = EMU.runtime_target(WID, 50)
OPT = EMU.optimal_cost(WID, RT)


def _request(seed, *, method="naive", wid=WID, max_iters=6, **kw):
    rng = np.random.default_rng(seed)
    return SearchRequest(
        SPACE, lambda c: EMU.run(wid, c, rng=rng), Objective("cost"),
        [Constraint("runtime", EMU.runtime_target(wid, 50))],
        method=method, bo_config=BOConfig(max_iters=max_iters), seed=seed,
        **kw)


def _support_repo(wid=WID, users=2, runs=12, seed=99):
    repo = Repository()
    rng = np.random.default_rng(seed)
    for u in range(users):
        for ci in rng.choice(len(SPACE), runs, replace=False):
            repo.add_run(EMU.make_record(f"anon-{u}", wid,
                                         SPACE.configs[ci], rng))
    return repo


def test_service_completes_all_tenants_batched():
    svc = SearchService(Repository(), slots=3)
    rids = [svc.submit(_request(s)) for s in range(3)]
    done = svc.run()
    assert sorted(c.rid for c in done) == rids
    for c in done:
        assert len(c.result.observations) == 6
        assert c.result.best_index_per_iter[-1] >= 0
    # 3 tenants x 2 measures x 3 model iterations collapsed into 3
    # fit batches (one per step), not 18 separate fits
    assert svc.stats["fit_jobs"] == 18
    assert svc.stats["fit_batches"] == 3
    assert svc.collect() == []          # collect drains


def test_service_queueing_beyond_slots():
    svc = SearchService(Repository(), slots=2)
    for s in range(5):
        svc.submit(_request(s, max_iters=4))
    done = svc.run()
    assert len(done) == 5


def test_service_karasu_uses_shared_store():
    repo = _support_repo()
    svc = SearchService(repo, slots=4)
    for s in range(4):
        svc.submit(_request(s, method="karasu"))
    done = svc.run()
    assert len(done) == 4
    for c in done:
        assert c.result.meta["selected"], "karasu never selected supports"
    ctx, = svc._contexts.values()
    # 2 support workloads x 2 measures fit exactly once, shared by all 4
    # tenants across all iterations
    assert ctx.store.misses == 4
    assert ctx.store.hits > ctx.store.misses


def test_service_matches_run_search_quality():
    repo = _support_repo()
    svc = SearchService(repo, slots=2)
    for s in range(2):
        svc.submit(_request(s, method="karasu", max_iters=8))
    gaps_svc = []
    for c in svc.run():
        i = c.result.best_index_per_iter[-1]
        gaps_svc.append(c.result.observations[i].measures["cost"] / OPT - 1)
    gaps_loop = []
    for s in range(2):
        rng = np.random.default_rng(s)
        r = run_search(SPACE, lambda c: EMU.run(WID, c, rng=rng),
                       Objective("cost"), [Constraint("runtime", RT)],
                       method="karasu", repository=_support_repo(),
                       bo_config=BOConfig(max_iters=8), seed=s)
        i = r.best_index_per_iter[-1]
        gaps_loop.append(r.observations[i].measures["cost"] / OPT - 1)
    assert np.mean(gaps_svc) <= np.mean(gaps_loop) + 0.25, (gaps_svc,
                                                            gaps_loop)


def test_service_publish_invalidates_incrementally():
    repo = _support_repo(users=1)
    svc = SearchService(repo, slots=2)
    svc.submit(_request(0, method="karasu", share_as="tenant-0"))
    svc.submit(_request(1, method="karasu"))
    n0 = len(repo)
    done = svc.run()
    assert len(done) == 2
    # tenant 0 published every profiling run to the shared repository
    assert len(repo.runs("tenant-0")) == 6
    assert len(repo) == n0 + 6
    # and the repository version moved, so later searches see fresh data
    assert repo.version("tenant-0") == 6
    # a publishing tenant must never select its OWN runs as support
    # (they score ~1.0 against themselves and bypass the LOO safeguard);
    # the non-publishing tenant is free to consume them
    r0 = next(c.result for c in done if c.rid == 0)
    assert all("tenant-0" not in sel for sel in r0.meta["selected"])


def test_service_early_stop():
    svc = SearchService(Repository(), slots=1)
    rng = np.random.default_rng(0)
    req = SearchRequest(
        SPACE, lambda c: EMU.run(WID, c, rng=rng), Objective("cost"),
        [Constraint("runtime", RT)], method="naive",
        bo_config=BOConfig(max_iters=20, early_stop=True), seed=0)
    svc.submit(req)
    done = svc.run()
    assert len(done) == 1
    res = done[0].result
    assert res.meta["n_profiled"] >= 6
    assert res.meta["n_profiled"] <= 20


def test_service_rejects_unknown_method():
    svc = SearchService()
    with pytest.raises(ValueError):
        svc.submit(_request(0, method="bogus"))
