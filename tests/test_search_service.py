"""Multi-tenant SearchService: batching, shared cache, serving semantics,
async profiling (ProfileExecutor backends + WAITING_PROFILE overlap),
fused posterior/acquisition query plan, multi-objective sessions."""
import threading

import numpy as np
import pytest

from repro.core import (BOConfig, Constraint, Objective, Repository,
                        run_search, run_search_moo, scout_search_space)
from repro.serve.profile_executor import (FakeProfileExecutor,
                                          ProcessPoolProfileExecutor,
                                          ProfileJob, SyncProfileExecutor,
                                          ThreadPoolProfileExecutor)
from repro.serve.search_service import (SearchRequest, SearchService)
from repro.simdata import make_emulator

EMU = make_emulator()
SPACE = scout_search_space()
WIDS = EMU.workload_ids()
WID = WIDS[6]
RT = EMU.runtime_target(WID, 50)
OPT = EMU.optimal_cost(WID, RT)


def _request(seed, *, method="naive", wid=WID, max_iters=6, **kw):
    rng = np.random.default_rng(seed)
    return SearchRequest(
        SPACE, lambda c: EMU.run(wid, c, rng=rng), Objective("cost"),
        [Constraint("runtime", EMU.runtime_target(wid, 50))],
        method=method, bo_config=BOConfig(max_iters=max_iters), seed=seed,
        **kw)


def _support_repo(wid=WID, users=2, runs=12, seed=99):
    repo = Repository()
    rng = np.random.default_rng(seed)
    for u in range(users):
        for ci in rng.choice(len(SPACE), runs, replace=False):
            repo.add_run(EMU.make_record(f"anon-{u}", wid,
                                         SPACE.configs[ci], rng))
    return repo


def test_service_completes_all_tenants_batched():
    svc = SearchService(Repository(), slots=3)
    rids = [svc.submit(_request(s)) for s in range(3)]
    done = svc.run()
    assert sorted(c.rid for c in done) == rids
    for c in done:
        assert len(c.result.observations) == 6
        assert c.result.best_index_per_iter[-1] >= 0
    # 3 tenants x 2 measures x 3 model iterations collapsed into 3
    # fit batches (one per step), not 18 separate fits
    assert svc.stats["fit_jobs"] == 18
    assert svc.stats["fit_batches"] == 3
    assert svc.collect() == []          # collect drains


def test_service_queueing_beyond_slots():
    svc = SearchService(Repository(), slots=2)
    for s in range(5):
        svc.submit(_request(s, max_iters=4))
    done = svc.run()
    assert len(done) == 5


def test_service_karasu_uses_shared_store():
    repo = _support_repo()
    svc = SearchService(repo, slots=4)
    for s in range(4):
        svc.submit(_request(s, method="karasu"))
    done = svc.run()
    assert len(done) == 4
    for c in done:
        assert c.result.meta["selected"], "karasu never selected supports"
    ctx, = svc._contexts.values()
    # 2 support workloads x 2 measures fit exactly once, shared by all 4
    # tenants across all iterations
    assert ctx.store.misses == 4
    assert ctx.store.hits > ctx.store.misses


def test_service_matches_run_search_quality():
    repo = _support_repo()
    svc = SearchService(repo, slots=2)
    for s in range(2):
        svc.submit(_request(s, method="karasu", max_iters=8))
    gaps_svc = []
    for c in svc.run():
        i = c.result.best_index_per_iter[-1]
        gaps_svc.append(c.result.observations[i].measures["cost"] / OPT - 1)
    gaps_loop = []
    for s in range(2):
        rng = np.random.default_rng(s)
        r = run_search(SPACE, lambda c: EMU.run(WID, c, rng=rng),
                       Objective("cost"), [Constraint("runtime", RT)],
                       method="karasu", repository=_support_repo(),
                       bo_config=BOConfig(max_iters=8), seed=s)
        i = r.best_index_per_iter[-1]
        gaps_loop.append(r.observations[i].measures["cost"] / OPT - 1)
    assert np.mean(gaps_svc) <= np.mean(gaps_loop) + 0.25, (gaps_svc,
                                                            gaps_loop)


def test_service_publish_invalidates_incrementally():
    repo = _support_repo(users=1)
    svc = SearchService(repo, slots=2)
    svc.submit(_request(0, method="karasu", share_as="tenant-0"))
    svc.submit(_request(1, method="karasu"))
    n0 = len(repo)
    done = svc.run()
    assert len(done) == 2
    # tenant 0 published every profiling run to the shared repository
    assert len(repo.runs("tenant-0")) == 6
    assert len(repo) == n0 + 6
    # and the repository version moved, so later searches see fresh data
    assert repo.version("tenant-0") == 6
    # a publishing tenant must never select its OWN runs as support
    # (they score ~1.0 against themselves and bypass the LOO safeguard);
    # the non-publishing tenant is free to consume them
    r0 = next(c.result for c in done if c.rid == 0)
    assert all("tenant-0" not in sel for sel in r0.meta["selected"])


def test_service_early_stop():
    svc = SearchService(Repository(), slots=1)
    rng = np.random.default_rng(0)
    req = SearchRequest(
        SPACE, lambda c: EMU.run(WID, c, rng=rng), Objective("cost"),
        [Constraint("runtime", RT)], method="naive",
        bo_config=BOConfig(max_iters=20, early_stop=True), seed=0)
    svc.submit(req)
    done = svc.run()
    assert len(done) == 1
    res = done[0].result
    assert res.meta["n_profiled"] >= 6
    assert res.meta["n_profiled"] <= 20


def test_service_rejects_unknown_method():
    svc = SearchService()
    with pytest.raises(ValueError):
        svc.submit(_request(0, method="bogus"))


def test_service_rejects_unknown_wait_mode():
    with pytest.raises(ValueError):
        SearchService(wait_mode="bogus")


def test_collect_empty_service_returns_immediately():
    """Regression: collect() on a service with zero submitted searches
    must return [] instead of blocking or raising — with and without
    wait semantics, for every executor backend."""
    for executor in (None, SyncProfileExecutor(),
                     ThreadPoolProfileExecutor(max_workers=1),
                     FakeProfileExecutor()):
        svc = SearchService(executor=executor)
        assert svc.collect() == []
        assert svc.collect(wait=True) == []          # must not block
        assert svc.collect(wait=True, timeout=0.01) == []
        svc.close()


def _noise_free_request(seed, *, method="naive", max_iters=6,
                        barrier=None):
    """profile_fn without shared RNG state: safe to call from executor
    threads in any order, so sync and async services see identical data."""
    def fn(c):
        out = EMU.run(WID, c, rng=None)
        if barrier is not None:
            barrier.wait(timeout=30)
        return out
    return SearchRequest(SPACE, fn, Objective("cost"),
                         [Constraint("runtime", RT)], method=method,
                         bo_config=BOConfig(max_iters=max_iters), seed=seed)


def _result_fingerprint(res):
    return (tuple(tuple(sorted(o.config.items())) for o in res.observations),
            tuple(tuple(sorted(o.measures.items()))
                  for o in res.observations),
            tuple(res.best_index_per_iter), res.stopped_at)


def test_async_threadpool_bitwise_matches_sync():
    """Thread-pool execution with a barrier forcing each round's arrival
    order must produce bitwise-identical BOResults to the synchronous
    path: same configs, same measures, same incumbents."""
    n = 3
    sync_svc = SearchService(Repository(), slots=n)
    for s in range(n):
        sync_svc.submit(_noise_free_request(s))
    sync_done = {c.rid: c.result for c in sync_svc.run()}

    # all n tenants advance in lockstep (same max_iters, no early stop),
    # so every wave is exactly n profiling runs: a Barrier(n) holds each
    # wave's results back until all have executed, forcing arrival order
    barrier = threading.Barrier(n)
    async_svc = SearchService(
        Repository(), slots=n,
        executor=ThreadPoolProfileExecutor(max_workers=n),
        wait_mode="all")
    for s in range(n):
        async_svc.submit(_noise_free_request(s, barrier=barrier))
    async_done = {c.rid: c.result for c in async_svc.run()}
    async_svc.close()

    assert sorted(sync_done) == sorted(async_done)
    for rid in sync_done:
        assert (_result_fingerprint(sync_done[rid])
                == _result_fingerprint(async_done[rid])), rid


def test_async_fake_executor_overlaps_heterogeneous_latencies():
    """With per-tenant latencies of 1..4 virtual ticks and wait_mode
    'any', fast sessions keep stepping while slow profilers are in
    flight (WAITING_PROFILE), and every session still completes its
    full budget with the same per-session data as the sync path."""
    n = 4
    latency = {rid: rid + 1 for rid in range(n)}
    exe = FakeProfileExecutor(lambda job: latency[job.rid])
    svc = SearchService(Repository(), slots=n, executor=exe,
                        wait_mode="any")
    for s in range(n):
        svc.submit(_noise_free_request(s, max_iters=5))
    done = {c.rid: c.result for c in svc.run()}
    assert sorted(done) == list(range(n))
    for res in done.values():
        assert len(res.observations) == 5
    # the service had to block on stragglers at least once...
    assert svc.stats["profile_waits"] > 0
    # ...and virtual time advanced instead of wall-clock sleeping
    assert exe.ticks > 0

    # per-session trajectories match a synchronous service: overlap must
    # not change WHAT a session profiles, only WHEN results land
    sync_svc = SearchService(Repository(), slots=n)
    for s in range(n):
        sync_svc.submit(_noise_free_request(s, max_iters=5))
    sync_done = {c.rid: c.result for c in sync_svc.run()}
    for rid in done:
        assert (_result_fingerprint(done[rid])
                == _result_fingerprint(sync_done[rid])), rid


def test_profile_executor_error_propagates():
    def boom(c):
        raise RuntimeError("cluster fell over")
    svc = SearchService(Repository(), slots=1)
    svc.submit(SearchRequest(SPACE, boom, Objective("cost"), [],
                             bo_config=BOConfig(max_iters=4), seed=0))
    with pytest.raises(RuntimeError, match="cluster fell over"):
        svc.run()
    # the erroring session is settled, not wedged in WAITING_PROFILE:
    # every failed run decremented inflight before raising
    assert all(s.inflight == 0 for s in svc.active.values())


def test_session_error_does_not_strand_held_outcomes():
    """An errored outcome must not stop the drain of later outcomes the
    executor already handed over, nor leave the session WAITING."""
    from repro.serve.profile_executor import ProfileOutcome
    from repro.serve.search_service import READY, _Session
    s = _Session(0, _noise_free_request(0))
    j0, j1 = s.launch(10), s.launch(11)
    meas, metr = EMU.run(WID, SPACE.configs[11], rng=None)
    # seq 1 lands first and is held back behind outstanding seq 0
    s.record(ProfileOutcome(j1, meas, metr), None)
    assert s.observations == [] and s.inflight == 2
    # then seq 0 lands with an error: raise, but drain seq 1 and settle
    with pytest.raises(RuntimeError, match="boom"):
        s.record(ProfileOutcome(j0, error=RuntimeError("boom")), None)
    assert len(s.observations) == 1
    assert s.inflight == 0 and s.state == READY


def test_fake_executor_fractional_timeout_progresses():
    """A sub-tick timeout must still advance the virtual clock (ceil),
    not busy-spin with a zero tick budget."""
    exe = FakeProfileExecutor(lambda job: 1)
    exe.submit(ProfileJob(0, 0, {}),
               lambda c: ({"cost": 1.0}, np.zeros((6, 5))))
    assert len(exe.collect(timeout=0.5)) == 1
    assert exe.ticks == 1


def test_collect_wait_timeout_honored_with_slow_profiler():
    """collect(wait=True, timeout=...)'s deadline must cap the executor
    waits inside step(), not just be checked between steps."""
    import time as _t

    def slow(c):
        _t.sleep(1.5)
        return EMU.run(WID, c, rng=None)

    svc = SearchService(Repository(), slots=1,
                        executor=ThreadPoolProfileExecutor(max_workers=1))
    svc.submit(SearchRequest(SPACE, slow, Objective("cost"), [],
                             bo_config=BOConfig(n_init=1, max_iters=3),
                             seed=0))
    t0 = _t.monotonic()
    assert svc.collect(wait=True, timeout=0.3) == []
    assert _t.monotonic() - t0 < 1.2    # returned before the 1.5 s run
    svc.close()

    # wait_mode="all" makes TWO executor waits per step (drain, then
    # collect); they must share one deadline, not double it
    svc2 = SearchService(Repository(), slots=1, wait_mode="all",
                         executor=ThreadPoolProfileExecutor(max_workers=1))
    svc2.submit(SearchRequest(SPACE, slow, Objective("cost"), [],
                              bo_config=BOConfig(n_init=1, max_iters=3),
                              seed=0))
    t0 = _t.monotonic()
    assert svc2.collect(wait=True, timeout=0.3) == []
    assert _t.monotonic() - t0 < 1.0
    svc2.close()


def test_service_cross_tenant_rgpe_batched_in_one_call():
    """All (tenant, measure) karasu ensembles of a step go through ONE
    padded ranking-loss launch: rgpe_batches counts steps (per kernel
    impl), not tenants x measures."""
    repo = _support_repo()
    svc = SearchService(repo, slots=4)
    for s in range(4):
        svc.submit(_request(s, method="karasu"))
    svc.run()
    assert svc.stats["rgpe_jobs"] > svc.stats["rgpe_batches"]
    # 3 scoring steps (obs 3 -> 6), one batch each
    assert svc.stats["rgpe_batches"] == 3


# -- fused posterior query plan + multi-objective serving --------------------


def _moo_request(seed, *, method="naive", wid=WID, max_iters=5, n_mc=16,
                 **kw):
    return SearchRequest(
        SPACE, lambda c: EMU.run(wid, c, rng=None), None,
        [Constraint("runtime", EMU.runtime_target(wid, 50))],
        method=method, bo_config=BOConfig(max_iters=max_iters), seed=seed,
        objectives=[Objective("cost"), Objective("energy")], n_mc=n_mc,
        **kw)


def test_service_rejects_malformed_moo_requests():
    svc = SearchService()
    # objective AND objectives
    with pytest.raises(ValueError, match="either objective or objectives"):
        svc.submit(SearchRequest(
            SPACE, lambda c: EMU.run(WID, c), Objective("cost"),
            objectives=[Objective("cost"), Objective("energy")]))
    # wrong arity
    with pytest.raises(ValueError, match="two or more"):
        svc.submit(SearchRequest(SPACE, lambda c: EMU.run(WID, c), None,
                                 objectives=[Objective("cost")]))
    # neither
    with pytest.raises(ValueError, match="needs an objective"):
        svc.submit(SearchRequest(SPACE, lambda c: EMU.run(WID, c), None))
    # augmented has no MOO path
    with pytest.raises(ValueError, match="naive|karasu"):
        svc.submit(_moo_request(0, method="augmented"))


def test_service_step_fuses_all_grid_posteriors():
    """A single-space cohort's step executes EVERY grid posterior —
    targets, all RGPE support stacks, SO and MOO tenants — in ONE padded
    batched_posterior launch: posterior_batches counts scoring steps,
    posterior_queries the fused stacks."""
    repo = _support_repo()
    svc = SearchService(repo, slots=4)
    for s in range(2):
        svc.submit(_request(s, method="karasu", max_iters=6))
    for s in range(2):
        svc.submit(_moo_request(10 + s, method="karasu", max_iters=6))
    done = svc.run()
    assert len(done) == 4
    # lockstep cohort: scoring steps = max_iters - n_init = 3, and every
    # step fused its targets + all ensembles into one launch
    assert svc.stats["posterior_batches"] == 3
    # each scoring step queried 1 target stack + one support stack per
    # (karasu tenant, measure): strictly more queries than launches
    assert svc.stats["posterior_queries"] > svc.stats["posterior_batches"]


def test_service_fused_posteriors_match_per_session_loop():
    """Acceptance: fused-plan posteriors/acquisitions agree with the
    per-session-loop path (fuse_posteriors=False) to 1e-4."""
    def build(fuse):
        svc = SearchService(_support_repo(), slots=4,
                            fuse_posteriors=fuse)
        for s in range(2):
            svc.submit(_request(s, method="karasu"))
        svc.submit(_moo_request(7, method="karasu"))
        svc.step()          # admit + init + first scoring round
        return svc

    fused, loop = build(True), build(False)
    s_f = [fused.active[r] for r in sorted(fused.active)]
    s_l = [loop.active[r] for r in sorted(loop.active)]
    # both services took identical trajectories so far
    for a, b in zip(s_f, s_l):
        assert [o.config for o in a.observations] == \
            [o.config for o in b.observations]
    posts_f = fused._posterior_phase(s_f)
    posts_l = loop._posterior_phase(s_l)
    assert fused.stats["posterior_batches"] >= 1
    assert loop.stats["posterior_batches"] == 0
    for a in s_f:
        for m in a.measures:
            np.testing.assert_allclose(
                np.asarray(posts_f[a.rid][m]["mu"]),
                np.asarray(posts_l[a.rid][m]["mu"]), atol=1e-4)
            np.testing.assert_allclose(
                np.asarray(posts_f[a.rid][m]["var"]),
                np.asarray(posts_l[a.rid][m]["var"]), atol=1e-4)
    # MOO acquisition: batched EHVI vs the per-candidate reference loop
    # on the same posteriors
    moo_f = next(s for s in s_f if s.is_moo)
    rem = moo_f.remaining()
    acq_f = fused._moo_acquisition(moo_f, posts_f[moo_f.rid], rem)
    acq_l = loop._moo_acquisition(moo_f, posts_f[moo_f.rid], rem)
    np.testing.assert_allclose(acq_f, acq_l, atol=1e-4)


def test_service_mixed_so_moo_cohort_deterministic():
    """Acceptance: a mixed single-objective + MOO multi-tenant cohort on
    the fake executor is bit-for-bit deterministic across runs."""
    def run_once():
        latency = {0: 2, 1: 1, 2: 3, 3: 1}
        svc = SearchService(
            _support_repo(), slots=4,
            executor=FakeProfileExecutor(lambda j: latency[j.rid]),
            wait_mode="any")
        svc.submit(_request(0, method="karasu", max_iters=5))
        svc.submit(_request(1, method="naive", max_iters=5))
        svc.submit(_moo_request(2, method="karasu"))
        svc.submit(_moo_request(3, method="naive"))
        return {c.rid: c.result for c in svc.run()}

    a, b = run_once(), run_once()
    assert sorted(a) == sorted(b) == [0, 1, 2, 3]
    for rid in a:
        assert (_result_fingerprint(a[rid])
                == _result_fingerprint(b[rid])), rid
    # MOO results carry their Pareto front
    for rid in (2, 3):
        assert a[rid].meta["moo"] is True
        front = a[rid].meta["pareto_front"]
        assert front.ndim == 2 and front.shape[1] == 2 and len(front) >= 1
        np.testing.assert_array_equal(front, b[rid].meta["pareto_front"])


def test_service_step_fuses_sample_draws():
    """All RGPE support-sample draws and MOO EHVI draws of a step ride
    the sample query plan: sample_batches counts fused launches, far
    fewer than the (tenant, measure/objective) draws they carry."""
    repo = _support_repo()
    svc = SearchService(repo, slots=4)
    for s in range(2):
        svc.submit(_request(s, method="karasu", max_iters=6))
    for s in range(2):
        svc.submit(_moo_request(10 + s, method="karasu", max_iters=6))
    done = svc.run()
    assert len(done) == 4
    assert svc.stats["sample_batches"] >= 1
    assert svc.stats["sample_queries"] > svc.stats["sample_batches"]
    # both MOO sessions' EHVI staircases shared vmapped launches
    assert svc.stats["ehvi_jobs"] > svc.stats["ehvi_batches"] >= 1

    # the loop baseline never enters the plan
    svc_l = SearchService(_support_repo(), slots=2, fuse_samples=False)
    for s in range(2):
        svc_l.submit(_request(s, method="karasu", max_iters=5))
    svc_l.run()
    assert svc_l.stats["sample_batches"] == 0
    assert svc_l.stats["ehvi_batches"] == 0


def test_service_fused_samples_match_loop():
    """Acceptance: fuse_samples=True (fused RGPE draws + vmapped EHVI)
    agrees with the per-job/per-session loop baseline to 1e-4 — same
    PRNG streams, so RGPE weights are identical and EHVI differs only
    by f32-vs-f64 roundoff."""
    def build(fuse):
        svc = SearchService(_support_repo(), slots=4, fuse_samples=fuse)
        for s in range(2):
            svc.submit(_request(s, method="karasu"))
        svc.submit(_moo_request(7, method="karasu"))
        svc.step()
        return svc

    fused, loop = build(True), build(False)
    s_f = [fused.active[r] for r in sorted(fused.active)]
    s_l = [loop.active[r] for r in sorted(loop.active)]
    for a, b in zip(s_f, s_l):
        assert [o.config for o in a.observations] == \
            [o.config for o in b.observations]
    posts_f = fused._posterior_phase(s_f)
    posts_l = loop._posterior_phase(s_l)
    assert fused.stats["sample_batches"] >= 1
    assert loop.stats["sample_batches"] == 0
    for a in s_f:
        for m in a.measures:
            if "weights" in posts_f[a.rid][m]:
                np.testing.assert_allclose(
                    posts_f[a.rid][m]["weights"],
                    posts_l[a.rid][m]["weights"], atol=1e-4)
            np.testing.assert_allclose(
                np.asarray(posts_f[a.rid][m]["mu"]),
                np.asarray(posts_l[a.rid][m]["mu"]), atol=1e-4)
    moo_f = next(s for s in s_f if s.is_moo)
    moo_l = next(s for s in s_l if s.is_moo)
    rem = moo_f.remaining()
    acq_f = fused._moo_phase([(moo_f, rem)], posts_f)[moo_f.rid]
    acq_l = loop._moo_phase([(moo_l, rem)], posts_l)[moo_l.rid]
    scale = max(1.0, float(np.abs(acq_l).max()))
    np.testing.assert_allclose(acq_f, acq_l, atol=1e-4 * scale)


def test_service_three_objective_session_end_to_end():
    """Acceptance: a 3-objective session runs end to end through the
    service — (k, 3) Pareto front, EHVI fused-vs-oracle parity <= 1e-4
    (the loop baseline for n >= 3 IS the recursive-sweep f64 oracle
    mc_ehvi_nd), and bit-for-bit determinism across runs."""
    def _req3(seed, **kw):
        return SearchRequest(
            SPACE, lambda c: EMU.run(WID, c, rng=None), None,
            [Constraint("runtime", RT)], method="karasu",
            bo_config=BOConfig(max_iters=5), seed=seed,
            objectives=[Objective("cost"), Objective("energy"),
                        Objective("runtime")], n_mc=8, **kw)

    def build(fuse):
        svc = SearchService(_support_repo(), slots=2, fuse_samples=fuse)
        svc.submit(_req3(0))
        svc.submit(_request(1, method="karasu"))
        svc.step()
        return svc

    fused, loop = build(True), build(False)
    s_f = [fused.active[r] for r in sorted(fused.active)]
    s_l = [loop.active[r] for r in sorted(loop.active)]
    for a, b in zip(s_f, s_l):
        assert [o.config for o in a.observations] == \
            [o.config for o in b.observations]
    posts_f = fused._posterior_phase(s_f)
    posts_l = loop._posterior_phase(s_l)
    moo_f = next(s for s in s_f if s.is_moo)
    moo_l = next(s for s in s_l if s.is_moo)
    rem = moo_f.remaining()
    acq_f = fused._moo_phase([(moo_f, rem)], posts_f)[moo_f.rid]
    acq_l = loop._moo_phase([(moo_l, rem)], posts_l)[moo_l.rid]
    scale = max(1.0, float(np.abs(acq_l).max()))
    np.testing.assert_allclose(acq_f, acq_l, atol=1e-4 * scale)
    assert fused.stats["ehvi_batches"] >= 1

    # end to end: completes, carries a 3-column front, deterministic
    def run_once():
        svc = SearchService(_support_repo(), slots=2)
        svc.submit(_req3(0))
        svc.submit(_request(1, method="karasu", max_iters=5))
        return {c.rid: c.result for c in svc.run()}

    a, b = run_once(), run_once()
    assert sorted(a) == [0, 1]
    front = a[0].meta["pareto_front"]
    assert front.ndim == 2 and front.shape[1] == 3 and len(front) >= 1
    for rid in a:
        assert (_result_fingerprint(a[rid])
                == _result_fingerprint(b[rid])), rid
    np.testing.assert_array_equal(front, b[0].meta["pareto_front"])


# -- process-pool profiling -------------------------------------------------

# forkserver: workers descend from a clean exec'd server process, not a
# fork of this (JAX-threaded) one — no inherited locks to deadlock on.
# Workers are long-lived, so the one-time import cost amortises.
import multiprocessing

MP_CTX = multiprocessing.get_context("forkserver")


def _pp_profile(config):
    """Module-level (picklable) noise-free profile fn for the process
    pool: workers resolve it by qualified name."""
    return EMU.run(WID, config, rng=None)


def _pp_boom(config):
    raise RuntimeError("cluster fell over")


def test_process_pool_executor_matches_sync_service():
    """Profiling on a process pool must complete every tenant with the
    exact per-session trajectories of the synchronous service — jobs,
    outcomes, and the profile_fn all cross the pickle boundary."""
    n = 2
    exe = ProcessPoolProfileExecutor(max_workers=n, mp_context=MP_CTX)
    svc = SearchService(Repository(), slots=n, executor=exe)
    for s in range(n):
        svc.submit(SearchRequest(SPACE, _pp_profile, Objective("cost"),
                                 [Constraint("runtime", RT)],
                                 bo_config=BOConfig(max_iters=4), seed=s))
    done = {c.rid: c.result for c in svc.run()}
    svc.close()
    assert sorted(done) == list(range(n))

    sync_svc = SearchService(Repository(), slots=n)
    for s in range(n):
        sync_svc.submit(SearchRequest(SPACE, _pp_profile,
                                      Objective("cost"),
                                      [Constraint("runtime", RT)],
                                      bo_config=BOConfig(max_iters=4),
                                      seed=s))
    sync_done = {c.rid: c.result for c in sync_svc.run()}
    for rid in done:
        assert (_result_fingerprint(done[rid])
                == _result_fingerprint(sync_done[rid])), rid


def test_process_pool_executor_error_propagates():
    """A profiler exception in the worker process is pickled back onto
    the outcome and re-raised by the service, which settles (not
    wedges) the session — same contract as every other backend."""
    exe = ProcessPoolProfileExecutor(max_workers=1, mp_context=MP_CTX)
    svc = SearchService(Repository(), slots=1, executor=exe)
    svc.submit(SearchRequest(SPACE, _pp_boom, Objective("cost"), [],
                             bo_config=BOConfig(max_iters=4), seed=0))
    with pytest.raises(RuntimeError, match="cluster fell over"):
        svc.run()
    # the remaining init runs are still in flight (async backend): each
    # raises as it lands, and the session settles once all are absorbed
    for _ in range(10):
        if not (svc.executor.pending()
                or any(s.inflight for s in svc.active.values())):
            break
        with pytest.raises(RuntimeError, match="cluster fell over"):
            svc.step()
    assert all(s.inflight == 0 for s in svc.active.values())
    svc.close()


def test_process_pool_executor_drain_and_order():
    """poll/collect/drain semantics on the process pool: outcomes come
    back in submission order among the completed set."""
    exe = ProcessPoolProfileExecutor(max_workers=2, mp_context=MP_CTX)
    try:
        for ci in range(3):
            exe.submit(ProfileJob(0, ci, SPACE.configs[ci], "init", ci),
                       _pp_profile)
        outs = exe.drain(timeout=60)
        assert exe.pending() == 0
        assert [o.job.seq for o in outs] == [0, 1, 2]
        assert all(o.error is None and o.measures for o in outs)
    finally:
        exe.shutdown()


def test_prng_key_schedule_collision_free():
    """Regression for the arithmetic key tags (1000 + it*10 + oi): every
    (purpose, iteration, index) must derive a distinct key, and the two
    purposes' subtrees must never overlap for any (it, index) pair."""
    from repro.core.bo import (KEY_PURPOSE_MOO_EHVI, KEY_PURPOSE_RGPE,
                               derive_key)
    import jax
    base = jax.random.PRNGKey(42)
    seen = set()
    for purpose in (KEY_PURPOSE_RGPE, KEY_PURPOSE_MOO_EHVI):
        for it in range(25):
            for idx in range(10):
                k = tuple(np.asarray(
                    jax.random.key_data(derive_key(base, purpose, it, idx))
                ).ravel().tolist())
                assert k not in seen, (purpose, it, idx)
                seen.add(k)
    assert len(seen) == 2 * 25 * 10


def test_prng_consumers_bitwise_deterministic():
    """Bit-for-bit determinism across BOTH derived-key consumers (RGPE
    support draws and MOO EHVI draws) on the fake executor: a karasu
    MOO tenant exercises RGPE and EHVI keys every scoring step, and two
    runs must produce identical trajectories and Pareto fronts."""
    def run_once():
        svc = SearchService(
            _support_repo(), slots=2,
            executor=FakeProfileExecutor(lambda j: 1 + j.rid),
            wait_mode="any")
        svc.submit(_moo_request(3, method="karasu", max_iters=6))
        svc.submit(_request(4, method="karasu", max_iters=6))
        done = {c.rid: c.result for c in svc.run()}
        assert svc.stats["rgpe_jobs"] > 0 and svc.stats["ehvi_jobs"] > 0
        return done

    a, b = run_once(), run_once()
    for rid in a:
        assert (_result_fingerprint(a[rid])
                == _result_fingerprint(b[rid])), rid
    np.testing.assert_array_equal(a[0].meta["pareto_front"],
                                  b[0].meta["pareto_front"])


def test_run_search_moo_routes_through_service():
    """run_search_moo is a thin driver over SearchService: one slot,
    sync executor, identical trajectory to an explicit submission."""
    rng = np.random.default_rng(0)
    r = run_search_moo(SPACE, lambda c: EMU.run(WID, c, rng=rng),
                       [Objective("cost"), Objective("energy")],
                       [Constraint("runtime", RT)], method="naive",
                       bo_config=BOConfig(max_iters=6), seed=3, n_mc=16)
    assert len(r.observations) == 6
    assert r.meta["moo"] is True and r.meta["n_profiled"] == 6

    rng = np.random.default_rng(0)
    svc = SearchService(slots=1)
    svc.submit(SearchRequest(
        SPACE, lambda c: EMU.run(WID, c, rng=rng), None,
        [Constraint("runtime", RT)], method="naive",
        bo_config=BOConfig(max_iters=6), seed=3,
        objectives=[Objective("cost"), Objective("energy")], n_mc=16))
    (c,) = svc.run()
    assert _result_fingerprint(c.result) == _result_fingerprint(r)


# -- compile-once steady state -----------------------------------------------


def test_precompile_zero_recompile_under_mixed_tenant_churn():
    """200 scheduling steps of a churning SO + 2-objective +
    3-objective cohort after an AOT bucket precompile: every planned
    launch signature lands in the precompiled vocabulary and no
    tracked launch recompiles (``plan_compile_misses`` stays 0)."""
    import dataclasses

    from repro.core.plan import CohortLimits, StepPlanner

    class RecordingPlanner(StepPlanner):
        def __init__(self):
            super().__init__()
            self.signatures = set()

        def plan(self, queries):
            p = super().plan(queries)
            for b in p.buckets:
                if b.kind != "draw":        # unjitted, no vocabulary
                    self.signatures.add(self.launch_signature(b))
            return p

    space = dataclasses.replace(SPACE, name="scout-mini",
                                configs=SPACE.configs[:8])
    repo = Repository()
    rng = np.random.default_rng(5)
    for u in range(2):
        for ci in rng.choice(len(space), 6, replace=False):
            repo.add_run(EMU.make_record(f"anon-{u}", WID,
                                         space.configs[ci], rng))
    planner = RecordingPlanner()
    svc = SearchService(repo, slots=3, planner=planner)
    # lane bound: 8 target lanes (sum of the cohort's measures) plus
    # 8 RGPE jobs x up to 3 support bases fused into the same buckets
    limits = CohortLimits(d=space.all_encoded().shape[1], q_grid=8,
                          max_obs=8, max_lanes=32, n_samples=(32,),
                          n_mc=(8,), n_objectives=(2, 3),
                          max_ehvi_boxes=256)
    pre = svc.precompile(limits)
    assert pre["buckets"] == len(svc.precompiled_signatures)
    assert svc.stats["precompiled_buckets"] == pre["buckets"]
    assert svc.stats["precompile_compiles"] == pre["compiles"]

    cfg = BOConfig(n_init=2, max_iters=5, rgpe_samples=32)
    cons = [Constraint("runtime", EMU.runtime_target(WID, 50))]

    def submit(i):
        runner = lambda c: EMU.run(WID, c, rng=None)
        if i % 3 == 0:
            svc.submit(SearchRequest(
                space, runner, Objective("cost"), cons, method="karasu",
                bo_config=cfg, seed=100 + i,
                share_as="tenant-0" if i == 0 else None))
        elif i % 3 == 1:
            svc.submit(SearchRequest(
                space, runner, None, cons, method="karasu",
                bo_config=cfg, seed=100 + i,
                objectives=[Objective("cost"), Objective("energy")],
                n_mc=8))
        else:
            svc.submit(SearchRequest(
                space, runner, None, (), method="karasu",
                bo_config=cfg, seed=100 + i,
                objectives=[Objective("cost"), Objective("energy"),
                            Objective("runtime")], n_mc=8))

    submitted = 0
    for _ in range(200):
        while len(svc.active) + len(svc.queue) < 3:
            submit(submitted)
            submitted += 1
        svc.step()
    assert svc.stats["steps"] == 200
    # churn actually happened: tenants retired and were replaced
    assert len(svc.done) >= 10
    # every planned launch came from the precompiled vocabulary...
    assert {"posterior", "sample", "loo", "ehvi", "fit"} <= \
        {sig[0] for sig in planner.signatures}
    assert planner.signatures <= svc.precompiled_signatures
    # ...and no tracked launch compiled while serving
    assert svc.stats["plan_compile_misses"] == 0


def test_fused_ehvi_service_matches_default_executor():
    """A fused-EHVI executor must serve bitwise-identical MOO
    trajectories to the default vmapped executor: the EHVI queries carry
    posterior rows + PRNG keys instead of materialised draws, and the
    kernel applies the exact same derive_key/affine recipe — so the
    only visible difference is the eliminated draw round."""
    from repro.core.plan import PlanExecutor

    def run(executor):
        svc = SearchService(Repository(), slots=3, plan_executor=executor)
        for s in range(3):
            svc.submit(_moo_request(20 + s, method="naive"))
        done = {c.rid: c.result for c in svc.run()}
        return svc, done

    base_svc, base = run(PlanExecutor(donate=False))
    fused_svc, fused = run(PlanExecutor(fused_ehvi=True, impl="xla",
                                        donate=False))
    assert base.keys() == fused.keys()
    for rid in base:
        assert _result_fingerprint(base[rid]) == \
            _result_fingerprint(fused[rid])
    # the fused path consumes posterior rows directly: no separate
    # draw launches, fewer plan rounds, same ehvi bucket accounting
    assert base_svc.stats["sample_batches"] > 0
    assert fused_svc.stats["sample_batches"] == 0
    assert fused_svc.stats["plan_batches"] < base_svc.stats["plan_batches"]
    assert fused_svc.stats["ehvi_batches"] == base_svc.stats["ehvi_batches"]


def test_precompile_zero_recompile_fused_donated_executor():
    """The churn guarantee must survive the fused + donated executor:
    precompile walks the same donate/fused launch choices the serving
    path makes (the donated twins are pinned at executor construction,
    not resolved per call), so a mixed SO + MOO cohort still hits only
    precompiled signatures with zero tracked recompiles."""
    import dataclasses

    from repro.core.plan import CohortLimits, PlanExecutor, StepPlanner

    class RecordingPlanner(StepPlanner):
        def __init__(self):
            super().__init__()
            self.signatures = set()

        def plan(self, queries):
            p = super().plan(queries)
            for b in p.buckets:
                if b.kind != "draw":
                    self.signatures.add(self.launch_signature(b))
            return p

    space = dataclasses.replace(SPACE, name="scout-mini",
                                configs=SPACE.configs[:8])
    repo = Repository()
    rng = np.random.default_rng(5)
    for u in range(2):
        for ci in rng.choice(len(space), 6, replace=False):
            repo.add_run(EMU.make_record(f"anon-{u}", WID,
                                         space.configs[ci], rng))
    planner = RecordingPlanner()
    executor = PlanExecutor(fused_posterior=True, fused_ehvi=True,
                            donate=True, impl="xla")
    svc = SearchService(repo, slots=3, planner=planner,
                        plan_executor=executor)
    limits = CohortLimits(d=space.all_encoded().shape[1], q_grid=8,
                          max_obs=8, max_lanes=32, n_samples=(32,),
                          n_mc=(8,), n_objectives=(2, 3),
                          max_ehvi_boxes=256)
    svc.precompile(limits)

    cfg = BOConfig(n_init=2, max_iters=5, rgpe_samples=32)
    cons = [Constraint("runtime", EMU.runtime_target(WID, 50))]

    def submit(i):
        runner = lambda c: EMU.run(WID, c, rng=None)
        if i % 3 == 0:
            svc.submit(SearchRequest(
                space, runner, Objective("cost"), cons, method="karasu",
                bo_config=cfg, seed=100 + i))
        elif i % 3 == 1:
            svc.submit(SearchRequest(
                space, runner, None, cons, method="karasu",
                bo_config=cfg, seed=100 + i,
                objectives=[Objective("cost"), Objective("energy")],
                n_mc=8))
        else:
            svc.submit(SearchRequest(
                space, runner, None, (), method="karasu",
                bo_config=cfg, seed=100 + i,
                objectives=[Objective("cost"), Objective("energy"),
                            Objective("runtime")], n_mc=8))

    submitted = 0
    for _ in range(120):
        while len(svc.active) + len(svc.queue) < 3:
            submit(submitted)
            submitted += 1
        svc.step()
    assert len(svc.done) >= 6
    assert planner.signatures <= svc.precompiled_signatures
    assert svc.stats["plan_compile_misses"] == 0


def test_fit_leg_warm_and_cold_rungs_zero_recompile():
    """Warm (short-refine) and COLD (full-schedule) fit buckets serve
    in the SAME scheduling step without leaving the precompiled
    vocabulary: staggered tenant lifetimes put a fresh tenant's first
    fit (cold — no warm cache yet) alongside running tenants' warm
    refines, both rungs land in distinct precompiled buckets, and
    ``plan_compile_misses`` stays 0."""
    import dataclasses

    from repro.core.plan import CohortLimits, StepPlanner

    class RecordingPlanner(StepPlanner):
        def __init__(self):
            super().__init__()
            self.signatures = set()
            self.fit_rungs = []          # steps rungs per fit round

        def plan(self, queries):
            p = super().plan(queries)
            for b in p.buckets:
                if b.kind != "draw":
                    self.signatures.add(self.launch_signature(b))
            rungs = {b.key[1] for b in p.buckets if b.kind == "fit"}
            if rungs:
                self.fit_rungs.append(rungs)
            return p

    space = dataclasses.replace(SPACE, name="scout-mini",
                                configs=SPACE.configs[:8])
    planner = RecordingPlanner()
    svc = SearchService(Repository(), slots=2, planner=planner)
    limits = CohortLimits(d=space.all_encoded().shape[1], q_grid=8,
                          max_obs=8, max_lanes=8)
    svc.precompile(limits)

    def submit(i):
        rng = np.random.default_rng(i)
        svc.submit(SearchRequest(
            space, lambda c: EMU.run(WID, c, rng=rng),
            Objective("cost"), [Constraint("runtime", RT)],
            method="naive",
            bo_config=BOConfig(n_init=2, max_iters=4 + (i % 3)),
            seed=10 + i))

    submitted = 0
    for _ in range(40):
        while len(svc.active) + len(svc.queue) < 2:
            submit(submitted)
            submitted += 1
        svc.step()

    assert svc.stats["fit_warm_lanes"] > 0
    assert svc.stats["fit_cold_lanes"] > 0
    assert svc.stats["fit_fused_batches"] > 0
    # both rungs were planned, and at least one round carried BOTH at
    # once (a cold newcomer sharing the step with warm incumbents)
    rungs = {r for s in planner.fit_rungs for r in s}
    assert rungs == {svc.fit_warm_steps, svc.fit_steps}
    assert any(len(s) == 2 for s in planner.fit_rungs)
    fit_sigs = {s for s in planner.signatures if s[0] == "fit"}
    assert {dict(p for p in s if isinstance(p, tuple))["steps"]
            for s in fit_sigs} == rungs
    # vocabulary closed, zero serving-time compiles
    assert planner.signatures <= svc.precompiled_signatures
    assert svc.stats["plan_compile_misses"] == 0


def test_fit_warm_steps_disabled_runs_every_lane_cold():
    """``fit_warm_steps=None`` turns the warm cache off: every fit
    lane runs the full cold schedule and the warm counter stays 0."""
    svc = SearchService(Repository(), slots=2, fit_warm_steps=None)
    for s in range(2):
        svc.submit(_request(s, max_iters=4))
    svc.run()
    assert svc.stats["fit_cold_lanes"] > 0
    assert svc.stats["fit_warm_lanes"] == 0
