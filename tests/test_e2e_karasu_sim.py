"""End-to-end simulated-workload regression suite (paper §IV in miniature).

Locks down the whole serving/BO stack: NaiveBO vs Karasu on the
``simdata.scout_like`` emulator, run through ``SearchService`` with the
deterministic ``FakeProfileExecutor`` (heterogeneous virtual profiling
latencies, zero wall-clock). For both data-availability cases evaluated
here — A (collaborator data from entirely unrelated workloads) and D
(histories of the SAME workload from other users) — Karasu must reach a
near-optimal configuration in fewer profiling runs than NaiveBO, which
is the paper's core wall-clock claim. Everything is seeded and the fake
executor advances a virtual clock, so two consecutive runs of this suite
produce bit-for-bit identical trajectories (asserted below).

Marked ``slow``: it runs ~10 full searches; CI exercises it in the
dedicated slow job (see .github/workflows/ci.yml), not in tier-1.
"""
import numpy as np
import pytest

from benchmarks import common as C
from repro.core import BOConfig, Constraint, Objective, Repository
from repro.serve.profile_executor import FakeProfileExecutor
from repro.serve.search_service import SearchRequest, SearchService

pytestmark = pytest.mark.slow

WID = C.emulator().workload_ids()[6]          # spark1.5/terasort
RT = C.emulator().runtime_target(WID, 50)
OPT = C.emulator().optimal_cost(WID, RT)
SEEDS = (0, 1)
MAX_ITERS = 15
NEAR_OPT = 1.10                               # within 10% of the optimum


def _run_service(method: str, repo: Repository, seeds,
                 fit_warm_steps=16) -> dict:
    """All seeds' searches as concurrent tenants of ONE service, each
    profiling run carrying a seed-dependent virtual latency — the async
    scheduler overlaps them deterministically."""
    svc = SearchService(repo, slots=len(seeds),
                        executor=FakeProfileExecutor(
                            lambda job: 1 + job.rid % 3),
                        wait_mode="any", fit_warm_steps=fit_warm_steps)
    rid_to_seed = {}
    for seed in seeds:
        rid = svc.submit(SearchRequest(
            C.space(), C.profile_fn(WID, seed), Objective("cost"),
            [Constraint("runtime", RT)], method=method,
            bo_config=BOConfig(max_iters=MAX_ITERS), seed=seed))
        rid_to_seed[rid] = seed
    done = svc.run()
    assert len(done) == len(seeds)
    return {rid_to_seed[c.rid]: c.result for c in done}


def _runs_to_near_optimal(result) -> int:
    """Profiling runs until the incumbent's noise-free cost is within
    NEAR_OPT of the ground-truth optimum; budget+1 if never reached."""
    for i, bi in enumerate(result.best_index_per_iter):
        if bi >= 0:
            cost = C.noise_free_cost(WID, result.observations[bi].config)
            if cost <= NEAR_OPT * OPT:
                return i + 1
    return len(result.observations) + 1


def _case_repo(case: str) -> Repository:
    if case == "D":
        pool = C.build_same_workload_pool(WID, 3, iters=10)
        return C.repo_from_pool(pool, [0, 1, 2])
    return C.case_repo(WID, case, n_entries=4, runs_each=12)


def _fingerprint(result):
    return (tuple(tuple(sorted(o.config.items()))
                  for o in result.observations),
            tuple(float(o.measures["cost"]) for o in result.observations),
            tuple(result.best_index_per_iter))


@pytest.fixture(scope="module")
def naive_runs():
    return _run_service("naive", Repository(), SEEDS)


@pytest.mark.parametrize("case", ["A", "D"])
def test_karasu_beats_naive_runs_to_near_optimal(case, naive_runs):
    repo = _case_repo(case)
    karasu = _run_service("karasu", repo, SEEDS)
    n_naive = [_runs_to_near_optimal(naive_runs[s]) for s in SEEDS]
    n_karasu = [_runs_to_near_optimal(karasu[s]) for s in SEEDS]
    # support models were actually consulted
    for s in SEEDS:
        assert karasu[s].meta["selected"], (case, s)
    # the paper's claim: fewer profiling runs to a near-optimal config
    assert np.mean(n_karasu) < np.mean(n_naive), (case, n_karasu, n_naive)
    # and never pathologically worse on any single seed
    assert max(n_karasu) <= MAX_ITERS + 1, (case, n_karasu)


@pytest.mark.parametrize("case", ["A", "D"])
def test_warm_started_fit_is_no_worse_than_cold(case):
    """The warm-started incremental fit leg (16-step refine from the
    cached hyperparameters) must not cost search quality: runs to a
    near-optimal configuration with warm starting on (the default) stay
    no worse than with every fit forced cold (``fit_warm_steps=None``),
    on both data-availability cases. Warm and cold reach slightly
    different hyperparameters, so individual trajectories may diverge
    by a profiling run either way; the guard is against SYSTEMATIC
    degradation — mean within one run of cold, and never failing to
    reach near-optimal inside the budget."""
    warm = _run_service("karasu", _case_repo(case), SEEDS)
    cold = _run_service("karasu", _case_repo(case), SEEDS,
                        fit_warm_steps=None)
    n_warm = [_runs_to_near_optimal(warm[s]) for s in SEEDS]
    n_cold = [_runs_to_near_optimal(cold[s]) for s in SEEDS]
    assert np.mean(n_warm) <= np.mean(n_cold) + 1.0, (case, n_warm,
                                                      n_cold)
    assert max(n_warm) <= MAX_ITERS + 1, (case, n_warm)


def test_e2e_trajectories_deterministic_across_runs():
    """Two consecutive end-to-end runs (fresh service, fresh fake
    executor, same seeds) must be bit-for-bit identical — the property
    the whole regression suite rests on."""
    repo1 = _case_repo("A")
    repo2 = _case_repo("A")
    r1 = _run_service("karasu", repo1, SEEDS)
    r2 = _run_service("karasu", repo2, SEEDS)
    for s in SEEDS:
        assert _fingerprint(r1[s]) == _fingerprint(r2[s]), s
