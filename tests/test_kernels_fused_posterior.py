"""Fused posterior+EI bucket kernel vs its oracles.

The fused kernel collapses a (q, d) posterior bucket — masked Matern
cross-kernel, triangular solve against each lane's Cholesky factor,
posterior moments, closed-form EI — into one launch. Its contract is
bit-level boring: match the vmapped-XLA reference chain (itself checked
against ``core.gp``'s ``_batched_posterior`` and
``core.acquisition.expected_improvement``) to 1e-4 on every bucket the
planner can emit, including the degenerate ones (a single observation,
a fully-masked lane).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.acquisition import expected_improvement
from repro.core.gp import _pad_stack_obs, batched_posterior, fit_gp_batched
from repro.kernels.fused_posterior import (fused_posterior_ei,
                                           fused_posterior_ei_pallas,
                                           fused_posterior_ei_ref)

TOL = 1e-4


def _bucket(seed=0, counts=(7, 5, 3), d=3, q=11):
    """A fitted ragged stack, unpacked into the fused launch's arrays."""
    rng = np.random.default_rng(seed)
    xs = [rng.random((n, d)) for n in counts]
    ys = [np.sin(x.sum(axis=1)) + 0.1 * rng.normal(size=len(x))
          for x in xs]
    bgp = fit_gp_batched(xs, ys, steps=40)
    n_pad = bgp.x.shape[1]
    x, mask, chol, alpha = _pad_stack_obs(bgp, n_pad)
    xq = jnp.broadcast_to(jnp.asarray(rng.random((q, d)), jnp.float32),
                          (bgp.m, q, d))
    best = jnp.asarray(rng.normal(size=bgp.m), jnp.float32)
    return (bgp, [bgp.log_lengthscales, bgp.log_signal, x, mask, chol,
                  alpha, xq, best])


def test_ref_matches_batched_posterior_and_ei():
    bgp, parts = _bucket()
    mu, var, ei = fused_posterior_ei_ref(*parts)
    mu0, var0 = batched_posterior(bgp, np.asarray(parts[6][0]))
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu0), atol=1e-5)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var0),
                               atol=1e-5)
    ei0 = expected_improvement(mu, var, parts[7][:, None])
    np.testing.assert_allclose(np.asarray(ei), np.asarray(ei0), atol=1e-5)


@pytest.mark.parametrize("counts,q", [((7, 5, 3), 11), ((8, 8), 16),
                                      ((4,), 5)])
def test_pallas_interpret_matches_ref(counts, q):
    _, parts = _bucket(seed=1, counts=counts, q=q)
    ref = fused_posterior_ei_ref(*parts)
    got = fused_posterior_ei_pallas(*parts, interpret=True)
    for r, g, name in zip(ref, got, ("mu", "var", "ei")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=TOL,
                                   err_msg=name)


def test_pallas_interpret_multi_block_q_padding():
    """q that is not a block multiple forces the edge-pad path and a
    multi-program grid along q."""
    _, parts = _bucket(seed=2, counts=(6, 9), q=11)
    ref = fused_posterior_ei_ref(*parts)
    got = fused_posterior_ei_pallas(*parts, block_q=4, interpret=True)
    for r, g in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=TOL)


def test_edge_bucket_single_observation():
    """n_obs = 1 — the first observation of a fresh tenant."""
    _, parts = _bucket(seed=3, counts=(1,), q=7)
    ref = fused_posterior_ei_ref(*parts)
    got = fused_posterior_ei_pallas(*parts, interpret=True)
    for r, g in zip(ref, got):
        assert np.all(np.isfinite(np.asarray(r)))
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=TOL)


def test_edge_bucket_fully_masked_lane():
    """A lane whose mask is all zeros (an empty padding lane) must
    produce the prior — mu 0, var exp(log_sf) — not NaNs, in both
    implementations."""
    _, parts = _bucket(seed=4, counts=(6, 4), q=9)
    mask = np.asarray(parts[3]).copy()
    mask[1] = 0.0
    parts[3] = jnp.asarray(mask)
    ref = fused_posterior_ei_ref(*parts)
    got = fused_posterior_ei_pallas(*parts, interpret=True)
    np.testing.assert_allclose(np.asarray(ref[0][1]), 0.0, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(ref[1][1]), float(np.exp(np.asarray(parts[1])[1])),
        atol=1e-5)
    for r, g in zip(ref, got):
        assert np.all(np.isfinite(np.asarray(g)))
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=TOL)


def test_dispatcher_impls_and_errors():
    _, parts = _bucket(seed=5, counts=(5,), q=6)
    via_xla = fused_posterior_ei(*parts, impl="xla")
    ref = fused_posterior_ei_ref(*parts)
    for a, b in zip(via_xla, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)
    # auto on CPU CI resolves to the XLA reference and stays finite
    via_auto = fused_posterior_ei(*parts, impl="auto")
    for a in via_auto:
        assert np.all(np.isfinite(np.asarray(a)))
    with pytest.raises(ValueError, match="fused_posterior impl"):
        fused_posterior_ei(*parts, impl="nope")
