"""BatchedGP / batched RGPE: agreement with the per-model reference path
(acceptance: <= 1e-4 on the standardised scale), the fused posterior
query plan, impl routing, and weight invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (batched_posterior, batched_posterior_multi,
                        batched_sample, batched_sample_multi, build_ensemble,
                        compute_weights, compute_weights_batched,
                        compute_weights_multi, ensemble_posterior,
                        ensemble_posterior_batched, fit_gp, fit_gp_batched,
                        gp_posterior, stack_gps)
from repro.core.rgpe import BatchedEnsemble
from repro.kernels.routing import resolve_impl

TOL = 1e-4


def _surface(x):
    return np.sin(3 * x[:, 0]) + 0.5 * x[:, 1]


def _models(seed=0, sizes=(5, 9, 14)):
    rng = np.random.default_rng(seed)
    xs = [rng.random((n, 3)) for n in sizes]
    ys = [np.sin(3 * x[:, 0]) + 0.5 * x[:, 1] - x[:, 2] for x in xs]
    return xs, ys, rng


def test_batched_fit_matches_per_model_posterior():
    xs, ys, rng = _models()
    xq = rng.random((25, 3))
    bgp = fit_gp_batched(xs, ys)
    mu_b, var_b = batched_posterior(bgp, xq)
    for i, (x, y) in enumerate(zip(xs, ys)):
        gp = fit_gp(x, y)
        mu, var = gp_posterior(gp, xq)
        np.testing.assert_allclose(np.asarray(mu_b[i]), np.asarray(mu),
                                   atol=TOL)
        np.testing.assert_allclose(np.asarray(var_b[i]), np.asarray(var),
                                   atol=TOL)
        np.testing.assert_allclose(float(bgp.y_mean[i]), float(gp.y_mean),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(bgp.y_std[i]), float(gp.y_std),
                                   rtol=1e-6)


def test_padding_is_exact():
    """Extra padding must not change results beyond float32 roundoff
    (different jit shapes reassociate reductions, so not bitwise)."""
    xs, ys, rng = _models(seed=1)
    xq = rng.random((10, 3))
    a = fit_gp_batched(xs, ys)
    b = fit_gp_batched(xs, ys, n_max=32)
    mu_a, var_a = batched_posterior(a, xq)
    mu_b, var_b = batched_posterior(b, xq)
    np.testing.assert_allclose(np.asarray(mu_a), np.asarray(mu_b), atol=TOL)
    np.testing.assert_allclose(np.asarray(var_a), np.asarray(var_b),
                               atol=TOL)


def test_stack_gps_is_exact_and_extract_roundtrips():
    xs, ys, rng = _models(seed=2)
    gps = [fit_gp(x, y) for x, y in zip(xs, ys)]
    bgp = stack_gps(gps)
    xq = rng.random((12, 3))
    mu_b, var_b = batched_posterior(bgp, xq)
    for i, gp in enumerate(gps):
        mu, var = gp_posterior(gp, xq)
        np.testing.assert_allclose(np.asarray(mu_b[i]), np.asarray(mu),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(var_b[i]), np.asarray(var),
                                   atol=1e-5)
        g2 = bgp.extract(i)
        assert g2.n == gp.n
        mu2, _ = gp_posterior(g2, xq)
        np.testing.assert_allclose(np.asarray(mu2), np.asarray(mu),
                                   atol=1e-5)


def test_batched_sample_matches_per_model():
    xs, ys, rng = _models(seed=3)
    gps = [fit_gp(x, y) for x, y in zip(xs, ys)]
    bgp = stack_gps(gps)
    xq = rng.random((7, 3))
    keys = jax.random.split(jax.random.PRNGKey(5), len(gps))
    s = batched_sample(bgp, xq, keys, 32)
    assert s.shape == (len(gps), 32, 7)
    from repro.core.gp import gp_sample
    for i, gp in enumerate(gps):
        si = gp_sample(gp, xq, keys[i], 32)
        np.testing.assert_allclose(np.asarray(s[i]), np.asarray(si),
                                   atol=1e-5)


# -- fused sample query plan --------------------------------------------------


def test_batched_sample_multi_matches_per_stack():
    """Many stacks' posterior draws fused into one padded launch per
    (S, q, d) bucket must reproduce each per-stack ``batched_sample`` —
    including edge buckets: a single-model stack, an n_obs=1 model,
    mixed dims, and differing n_samples."""
    rng = np.random.default_rng(21)
    queries, singles = [], []
    cases = [((5, 9, 14), 3, 64, 7),     # sizes, d, S, q
             ((4, 7), 3, 64, 7),         # same bucket as above
             ((6,), 3, 64, 7),           # single-model stack, same bucket
             ((1, 8), 3, 64, 7),         # n_obs=1 lane, same bucket
             ((5, 9), 2, 64, 7),         # different dim -> own bucket
             ((5, 9), 3, 32, 7),         # different S -> own bucket
             ((5, 9), 3, 64, 11)]        # different q -> own bucket
    for j, (sizes, d, S, q) in enumerate(cases):
        xs = [rng.random((n, d)) for n in sizes]
        ys = [x[:, 0] + np.sin(3 * x[:, 1]) for x in xs]
        st = fit_gp_batched(xs, ys)
        xq = rng.random((q, d))
        keys = jax.random.split(jax.random.PRNGKey(j), len(sizes))
        queries.append((st, xq, keys, S))
        singles.append(batched_sample(st, xq, keys, S))

    counters = {}
    res = batched_sample_multi(queries, counters=counters)
    # first four cases share one (64, 7, 3) bucket; the rest are singletons
    assert counters["launches"] == 4
    assert counters["queries"] == len(cases)
    for (st, xq, _, S), got, want in zip(queries, res, singles):
        assert got.shape == (st.m, S, xq.shape[0])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=TOL)


def test_batched_sample_multi_draw_streams_are_fusion_invariant():
    """Each lane consumes normal(key_i, (S, q)) regardless of which
    other queries share its launch, so adding an unrelated query to the
    plan must not perturb existing draws (beyond posterior roundoff)."""
    rng = np.random.default_rng(22)
    xs = [rng.random((n, 2)) for n in (5, 8)]
    st = fit_gp_batched(xs, [x[:, 0] for x in xs])
    xq = rng.random((6, 2))
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    alone, = batched_sample_multi([(st, xq, keys, 48)])
    other = fit_gp_batched([rng.random((12, 2))], [np.zeros(12)])
    joined, _ = batched_sample_multi(
        [(st, xq, keys, 48), (other, xq, jax.random.split(
            jax.random.PRNGKey(9), 1), 48)])
    np.testing.assert_allclose(np.asarray(alone), np.asarray(joined),
                               atol=TOL)


def test_loo_sample_multi_matches_per_target():
    """Fused leave-one-out draws (padded cho_solve, exact-shape eps)
    must reproduce per-target gp_loo_samples — including an n_obs=1
    target and mixed observation counts in one call."""
    import jax.random as jr
    from repro.core.gp import gp_loo_samples, loo_sample_multi
    rng = np.random.default_rng(31)
    targets = []
    for n in (6, 6, 9, 1):
        x = rng.random((n, 2))
        targets.append(fit_gp(x, x[:, 0] + 0.1 * rng.normal(size=n)))
    queries = [(gp, jr.PRNGKey(i), 32) for i, gp in enumerate(targets)]
    counters = {}
    res = loo_sample_multi(queries, counters=counters)
    assert counters["launches"] == 3        # n=6 bucket shared, 9, 1
    assert counters["queries"] == 4
    for (gp, key, S), got in zip(queries, res):
        want = gp_loo_samples(gp, key, S)
        assert got.shape == want.shape == (S, gp.n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=TOL)


def test_compute_weights_multi_fused_samples_match_loop():
    """fuse_samples=True (the sample query plan) and the per-job draw
    loop consume identical PRNG streams, so weights must agree — and
    the fused path must report its launch fusion via sample_counters."""
    from repro.core.rgpe import WeightJob
    rng = np.random.default_rng(23)
    jobs = []
    for j in range(3):
        xs = [rng.random((10 + i, 2)) for i in range(2)]
        bases = fit_gp_batched(xs, [_surface(x) for x in xs])
        xt = rng.random((6, 2))         # same n_obs -> one sample bucket
        jobs.append(WeightJob(bases, fit_gp(xt, _surface(xt)),
                              jax.random.PRNGKey(j), 64))
    # an n_obs=1 job: uniform short-circuit, never enters the plan
    x1 = rng.random((1, 2))
    jobs.append(WeightJob(bases, fit_gp(x1, x1[:, 0]),
                          jax.random.PRNGKey(9), 64))
    sc = {}
    w_fused = compute_weights_multi(jobs, fuse_samples=True,
                                    sample_counters=sc)
    w_loop = compute_weights_multi(jobs, fuse_samples=False)
    # one fused base-draw launch + one fused LOO launch for all 3 jobs
    assert sc["launches"] == 2 and sc["queries"] == 6
    for a, b in zip(w_fused, w_loop):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=TOL)
    np.testing.assert_allclose(np.asarray(w_fused[-1]),
                               np.full(3, 1.0 / 3.0), atol=1e-7)


# -- fused posterior query plan ---------------------------------------------


def test_batched_posterior_multi_matches_per_stack():
    """Many stacks of different m / n_max / grids fused into one padded
    launch must reproduce each per-stack batched_posterior."""
    rng = np.random.default_rng(11)
    stacks, grids = [], []
    for sizes in ((5, 9, 14), (4, 7), (6,)):
        xs = [rng.random((n, 3)) for n in sizes]
        ys = [x[:, 0] + np.sin(3 * x[:, 1]) for x in xs]
        stacks.append(fit_gp_batched(xs, ys))
        grids.append(rng.random((25, 3)))
    # a (q, d) group of its own: fused plan buckets by grid shape
    stacks.append(stacks[0])
    grids.append(rng.random((13, 3)))

    counters = {}
    res = batched_posterior_multi(list(zip(stacks, grids)),
                                  counters=counters)
    assert counters["launches"] == 2        # (25, 3) bucket + (13, 3)
    assert counters["queries"] == 4
    for st, xq, (mu, var) in zip(stacks, grids, res):
        mu0, var0 = batched_posterior(st, xq)
        assert mu.shape == (st.m, xq.shape[0])
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mu0),
                                   atol=TOL)
        np.testing.assert_allclose(np.asarray(var), np.asarray(var0),
                                   atol=TOL)


def test_mix_weighted_matches_ensemble_posterior_batched():
    """Fusing bases + target rows through mix_weighted (as the query
    plan does) agrees with the per-ensemble mixture oracle."""
    rng = np.random.default_rng(12)
    xs = rng.random((20, 2))
    bases = stack_gps([fit_gp(xs, _surface(xs)),
                       fit_gp(rng.random((10, 2)), rng.normal(size=10))])
    x_t = rng.random((6, 2))
    target = fit_gp(x_t, _surface(x_t))
    w = compute_weights_batched(bases, target, jax.random.PRNGKey(3))
    ens = BatchedEnsemble(bases, target, w)
    xq = rng.random((30, 2))
    mu_b, var_b = batched_posterior(bases, xq)
    mu_t, var_t = gp_posterior(target, xq)
    from repro.core import mix_weighted
    mu, var = mix_weighted(mu_b, var_b, mu_t, var_t, w)
    mu0, var0 = ensemble_posterior_batched(ens, xq)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu0), atol=TOL)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var0), atol=TOL)


def test_impl_routing_resolves_auto_by_backend_and_size():
    # explicit impls pass through untouched on any backend
    for impl in ("xla", "pallas", "pallas_interpret"):
        assert resolve_impl(impl, cells=1) == impl
    # auto: pallas only on TPU and only above the cell threshold
    assert resolve_impl("auto", cells=1 << 30, backend="tpu") == "pallas"
    assert resolve_impl("auto", cells=8, backend="tpu") == "xla"
    assert resolve_impl("auto", cells=1 << 30, backend="cpu") == "xla"
    assert resolve_impl("auto", cells=1 << 30, backend="gpu") == "xla"
    # threshold override
    assert resolve_impl("auto", cells=9, backend="tpu",
                        min_cells=8) == "pallas"
    # on this machine (CPU CI) auto must resolve to the XLA reference
    rng = np.random.default_rng(0)
    xs = [rng.random((5, 2))]
    bgp = fit_gp_batched(xs, [xs[0][:, 0]])
    mu_a, var_a = batched_posterior(bgp, rng.random((4, 2)), impl="auto")
    assert np.all(np.isfinite(np.asarray(mu_a)))


def test_impl_routing_env_threshold_read_at_resolve_time(monkeypatch):
    # the env override must be honoured even when set AFTER import —
    # it used to be frozen into the module constant at import time, so
    # services configured via env after ``import repro`` silently kept
    # the default threshold
    monkeypatch.setenv("REPRO_PALLAS_AUTO_MIN_CELLS", "16")
    assert resolve_impl("auto", cells=16, backend="tpu") == "pallas"
    assert resolve_impl("auto", cells=15, backend="tpu") == "xla"
    monkeypatch.setenv("REPRO_PALLAS_AUTO_MIN_CELLS", str(1 << 30))
    assert resolve_impl("auto", cells=16, backend="tpu") == "xla"
    # an explicit min_cells argument still beats the env var
    monkeypatch.setenv("REPRO_PALLAS_AUTO_MIN_CELLS", "1")
    assert resolve_impl("auto", cells=2, backend="tpu",
                        min_cells=4) == "xla"
    monkeypatch.delenv("REPRO_PALLAS_AUTO_MIN_CELLS")
    assert resolve_impl("auto", cells=1 << 30, backend="tpu") == "pallas"


# -- RGPE weights ------------------------------------------------------------


def _rgpe_setup(seed=4):
    rng = np.random.default_rng(seed)
    xs = rng.random((30, 2))
    related = fit_gp(xs, _surface(xs))
    unrelated = fit_gp(rng.random((12, 2)), rng.normal(size=12))
    x_t = rng.random((8, 2))
    target = fit_gp(x_t, _surface(x_t))
    return related, unrelated, target, rng


def test_batched_weights_match_sequential():
    related, unrelated, target, _ = _rgpe_setup()
    key = jax.random.PRNGKey(0)
    w_seq = np.asarray(compute_weights([related, unrelated], target, key))
    w_bat = np.asarray(compute_weights_batched(
        stack_gps([related, unrelated]), target, key))
    np.testing.assert_allclose(w_bat, w_seq, atol=TOL)


def test_weights_on_simplex_and_target_never_diluted():
    related, unrelated, target, _ = _rgpe_setup(seed=5)
    for key_i in range(3):
        w = np.asarray(compute_weights_batched(
            stack_gps([related, unrelated]), target,
            jax.random.PRNGKey(key_i), n_samples=64))
        assert w.shape == (3,)
        assert np.all(w >= -1e-9)
        np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)
        # the related model must dominate the pure-noise one
        assert w[0] >= w[1]
    # dilution prevention never drops the target: even vs a perfect base
    # model the target keeps a nonzero share of the argmin ties
    w = np.asarray(compute_weights_batched(
        stack_gps([related]), target, jax.random.PRNGKey(9)))
    assert w[-1] > 0.0


def test_single_observation_falls_back_to_uniform():
    related, unrelated, target, rng = _rgpe_setup(seed=6)
    t1 = fit_gp(np.asarray(target.x)[:1], np.asarray(target.y_raw)[:1])
    bases = stack_gps([related, unrelated])
    w_b = np.asarray(compute_weights_batched(bases, t1,
                                             jax.random.PRNGKey(0)))
    w_s = np.asarray(compute_weights([related, unrelated], t1,
                                     jax.random.PRNGKey(0)))
    np.testing.assert_allclose(w_b, np.full(3, 1.0 / 3.0), atol=1e-7)
    np.testing.assert_allclose(w_s, w_b, atol=1e-7)


def test_batched_ensemble_posterior_matches_sequential():
    related, unrelated, target, rng = _rgpe_setup(seed=7)
    key = jax.random.PRNGKey(2)
    ens = build_ensemble([related, unrelated], target, key)
    bens = BatchedEnsemble(stack_gps([related, unrelated]), target,
                           compute_weights_batched(
                               stack_gps([related, unrelated]), target, key))
    xq = rng.random((40, 2))
    mu, var = ensemble_posterior(ens, xq)
    mu_b, var_b = ensemble_posterior_batched(bens, xq)
    np.testing.assert_allclose(np.asarray(mu_b), np.asarray(mu), atol=TOL)
    np.testing.assert_allclose(np.asarray(var_b), np.asarray(var), atol=TOL)


def test_pack_fit_lanes_standardisation_is_bitwise_per_lane():
    """The one-shot f64-accumulated standardisation in _pack_fit_lanes
    must be BITWISE identical to an explicit per-lane float64 loop
    mirroring its operation order: both the legacy vmapped fit and the
    fused fit leg consume this packing, so any drift here would
    silently fork their parity baselines. Includes a single-observation
    lane and a constant-target lane (the 1e-8 std clamp path)."""
    from repro.core.gp import _pack_fit_lanes
    rng = np.random.default_rng(11)
    counts = (7, 5, 1, 4)
    d, nm = 3, 8
    xs = [rng.random((n, d)) for n in counts]
    ys = [rng.normal(size=n) * 10.0 + 5.0 for n in counts]
    ys[3] = np.full(4, 2.5)                    # constant -> clamped std
    x, ysd, mask, y_mean, y_std = _pack_fit_lanes(
        xs, ys, list(counts), nm)
    for i, n in enumerate(counts):
        row = np.zeros(nm, np.float32)
        row[:n] = np.asarray(ys[i], np.float32)
        mrow = np.zeros(nm, np.float32)
        mrow[:n] = 1.0
        mu = row.sum(dtype=np.float64) / np.float64(n)
        sq = ((row - mu) * mrow) ** 2
        sd = np.maximum(np.sqrt(sq.sum(dtype=np.float64)
                                / np.float64(n)), 1e-8)
        ym = np.float32(mu)
        ysd_i = ((row - ym) / np.float32(sd)) * mrow
        assert y_mean[i] == ym
        assert y_std[i] == np.float32(sd)
        assert np.array_equal(ysd[i], ysd_i)
        assert np.array_equal(mask[i], mrow)
        assert np.array_equal(x[i, :n],
                              np.asarray(xs[i], np.float32))
        assert (x[i, n:] == 0).all() and (ysd[i, n:] == 0).all()
