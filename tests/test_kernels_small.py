"""matern / pairwise_pearson / ranking_loss kernels vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.stats import pearsonr

from repro.kernels.matern import matern52, matern52_ref
from repro.kernels.pairwise_pearson import pairwise_pearson
from repro.kernels.ranking_loss import ranking_loss, ranking_loss_ref


@pytest.mark.parametrize("m,n,d", [(5, 7, 3), (37, 53, 7), (130, 64, 18)])
def test_matern_pallas_vs_ref(m, n, d):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    r = matern52_ref(a, b)
    p = matern52(a, b, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(p), np.asarray(r),
                               atol=1e-5, rtol=1e-5)


def test_matern_identity_diag():
    a = jax.random.normal(jax.random.PRNGKey(0), (9, 4))
    k = np.asarray(matern52_ref(a, a))
    np.testing.assert_allclose(np.diagonal(k), 1.0, atol=1e-4)
    assert np.all(k <= 1.0 + 1e-5) and np.all(k > 0)


@pytest.mark.parametrize("m,n,d", [(4, 6, 18), (9, 13, 30), (70, 5, 18)])
def test_pearson_vs_scipy(m, n, d):
    rng = np.random.default_rng(0)
    a, b = rng.normal(size=(m, d)), rng.normal(size=(n, d))
    for impl in ["xla", "pallas_interpret"]:
        r = np.asarray(pairwise_pearson(jnp.array(a), jnp.array(b),
                                        impl=impl))
        exp = np.array([[pearsonr(a[i], b[j])[0] for j in range(n)]
                        for i in range(m)])
        np.testing.assert_allclose(r, exp, atol=1e-5, err_msg=impl)


@pytest.mark.parametrize("s,n", [(7, 5), (19, 11), (200, 20)])
def test_ranking_loss_vs_bruteforce(s, n):
    p = jax.random.normal(jax.random.PRNGKey(0), (s, n))
    y = jax.random.normal(jax.random.PRNGKey(1), (n,))
    ref = np.asarray(ranking_loss_ref(p, y))
    brute = np.zeros(s, int)
    pn, yn = np.asarray(p), np.asarray(y)
    for si in range(s):
        for j in range(n):
            for k in range(n):
                brute[si] += (pn[si, j] < pn[si, k]) ^ (yn[j] < yn[k])
    np.testing.assert_array_equal(ref, brute)
    pi = np.asarray(ranking_loss(p, y, impl="pallas_interpret"))
    np.testing.assert_array_equal(pi, brute)
