"""matern / pairwise_pearson / ranking_loss kernels vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.stats import pearsonr

from repro.kernels.matern import matern52, matern52_ref
from repro.kernels.pairwise_pearson import pairwise_pearson
from repro.kernels.ranking_loss import (ranking_loss, ranking_loss_padded,
                                        ranking_loss_padded_ref,
                                        ranking_loss_ref)

IMPLS = ["xla", "pallas_interpret"]


@pytest.mark.parametrize("m,n,d", [(5, 7, 3), (37, 53, 7), (130, 64, 18)])
def test_matern_pallas_vs_ref(m, n, d):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    r = matern52_ref(a, b)
    p = matern52(a, b, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(p), np.asarray(r),
                               atol=1e-5, rtol=1e-5)


def test_matern_identity_diag():
    a = jax.random.normal(jax.random.PRNGKey(0), (9, 4))
    k = np.asarray(matern52_ref(a, a))
    np.testing.assert_allclose(np.diagonal(k), 1.0, atol=1e-4)
    assert np.all(k <= 1.0 + 1e-5) and np.all(k > 0)


@pytest.mark.parametrize("m,n,d", [(4, 6, 18), (9, 13, 30), (70, 5, 18)])
def test_pearson_vs_scipy(m, n, d):
    rng = np.random.default_rng(0)
    a, b = rng.normal(size=(m, d)), rng.normal(size=(n, d))
    for impl in ["xla", "pallas_interpret"]:
        r = np.asarray(pairwise_pearson(jnp.array(a), jnp.array(b),
                                        impl=impl))
        exp = np.array([[pearsonr(a[i], b[j])[0] for j in range(n)]
                        for i in range(m)])
        np.testing.assert_allclose(r, exp, atol=1e-5, err_msg=impl)


@pytest.mark.parametrize("m,n", [(1, 18), (3, 1), (2, 2)])
def test_pearson_edge_shapes_impls_agree(m, n):
    """Single-row batches on either side: implementations must agree on
    the shapes Algorithm-1 hits with tiny target histories."""
    rng = np.random.default_rng(3)
    a, b = rng.normal(size=(m, 6)), rng.normal(size=(n, 6))
    ref = np.asarray(pairwise_pearson(jnp.array(a), jnp.array(b)))
    got = np.asarray(pairwise_pearson(jnp.array(a), jnp.array(b),
                                      impl="pallas_interpret"))
    np.testing.assert_allclose(got, ref, atol=1e-5)


def test_pearson_constant_row_is_finite():
    """A zero-variance metric vector must yield a finite (clamped)
    correlation, not NaN/inf, in both implementations."""
    a = np.ones((2, 8)) * 5.0               # constant rows
    b = np.random.default_rng(0).normal(size=(3, 8))
    for impl in IMPLS:
        r = np.asarray(pairwise_pearson(jnp.array(a), jnp.array(b),
                                        impl=impl))
        assert np.all(np.isfinite(r)), impl


@pytest.mark.parametrize("s,n", [(7, 5), (19, 11), (200, 20)])
def test_ranking_loss_vs_bruteforce(s, n):
    p = jax.random.normal(jax.random.PRNGKey(0), (s, n))
    y = jax.random.normal(jax.random.PRNGKey(1), (n,))
    ref = np.asarray(ranking_loss_ref(p, y))
    brute = np.zeros(s, int)
    pn, yn = np.asarray(p), np.asarray(y)
    for si in range(s):
        for j in range(n):
            for k in range(n):
                brute[si] += (pn[si, j] < pn[si, k]) ^ (yn[j] < yn[k])
    np.testing.assert_array_equal(ref, brute)
    pi = np.asarray(ranking_loss(p, y, impl="pallas_interpret"))
    np.testing.assert_array_equal(pi, brute)


def test_ranking_loss_single_observation_is_zero():
    """n_obs=1 has no rankable pair: loss must be 0, not garbage, in
    every implementation (the RGPE short-circuit relies on callers, but
    the kernel itself must still be well-defined)."""
    p = jax.random.normal(jax.random.PRNGKey(0), (9, 1))
    y = jnp.array([3.0])
    for impl in IMPLS:
        np.testing.assert_array_equal(
            np.asarray(ranking_loss(p, y, impl=impl)), np.zeros(9, int),
            err_msg=impl)


def test_ranking_loss_all_tied_targets():
    """All-tied y: no pair satisfies y[j] < y[k], so the loss is exactly
    the number of strictly ordered prediction pairs."""
    s, n = 13, 6
    p = jax.random.normal(jax.random.PRNGKey(2), (s, n))
    y = jnp.full((n,), 2.5)
    pn = np.asarray(p)
    want = np.array([(pn[i][:, None] < pn[i][None, :]).sum()
                     for i in range(s)])
    for impl in IMPLS:
        np.testing.assert_array_equal(
            np.asarray(ranking_loss(p, y, impl=impl)), want, err_msg=impl)


def _ragged_batch(problems):
    """Pack [(preds (S,n), y (n,)), ...] into padded (R, n_max) arrays."""
    n_max = max(p.shape[1] for p, _ in problems)
    P = np.concatenate([np.pad(p, ((0, 0), (0, n_max - p.shape[1])))
                        for p, _ in problems])
    Y = np.concatenate([np.pad(np.broadcast_to(y, p.shape),
                               ((0, 0), (0, n_max - p.shape[1])))
                        for p, y in problems])
    NV = np.concatenate([np.full(p.shape[0], p.shape[1], np.int32)
                         for p, _ in problems])
    return jnp.array(P), jnp.array(Y), jnp.array(NV)


@pytest.mark.parametrize("impl", IMPLS)
def test_ranking_loss_padded_matches_per_problem(impl):
    """The ragged batch entry point must reproduce per-problem
    ranking_loss exactly, including n_obs=1 and all-tied-y rows."""
    rng = np.random.default_rng(0)
    problems = [
        (rng.normal(size=(5, 7)), rng.normal(size=7)),
        (rng.normal(size=(4, 1)), rng.normal(size=1)),       # n_obs = 1
        (rng.normal(size=(6, 9)), np.full(9, 1.0)),          # all tied
        (rng.normal(size=(3, 20)), rng.normal(size=20)),
    ]
    P, Y, NV = _ragged_batch(problems)
    got = np.asarray(ranking_loss_padded(P, Y, NV, impl=impl))
    off = 0
    for p, y in problems:
        want = np.asarray(ranking_loss(jnp.array(p), jnp.array(y)))
        np.testing.assert_array_equal(got[off:off + p.shape[0]], want,
                                      err_msg=impl)
        off += p.shape[0]


@pytest.mark.parametrize("impl", IMPLS)
def test_ranking_loss_padded_fully_masked_rows(impl):
    """Rows with n_valid = 0 (pure padding) must count zero pairs no
    matter what values sit in the padded sample/target slots."""
    rng = np.random.default_rng(1)
    P = jnp.array(rng.normal(size=(11, 8)) * 1e6)
    Y = jnp.array(rng.normal(size=(11, 8)))
    nv = np.zeros(11, np.int32)
    nv[::3] = 8                            # interleave some live rows
    got = np.asarray(ranking_loss_padded(P, Y, jnp.array(nv), impl=impl))
    assert (got[nv == 0] == 0).all()
    ref = np.asarray(ranking_loss_padded_ref(P, Y, jnp.array(nv)))
    np.testing.assert_array_equal(got, ref)
