"""SSD scan: chunked xla + pallas(interpret) vs sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref


def _mk(b, s, h, hd, n, per_head, dtype=jnp.float32):
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (b, s, h, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1),
                                           (b, s, h)))
    decay = jnp.exp(-dt * jnp.exp(jax.random.normal(
        jax.random.fold_in(k, 2), (h,))))
    shp = (b, s, h, n) if per_head else (b, s, n)
    B = jax.random.normal(jax.random.fold_in(k, 3), shp, dtype)
    C = jax.random.normal(jax.random.fold_in(k, 4), shp, dtype)
    S0 = jax.random.normal(jax.random.fold_in(k, 5), (b, h, hd, n))
    return x, dt, decay, B, C, S0


@pytest.mark.parametrize("b,s,h,hd,n", [
    (1, 32, 2, 8, 4), (2, 67, 3, 16, 8), (1, 200, 1, 8, 16),
])
@pytest.mark.parametrize("per_head", [False, True])
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_ssm_scan_vs_ref(b, s, h, hd, n, per_head, impl):
    x, dt, decay, B, C, S0 = _mk(b, s, h, hd, n, per_head)
    yr, sr = ssm_scan_ref(x, dt, decay, B, C, S0)
    y, sf = ssm_scan(x, dt, decay, B, C, initial_state=S0, impl=impl)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               atol=2e-4, rtol=2e-4)


def test_ssm_scan_state_chaining():
    """Scanning two halves with carried state == scanning whole."""
    x, dt, decay, B, C, _ = _mk(1, 64, 2, 8, 4, False)
    y_full, s_full = ssm_scan(x, dt, decay, B, C, impl="xla")
    y1, s1 = ssm_scan(x[:, :32], dt[:, :32], decay[:, :32], B[:, :32],
                      C[:, :32], impl="xla")
    y2, s2 = ssm_scan(x[:, 32:], dt[:, 32:], decay[:, 32:], B[:, 32:],
                      C[:, 32:], initial_state=s1, impl="xla")
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=2e-4, rtol=2e-4)
