"""BO loop integration: convergence, early stopping, Karasu >= NaiveBO
with same-workload support data (the paper's core claim in miniature)."""
import numpy as np
import pytest

from repro.core import (BOConfig, Constraint, Objective, Repository,
                        run_search, scout_search_space)
from repro.simdata import make_emulator

EMU = make_emulator()
SPACE = scout_search_space()
WID = EMU.workload_ids()[6]   # spark1.5/terasort
TARGET_RT = EMU.runtime_target(WID, 50)
OPT = EMU.optimal_cost(WID, TARGET_RT)


def _profile(seed):
    rng = np.random.default_rng(seed)
    return lambda c: EMU.run(WID, c, rng=rng)


def _final_gap(result):
    i = result.best_index_per_iter[-1]
    assert i >= 0, "no feasible config found"
    return result.observations[i].measures["cost"] / OPT - 1.0


def test_naive_bo_converges():
    r = run_search(SPACE, _profile(0), Objective("cost"),
                   [Constraint("runtime", TARGET_RT)], method="naive",
                   bo_config=BOConfig(max_iters=12), seed=0)
    assert len(r.observations) == 12
    assert _final_gap(r) < 0.5


def test_early_stopping_triggers():
    r = run_search(SPACE, _profile(0), Objective("cost"),
                   [Constraint("runtime", TARGET_RT)], method="naive",
                   bo_config=BOConfig(max_iters=20, early_stop=True),
                   seed=0)
    assert len(r.observations) <= 20
    assert r.meta["n_profiled"] >= 6   # stopping needs >= 6 runs


def test_karasu_uses_support_and_improves_early():
    """Case D: repository holds another user's runs of the same workload;
    Karasu's early-iteration incumbent should (weakly) dominate NaiveBO's
    on average over seeds."""
    repo = Repository()
    rng = np.random.default_rng(99)
    for u in range(2):
        for ci in rng.choice(len(SPACE), 12, replace=False):
            repo.add_run(EMU.make_record(f"anon-{u}", WID,
                                         SPACE.configs[ci], rng))
    gaps_n, gaps_k = [], []
    for seed in range(2):
        rn = run_search(SPACE, _profile(seed), Objective("cost"),
                        [Constraint("runtime", TARGET_RT)], method="naive",
                        bo_config=BOConfig(max_iters=8), seed=seed)
        rk = run_search(SPACE, _profile(seed), Objective("cost"),
                        [Constraint("runtime", TARGET_RT)],
                        method="karasu", repository=repo,
                        bo_config=BOConfig(max_iters=8), seed=seed)
        assert rk.meta["selected"], "karasu never selected support models"
        gaps_n.append(_final_gap(rn))
        gaps_k.append(_final_gap(rk))
    assert np.mean(gaps_k) <= np.mean(gaps_n) + 0.10, (gaps_k, gaps_n)


def test_augmented_bo_runs():
    r = run_search(SPACE, _profile(1), Objective("cost"),
                   [Constraint("runtime", TARGET_RT)], method="augmented",
                   bo_config=BOConfig(max_iters=8), seed=1)
    assert len(r.observations) == 8


def test_karasu_fused_posteriors_match_per_ensemble_loop():
    """run_search's karasu model refresh fuses ALL grid posteriors
    (target stack + every measure's support stack) into one launch; it
    must agree with the historical per-ensemble loop
    (``ensemble_posterior_batched`` per measure) to 1e-4."""
    import jax
    from repro.core import BatchedEnsemble, ensemble_posterior_batched
    from repro.core.bo import KarasuContext, _model_posteriors_karasu

    repo = Repository()
    rng = np.random.default_rng(42)
    for u in range(2):
        for ci in rng.choice(len(SPACE), 12, replace=False):
            repo.add_run(EMU.make_record(f"anon-{u}", WID,
                                         SPACE.configs[ci], rng))
    # a few target observations with metrics, as mid-search state
    from repro.core.types import Observation
    xq_all = SPACE.all_encoded()
    obs = []
    for ci in rng.choice(len(SPACE), 5, replace=False):
        m, metr = EMU.run(WID, SPACE.configs[int(ci)], rng=rng)
        obs.append(Observation(config=SPACE.configs[int(ci)],
                               x=xq_all[int(ci)], measures=m,
                               metrics=metr))

    cfg = BOConfig()
    ctx = KarasuContext(repo, SPACE, noise=cfg.noise)
    measures = ["cost", "runtime"]
    key = jax.random.PRNGKey(7)
    xq = xq_all[:40]
    post, selected = _model_posteriors_karasu(obs, measures, cfg, ctx,
                                              key, xq)
    assert selected, "no support selected — parity test vacuous"

    # reconstruct the old loop with the SAME weights and support stacks
    from repro.core import fit_gp_batched
    x = np.stack([o.x for o in obs])
    tgts = fit_gp_batched([x] * len(measures),
                          [np.array([o.measures[m] for o in obs])
                           for m in measures], noise=cfg.noise, round_to=8)
    for mi, m in enumerate(measures):
        bases, _ = ctx.store.get_stacked([z for z, _ in selected], m)
        assert bases is not None
        w = post[m]["weights"]
        assert len(w) == bases.m + 1
        mu0, var0 = ensemble_posterior_batched(
            BatchedEnsemble(bases, tgts.extract(mi), w), xq)
        np.testing.assert_allclose(np.asarray(post[m]["mu"]),
                                   np.asarray(mu0), atol=1e-4)
        np.testing.assert_allclose(np.asarray(post[m]["var"]),
                                   np.asarray(var0), atol=1e-4)
