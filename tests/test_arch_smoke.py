"""Per-architecture smoke tests: reduced configs, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.train.optim import adamw, cosine_schedule
from repro.train.step import make_train_step


def _batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = jax.random.normal(
            key, (b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_image_patches:
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_image_patches, cfg.d_model), jnp.bfloat16)
        batch["image_mask"] = jnp.zeros((b, s), bool).at[
            :, 2:2 + min(cfg.n_image_patches, s - 2)].set(True)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = bundle.train_logits(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss_finite(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt = adamw()
    opt_state = opt.init(params)
    step = make_train_step(bundle, opt, cosine_schedule(1e-3, 2, 100),
                           microbatches=2)
    batch = _batch(cfg)
    p, o, metrics = step(params, opt_state, batch, jnp.ones((), jnp.int32))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    caches = bundle.init_cache(params, 2, 24, batch=batch,
                               dtype=jnp.float32)
    tok = batch["tokens"][:, :1]
    for pos in range(2):
        positions = jnp.full((2, 1), pos, jnp.int32)
        logits, caches = bundle.decode_step(params, caches, tok, positions)
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)


def test_full_configs_match_assignment():
    spec = {
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51872),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d, arch
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff and cfg.vocab == v, arch
    qw = get_config("qwen3-moe-235b-a22b")
    assert qw.n_experts == 128 and qw.top_k == 8 and qw.moe_d_ff == 1536
    ar = get_config("arctic-480b")
    assert ar.n_experts == 128 and ar.top_k == 2 and ar.dense_residual
    za = get_config("zamba2-1.2b")
    assert za.ssm_state == 64
