"""MoE: ragged grouped-GEMM path vs dense oracle; EP modes on a tiny
4-device mesh vs local path."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ModelConfig
from repro.models.moe import init_moe, moe

CFG = ModelConfig(name="t", d_model=32, d_ff=64, n_experts=8, top_k=2,
                  moe_d_ff=48, moe_capacity_factor=8.0,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)


def test_ragged_matches_dense():
    import dataclasses
    p = init_moe(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
    outs = {}
    for impl in ["dense", "ragged"]:
        cfg = dataclasses.replace(CFG, moe_impl=impl)
        outs[impl], aux = moe(p, x, cfg)
        assert bool(jnp.isfinite(aux))
    np.testing.assert_allclose(np.asarray(outs["ragged"]),
                               np.asarray(outs["dense"]),
                               atol=1e-4, rtol=1e-4)


def test_router_weights_normalised():
    from repro.models.moe import _route
    p = init_moe(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 32))
    w, idx, aux = _route(p["router"], x, CFG.top_k)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert int(jnp.max(idx)) < CFG.n_experts
    assert float(aux) >= 1.0 - 1e-3  # aux >= 1 at optimum (balanced)
