"""Flash attention: xla + pallas(interpret) vs naive oracle, shape/dtype
sweeps including non-divisible tails, GQA, SWA, softcap, decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention

SHAPES = [
    # (b, q, kv, nq, nkv, hd)
    (1, 16, 16, 2, 2, 8),
    (2, 67, 131, 8, 2, 32),      # GQA + ragged tails
    (1, 128, 128, 4, 4, 64),
    (2, 1, 160, 8, 4, 16),       # decode-style
]


def _mk(shape, dtype):
    b, q, kv, nq, nkv, hd = shape
    k = jax.random.PRNGKey(0)
    qa = jax.random.normal(k, (b, q, nq, hd), dtype)
    ka = jax.random.normal(jax.random.fold_in(k, 1), (b, kv, nkv, hd), dtype)
    va = jax.random.normal(jax.random.fold_in(k, 2), (b, kv, nkv, hd), dtype)
    return qa, ka, va


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=False),
    dict(causal=True, window=16), dict(causal=True, softcap=20.0),
])
def test_flash_vs_ref(shape, dtype, kwargs):
    qa, ka, va = _mk(shape, dtype)
    ref = attention_ref(qa, ka, va, **kwargs)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    for impl in ["xla", "pallas_interpret"]:
        out = flash_attention(qa, ka, va, impl=impl, **kwargs)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol, err_msg=f"{impl} {shape} {kwargs}")


def test_decode_path_with_mask():
    qa, ka, va = _mk((2, 1, 64, 8, 4, 16), jnp.float32)
    kv_mask = jnp.arange(64)[None, :] < 40
    kv_mask = jnp.broadcast_to(kv_mask, (2, 64))
    qpos = jnp.full((2, 1), 39)
    kpos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    ref = attention_ref(qa, ka, va, causal=True, q_positions=qpos,
                        kv_positions=kpos, kv_mask=kv_mask)
    out = flash_attention(qa, ka, va, causal=True, q_positions=qpos,
                          kv_positions=kpos, kv_mask=kv_mask, impl="decode")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)
