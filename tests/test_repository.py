"""Repository semantics + the incremental support-model store."""
import numpy as np
import pytest

from repro.core import Repository, RunRecord, SupportModelStore
from repro.core.encoding import scout_search_space
from repro.simdata import make_emulator

EMU = make_emulator()
SPACE = scout_search_space()


def _records(shared_id, wid, n, seed):
    rng = np.random.default_rng(seed)
    return [EMU.make_record(shared_id, wid, SPACE.configs[ci], rng)
            for ci in rng.choice(len(SPACE), n, replace=False)]


def _filled_repo():
    repo = Repository()
    wids = EMU.workload_ids()
    repo.add_runs(_records("a", wids[0], 6, 0))
    repo.add_runs(_records("b", wids[1], 5, 1))
    repo.add_runs(_records("c", wids[2], 2, 2))   # too few for a GP
    return repo


def test_roundtrip_preserves_configs_metrics_measures(tmp_path):
    repo = _filled_repo()
    path = str(tmp_path / "repo.json")
    repo.save(path)
    back = Repository.load(path)
    assert len(back) == len(repo)
    assert set(back.workloads()) == set(repo.workloads())
    for z in repo.workloads():
        for r0, r1 in zip(repo.runs(z), back.runs(z)):
            assert dict(r0.config) == dict(r1.config)
            np.testing.assert_allclose(r0.metrics, r1.metrics)
            assert set(r0.measures) == set(r1.measures)
            for k in r0.measures:
                assert r0.measures[k] == pytest.approx(r1.measures[k])


def test_filtered_keeps_only_matching_workloads():
    repo = _filled_repo()
    f = repo.filtered(lambda z: z in ("a", "c"))
    assert set(f.workloads()) == {"a", "c"}
    assert len(f.runs("a")) == len(repo.runs("a"))
    assert len(f.runs("b")) == 0
    # original untouched
    assert set(repo.workloads()) == {"a", "b", "c"}


def test_truncated_counts_and_order():
    repo = _filled_repo()
    t = repo.truncated({"a": 4})
    assert len(t.runs("a")) == 4
    # first 4 in insertion order; unmentioned workloads keep everything
    for r0, r1 in zip(repo.runs("a")[:4], t.runs("a")):
        assert dict(r0.config) == dict(r1.config)
    assert len(t.runs("b")) == len(repo.runs("b"))


def test_versions_bump_on_add_run():
    repo = Repository()
    assert repo.version("a") == 0
    repo.add_runs(_records("a", EMU.workload_ids()[0], 3, 0))
    assert repo.version("a") == 3
    assert repo.version("b") == 0
    g = repo.global_version()
    repo.add_run(_records("b", EMU.workload_ids()[1], 1, 1)[0])
    assert repo.version("b") == 1
    assert repo.global_version() == g + 1


def test_store_caches_until_add_run_invalidates():
    repo = _filled_repo()
    store = SupportModelStore(repo, SPACE)
    gp_a = store.get("a", "cost")
    assert gp_a is not None
    assert store.get("a", "cost") is gp_a          # cache hit, same object
    assert store.misses == 1 and store.hits == 1
    gp_b = store.get("b", "cost")
    assert gp_b is not None

    # new data for "a" invalidates ONLY ("a", *) entries
    repo.add_run(_records("a", EMU.workload_ids()[0], 1, 42)[0])
    gp_a2 = store.get("a", "cost")
    assert gp_a2 is not gp_a
    assert gp_a2.n == gp_a.n + 1                   # refit on the new data
    assert store.get("b", "cost") is gp_b          # untouched workload: hit


def test_store_handles_unusable_workloads():
    repo = _filled_repo()
    store = SupportModelStore(repo, SPACE)
    assert store.get("c", "cost") is None          # only 2 runs
    assert store.get("missing", "cost") is None
    # get_stacked skips the unusable ones
    bgp, ids = store.get_stacked(["a", "c", "b", "missing"], "cost")
    assert ids == ["a", "b"]
    assert bgp.m == 2
    none_bgp, none_ids = store.get_stacked(["c", "missing"], "cost")
    assert none_bgp is None and none_ids == []


def test_stack_cache_lru_bound_and_evictions():
    """The version-keyed stack cache is LRU-bounded: beyond max_entries
    the least recently USED entry is evicted (counted), recently-hit
    entries survive, and an evicted set is simply rebuilt on demand."""
    repo = _filled_repo()
    store = SupportModelStore(repo, SPACE, max_entries=2)
    s_ab, _ = store.get_stacked(["a", "b"], "cost")
    s_a, _ = store.get_stacked(["a"], "cost")
    assert store.evictions == 0
    # touch ("a","b") so ("a",) becomes the LRU victim of the next insert
    assert store.get_stacked(["a", "b"], "cost")[0] is s_ab
    store.get_stacked(["b"], "cost")
    assert store.evictions == 1
    assert len(store._stacked) == 2
    assert store.get_stacked(["a", "b"], "cost")[0] is s_ab   # survived
    # the evicted ("a",) set rebuilds transparently (a fresh stack)
    s_a2, ids = store.get_stacked(["a"], "cost")
    assert ids == ["a"] and s_a2 is not s_a
    assert store.evictions == 2                    # its insert evicted again
