"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core.aggregation import aggregate_metrics
from repro.core.encoding import scout_search_space
from repro.core.selection import dist
from repro.core.types import RunRecord
from repro.kernels.ranking_loss import ranking_loss_ref
from repro.kernels.pairwise_pearson import pairwise_pearson_ref

_float = st.floats(min_value=-100, max_value=100, allow_nan=False,
                   width=32)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float32, (5, 8),
                  elements=st.integers(-50, 50).map(float)),
       hnp.arrays(np.float32, (8,), elements=_float))
def test_ranking_loss_invariant_under_monotone_transform(p, y):
    """The property RGPE relies on (paper §III-B): only rankings matter,
    so any strictly increasing transform of predictions leaves the loss
    unchanged. (Predictions drawn on an integer grid so the exp transform
    cannot collapse distinct values in float32.)"""
    base = np.asarray(ranking_loss_ref(jnp.array(p), jnp.array(y)))
    transformed = np.asarray(ranking_loss_ref(
        jnp.array(3.0 * p + 7.0), jnp.array(y)))
    exp_t = np.asarray(ranking_loss_ref(jnp.array(np.exp(p * 0.05)),
                                        jnp.array(y)))
    np.testing.assert_array_equal(base, transformed)
    np.testing.assert_array_equal(base, exp_t)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float64, (4, 18),
                  elements=st.floats(0, 100, allow_nan=False)),
       hnp.arrays(np.float64, (3, 18),
                  elements=st.floats(0, 100, allow_nan=False)))
def test_pearson_symmetry_and_range(a, b):
    r = np.asarray(pairwise_pearson_ref(jnp.array(a), jnp.array(b)))
    assert np.all(r <= 1.0 + 1e-5) and np.all(r >= -1.0 - 1e-5)
    rt = np.asarray(pairwise_pearson_ref(jnp.array(b), jnp.array(a)))
    np.testing.assert_allclose(r, rt.T, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float64, (6, 40),
                  elements=st.floats(0, 100, allow_nan=False)))
def test_agg_quantiles_contained_and_ordered(raw):
    agg = aggregate_metrics(raw)
    assert agg.shape == (6, 3)
    for i in range(6):
        assert raw[i].min() - 1e-9 <= agg[i, 0] <= agg[i, 1] <= agg[i, 2] \
            <= raw[i].max() + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64))
def test_dist_scaling_factor_bounds(n1, n2):
    """DIST scaling factor in (0, 1], = 1 iff equal node counts; score in
    [0, 1]."""
    rng = np.random.default_rng(0)
    r1 = RunRecord("a", {"machine_type": "c4.large", "node_count": n1},
                   rng.random((6, 3)), {"cost": 1.0})
    r2 = RunRecord("b", {"machine_type": "c4.large", "node_count": n2},
                   rng.random((6, 3)), {"cost": 1.0})
    w, s = dist(r1, r2)
    assert 0 < w <= 1.0
    assert (w == 1.0) == (n1 == n2)
    assert 0.0 <= s <= 1.0


def test_encoder_deterministic_and_distinct():
    space = scout_search_space()
    assert len(space) == 69
    X = space.all_encoded()
    X2 = space.all_encoded()
    np.testing.assert_array_equal(X, X2)
    # all configs encode distinctly
    assert len({tuple(row) for row in X}) == 69
    assert np.all(np.isfinite(X))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 68), st.integers(0, 68))
def test_rgpe_weights_simplex(i, j):
    from repro.core import compute_weights, fit_gp
    rng = np.random.default_rng(i * 100 + j)
    x = rng.random((6, 3))
    y = rng.random(6)
    t = fit_gp(x, y)
    b = fit_gp(rng.random((8, 3)), rng.random(8))
    w = np.asarray(compute_weights([b], t, jax.random.PRNGKey(j),
                                   n_samples=32))
    assert np.all(w >= -1e-9)
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)
